//! Integration tests for the sharded serving runtime: replication must not
//! change predictions (multiset-identical across pool sizes), and
//! admission control must shed load under saturation without deadlocking.

use esda::arch::HwConfig;
use esda::coordinator::{
    encode_packet, run_pool, run_pool_source, run_server, run_server_source, synthetic_source,
    AutoscaleConfig, Backend, BackendError, Classification, DeltaStatus, DeltaStore, DropPolicy,
    EventSource, Functional, IngestError, MixSource, NetConfig, NetSource, ReplaySource,
    ReplicaPool, ReplicaSpec, ServerConfig, ServerResult, Simulator, SourcedRequest, Swappable,
    TenantConfig, DEFAULT_TENANT,
};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::model::quant::{quantize_network, QuantizedNet};
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::Rng;
use std::time::Duration;

fn qnet_for(profile: &DatasetProfile) -> QuantizedNet {
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let w = FloatWeights::random(&spec, 3);
    let mut rng = Rng::new(9);
    let calib: Vec<SparseMap<f32>> = (0..3)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    quantize_network(&spec, &w, &calib)
}

fn prediction_multiset(r: &ServerResult) -> Vec<(usize, usize)> {
    let mut v: Vec<(usize, usize)> = r.predictions.iter().map(|p| (p.label, p.pred)).collect();
    v.sort_unstable();
    v
}

/// With a fixed seed and lossless admission, the N-worker pool classifies
/// exactly the same requests to exactly the same classes as the
/// single-worker pipeline — replication is an implementation detail.
#[test]
fn pool_prediction_multiset_is_replica_invariant() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = |workers: usize| ServerConfig {
        n_requests: 24,
        seed: 42,
        clip: 8.0,
        workers,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let single = run_server(&profile, &backend, &cfg(1)).expect("1-worker run");
    assert_eq!(single.metrics.total, 24);
    assert_eq!(single.metrics.dropped, 0);
    let base = prediction_multiset(&single);

    let pooled = run_server(&profile, &backend, &cfg(4)).expect("4-worker run");
    assert_eq!(pooled.metrics.total, 24);
    assert_eq!(pooled.metrics.dropped, 0);
    assert_eq!(pooled.metrics.per_worker.len(), 4);
    assert_eq!(
        pooled.metrics.per_worker.iter().map(|w| w.served).sum::<usize>(),
        24,
        "per-worker served counts must sum to the total"
    );
    assert_eq!(prediction_multiset(&pooled), base, "replication changed predictions");
}

/// The simulator backend is deterministic too, so replica-invariance holds
/// for the cycle-level path as well (smaller request count: it's slower).
#[test]
fn simulator_pool_is_replica_invariant() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let n_ops = qnet.spec.ops().len();
    let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
    let cfg = |workers: usize| ServerConfig {
        n_requests: 8,
        seed: 7,
        clip: 8.0,
        workers,
        queue_depth: 2,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let a = run_server(&profile, &backend, &cfg(1)).expect("1-worker run");
    let b = run_server(&profile, &backend, &cfg(3)).expect("3-worker run");
    assert_eq!(prediction_multiset(&a), prediction_multiset(&b));
    // Cycle counts are per-request properties and must survive pooling.
    assert_eq!(
        a.metrics.mean_sim_latency_ms(1e6).is_some(),
        b.metrics.mean_sim_latency_ms(1e6).is_some()
    );
}

/// A deliberately slow backend to saturate the ingress queue. The first
/// request stalls for a long window (producers are orders of magnitude
/// faster, so the depth-1 queue overflows many times during it — drops
/// are effectively deterministic, not a timing race); later requests are
/// near-instant to keep the test fast.
struct Throttled {
    inner: Functional,
    first: std::sync::atomic::AtomicBool,
    first_delay: Duration,
    delay: Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        let first = self.first.swap(false, std::sync::atomic::Ordering::SeqCst);
        std::thread::sleep(if first { self.first_delay } else { self.delay });
        self.inner.classify(map)
    }
}

fn throttled(profile: &DatasetProfile, first_delay_ms: u64, delay_ms: u64) -> Throttled {
    Throttled {
        inner: Functional::new(qnet_for(profile)),
        first: std::sync::atomic::AtomicBool::new(true),
        first_delay: Duration::from_millis(first_delay_ms),
        delay: Duration::from_millis(delay_ms),
    }
}

/// Saturating a depth-1 queue with the drop-oldest policy records drops,
/// keeps the books balanced, and completes without deadlock.
#[test]
fn saturated_queue_sheds_load_without_deadlock() {
    let profile = DatasetProfile::n_mnist();
    // 250ms stall on request 1: the source+repr stages only need to emit
    // 2 of the remaining 31 requests within it to force a drop.
    let backend = throttled(&profile, 250, 1);
    let cfg = ServerConfig {
        n_requests: 32,
        seed: 11,
        clip: 8.0,
        workers: 1,
        queue_depth: 1,
        drop_policy: DropPolicy::DropOldest,
        batch: 1,
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).expect("shedding run must complete");
    let m = &r.metrics;
    assert!(m.dropped >= 1, "expected admission control to drop under saturation");
    assert!(m.total >= 1, "some requests must still be served");
    assert_eq!(m.total + m.dropped, 32, "served + dropped must cover the offered stream");
    assert!(m.drop_rate() > 0.0 && m.drop_rate() < 1.0);
    // The aggregated percentile report must satisfy the ordering property
    // the propcheck suite verifies on random samples.
    let e2e = m.e2e_percentiles();
    assert!(e2e.p50 <= e2e.p95 && e2e.p95 <= e2e.p99 && e2e.p99 <= e2e.max);
}

/// Blocking admission under the same load stays lossless end to end.
#[test]
fn blocking_admission_is_lossless_under_saturation() {
    let profile = DatasetProfile::n_mnist();
    let backend = throttled(&profile, 1, 1);
    let cfg = ServerConfig {
        n_requests: 16,
        seed: 11,
        clip: 8.0,
        workers: 2,
        queue_depth: 1,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).expect("blocking run");
    assert_eq!(r.metrics.total, 16);
    assert_eq!(r.metrics.dropped, 0);
}

/// Cost-aware routing is a scheduling detail: for any pool shape built
/// from prediction-equivalent classes, the (label, pred) multiset is
/// identical to the single-replica baseline — heterogeneity changes *who*
/// serves a request, never *what* it predicts.
#[test]
fn pool_shape_invariant_prediction_multiset() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let cfg = ServerConfig {
        n_requests: 24,
        seed: 42,
        clip: 8.0,
        workers: 1,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let baseline =
        run_server(&profile, &Functional::new(qnet.clone()), &cfg).expect("baseline run");
    assert_eq!(baseline.metrics.total, 24);
    let base = prediction_multiset(&baseline);

    // Shape A: one class, three replicas, batch affinity 4.
    let pool_a =
        ReplicaPool::build(vec![ReplicaSpec::functional(3, qnet.clone())]).expect("pool a");
    // Shape B: two functional classes at different batch affinities.
    let (qb1, qb2) = (qnet.clone(), qnet.clone());
    let pool_b = ReplicaPool::build(vec![
        ReplicaSpec::new("func-a", 2, 4, move |_| Ok(Box::new(Functional::new(qb1.clone())))),
        ReplicaSpec::new("func-b", 1, 2, move |_| Ok(Box::new(Functional::new(qb2.clone())))),
    ])
    .expect("pool b");
    // Shape C: a fast class next to a throttled (but prediction-identical)
    // class, so the router actually has a cost gradient to act on.
    let (qc1, qc2) = (qnet.clone(), qnet);
    let pool_c = ReplicaPool::build(vec![
        ReplicaSpec::new("fast", 1, 2, move |_| Ok(Box::new(Functional::new(qc1.clone())))),
        ReplicaSpec::new("lagged", 1, 1, move |_| {
            Ok(Box::new(Throttled {
                inner: Functional::new(qc2.clone()),
                first: std::sync::atomic::AtomicBool::new(false),
                first_delay: Duration::ZERO,
                delay: Duration::from_millis(1),
            }))
        }),
    ])
    .expect("pool c");

    for (label, pool) in [("a", pool_a), ("b", pool_b), ("c", pool_c)] {
        let r = run_pool(&profile, &pool, &cfg).expect("pool run");
        assert_eq!(r.metrics.total, 24, "shape {label}");
        assert_eq!(r.metrics.dropped, 0, "shape {label}");
        assert_eq!(
            prediction_multiset(&r),
            base,
            "pool shape {label} changed predictions"
        );
        assert_eq!(
            r.metrics.per_class.iter().map(|c| c.served).sum::<usize>(),
            24,
            "shape {label}: per-class served must sum to the total"
        );
    }
}

/// The router must learn to starve a deliberately slow replica class: it
/// probes the class to seed its cost model (a handful of requests at
/// most), then routes traffic to the fast class — while the prediction
/// multiset stays exactly the single-replica baseline's.
#[test]
fn cost_aware_routing_starves_slow_class() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let cfg = ServerConfig {
        n_requests: 48,
        seed: 42,
        clip: 8.0,
        workers: 1,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let baseline =
        run_server(&profile, &Functional::new(qnet.clone()), &cfg).expect("baseline run");
    let base = prediction_multiset(&baseline);

    let (qf, qs) = (qnet.clone(), qnet);
    // Slow class listed FIRST so the probe traffic actually hits it before
    // the fast class's cost model can win by default ordering.
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::new("slow", 1, 1, move |_| {
            Ok(Box::new(Throttled {
                inner: Functional::new(qs.clone()),
                first: std::sync::atomic::AtomicBool::new(false),
                first_delay: Duration::ZERO,
                delay: Duration::from_millis(25),
            }))
        }),
        ReplicaSpec::new("fast", 1, 4, move |_| Ok(Box::new(Functional::new(qf.clone())))),
    ])
    .expect("pool build");
    let r = run_pool(&profile, &pool, &cfg).expect("pool run");
    assert_eq!(r.metrics.total, 48);
    assert_eq!(prediction_multiset(&r), base, "routing changed predictions");

    let slow = r.metrics.per_class.iter().find(|c| c.class == "slow").expect("slow class");
    let fast = r.metrics.per_class.iter().find(|c| c.class == "fast").expect("fast class");
    assert_eq!(slow.served + fast.served, 48);
    assert!(slow.served >= 1, "the slow class must at least be probed");
    assert!(
        slow.served * 3 <= fast.served,
        "cost-aware routing failed to shift load: slow {} vs fast {}",
        slow.served,
        fast.served
    );
    assert!(
        slow.unseeded >= 1,
        "the slow class's first request(s) must predate its cost model"
    );
}

/// Conservation under randomized configs — worker count, queue depth,
/// batch caps, drop policy, pool shape, service jitter, an occasional
/// randomized SLO, and mid-stream backend failure: every generated
/// request is accounted for exactly once
/// (`submitted == served + dropped + deadline-shed + in_flight`) and no
/// request is served twice (backend classification count == recorded
/// servings).
#[test]
fn serving_conserves_requests_property() {
    use esda::util::propcheck::{check, Gen};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    struct Counting {
        inner: Functional,
        calls: Arc<AtomicUsize>,
        fail_after: Option<usize>,
        delay: Duration,
    }
    impl Counting {
        /// Count, fault-inject, and throttle one request; `Ok(())` means
        /// the inner backend may run it.
        fn admit(&self) -> Result<(), BackendError> {
            let n = self.calls.fetch_add(1, Ordering::SeqCst);
            if let Some(k) = self.fail_after {
                if n >= k {
                    return Err(BackendError("injected mid-stream fault".into()));
                }
            }
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Ok(())
        }
    }
    impl Backend for Counting {
        fn name(&self) -> &str {
            "counting"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            self.admit()?;
            self.inner.classify(map)
        }
        // Delegate the delta path so a counting class can also be a
        // delta class: the per-request books (and the injected fault)
        // must hold on the incremental path too.
        fn supports_delta(&self) -> bool {
            self.inner.supports_delta()
        }
        fn classify_batch_delta(
            &self,
            streams: &[Option<u64>],
            maps: &[SparseMap<f32>],
        ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
            streams
                .iter()
                .zip(maps)
                .map(|(s, m)| {
                    self.admit()?;
                    self.inner
                        .classify_batch_delta(std::slice::from_ref(s), std::slice::from_ref(m))
                        .pop()
                        .expect("one result per request")
                })
                .collect()
        }
        fn evict_stream(&self, stream: u64) {
            self.inner.evict_stream(stream);
        }
    }

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    check("served + dropped + in_flight == submitted", 14, |g: &mut Gen| {
        let n_requests = g.usize(4, 20);
        let cfg = ServerConfig {
            n_requests,
            seed: g.u64(0..=1 << 40),
            clip: 8.0,
            workers: g.usize(1, 3),
            queue_depth: g.usize(1, 4),
            drop_policy: if g.bool() { DropPolicy::Block } else { DropPolicy::DropOldest },
            batch: g.usize(1, 4),
            // Sometimes a (possibly very tight) deadline: requests may
            // then leave the system via any of the three shed points, and
            // the books must still balance.
            slo: if g.chance(0.3) {
                Some(Duration::from_micros(g.u64(1..=50_000)))
            } else {
                None
            },
            // Sometimes a deliberately twitchy autoscaler (tiny tick,
            // hair-trigger watermarks) so replica counts churn mid-run:
            // scale-ups, token retirements, and re-growth must all
            // conserve requests.
            autoscale: if g.chance(0.5) {
                Some(AutoscaleConfig {
                    interval: Duration::from_millis(2),
                    window: Duration::from_millis(20),
                    high_backlog: 0.5,
                    low_util: 0.9,
                })
            } else {
                None
            },
            // Sometimes an overlapping multi-stream source: requests then
            // carry stream ids, and (with a delta class below) the sticky
            // router is live while replicas churn.
            overlap: if g.chance(0.5) { 0.5 + 0.45 * g.rng().f64() } else { 0.0 },
            streams: g.usize(1, 3),
            ..Default::default()
        };
        let fail_after = if g.chance(0.35) { Some(g.usize(0, n_requests)) } else { None };
        let delay = Duration::from_micros(g.u64(0..=400));
        let calls = Arc::new(AtomicUsize::new(0));
        let outcome = if g.bool() {
            // Heterogeneous: two counting classes sharing one call
            // counter; only the first injects the fault, so the abort
            // path crosses class boundaries. The first class is sometimes
            // delta-capable (one cache store shared by its replicas) so
            // sticky routing and incremental execution run under the same
            // churn the property already generates.
            let delta_cls = g.bool();
            let store: DeltaStore =
                Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
            let (qa, qb) = (qnet.clone(), qnet.clone());
            let (ca, cb) = (Arc::clone(&calls), Arc::clone(&calls));
            // Classes are sometimes scalable: the factory then also runs
            // mid-serve, on the controller's scale-up path.
            let (na, nb) = (g.usize(1, 2), g.usize(1, 2));
            let (ma, mb) = (na + g.usize(0, 2), nb + g.usize(0, 1));
            let pool = ReplicaPool::build(vec![
                ReplicaSpec::new("a", na, g.usize(1, 4), move |_| {
                    let inner = if delta_cls {
                        Functional::new(qa.clone()).with_delta_store(0.35, Arc::clone(&store))
                    } else {
                        Functional::new(qa.clone())
                    };
                    Ok(Box::new(Counting {
                        inner,
                        calls: Arc::clone(&ca),
                        fail_after,
                        delay,
                    }))
                })
                .with_max_replicas(ma),
                ReplicaSpec::new("b", nb, g.usize(1, 4), move |_| {
                    Ok(Box::new(Counting {
                        inner: Functional::new(qb.clone()),
                        calls: Arc::clone(&cb),
                        fail_after: None,
                        delay: Duration::ZERO,
                    }))
                })
                .with_max_replicas(mb),
            ])
            .expect("pool build");
            run_pool(&profile, &pool, &cfg)
        } else {
            let backend = Counting {
                inner: Functional::new(qnet.clone()),
                calls: Arc::clone(&calls),
                fail_after,
                delay,
            };
            run_server(&profile, &backend, &cfg)
        };
        match outcome {
            Ok(r) => {
                assert_eq!(
                    r.metrics.total + r.metrics.dropped + r.metrics.deadline_drops(),
                    n_requests,
                    "clean run must conserve the request stream"
                );
                assert_eq!(r.predictions.len(), r.metrics.total);
                assert_eq!(
                    calls.load(Ordering::SeqCst),
                    r.metrics.total,
                    "a request was classified more or fewer times than it was recorded"
                );
                let per_class: usize = r.metrics.per_class.iter().map(|c| c.served).sum();
                assert_eq!(per_class, r.metrics.total);
                // Delta books: every served request carries exactly one
                // execution status, and each request crosses the sticky
                // router at most once.
                let d = &r.metrics.delta;
                assert_eq!(
                    d.attempts() + d.not_applicable,
                    r.metrics.total,
                    "delta statuses must partition the served stream"
                );
                assert!(
                    d.sticky_hits + d.sticky_cold + d.sticky_retired + d.sticky_capacity
                        <= n_requests,
                    "sticky outcomes exceed the offered stream"
                );
                // The per-class deadline sheds are exactly the global
                // router-side count, and every served request was scored
                // against its deadline when one existed.
                let class_ddl: usize =
                    r.metrics.per_class.iter().map(|c| c.deadline_drops).sum();
                assert_eq!(class_ddl, r.metrics.deadline_router);
                // Autoscaled or not, replica books stay inside the band.
                for c in &r.metrics.per_class {
                    assert!(
                        c.replicas_min <= c.replicas && c.replicas <= c.replicas_max,
                        "class {}: {} outside [{}, {}]",
                        c.class,
                        c.replicas,
                        c.replicas_min,
                        c.replicas_max
                    );
                    assert!(
                        (c.replicas_min..=c.replicas_max).contains(&c.replicas_peak),
                        "class {}: peak {} outside [{}, {}]",
                        c.class,
                        c.replicas_peak,
                        c.replicas_min,
                        c.replicas_max
                    );
                }
                if cfg.slo.is_some() {
                    assert_eq!(
                        r.metrics.deadline_met + r.metrics.deadline_missed,
                        r.metrics.total,
                        "every served request must be scored against its deadline"
                    );
                    assert_eq!(r.metrics.deadline_offered, n_requests);
                } else {
                    assert_eq!(r.metrics.deadline_offered, 0);
                    assert_eq!(r.metrics.deadline_drops(), 0);
                }
            }
            Err(e) => {
                assert!(
                    e.completed + e.dropped + e.in_flight <= n_requests,
                    "aborted run over-counts: {} + {} + {} > {n_requests}",
                    e.completed,
                    e.dropped,
                    e.in_flight
                );
                assert!(
                    calls.load(Ordering::SeqCst) >= e.completed,
                    "recorded more servings than classifications"
                );
            }
        }
    });
}

/// Micro-batching must not change what gets predicted: the prediction
/// multiset is identical across batch caps (the batched-vs-sequential
/// equality the compile-once/execute-many engine guarantees), and the
/// recorded batch sizes always partition the served stream.
#[test]
fn batched_pool_prediction_multiset_is_batch_invariant() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = |batch: usize| ServerConfig {
        n_requests: 24,
        seed: 42,
        clip: 8.0,
        workers: 3,
        queue_depth: 8,
        drop_policy: DropPolicy::Block,
        batch,
        ..Default::default()
    };
    let mut base: Option<Vec<(usize, usize)>> = None;
    for batch in [1usize, 4, 16] {
        let r = run_server(&profile, &backend, &cfg(batch)).expect("batched run");
        assert_eq!(r.metrics.total, 24, "batch cap {batch}");
        assert_eq!(r.metrics.dropped, 0);
        let served: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(served, 24, "batch sizes must partition the stream (cap {batch})");
        assert!(
            r.metrics.batch_sizes.iter().all(|&b| b >= 1 && b <= batch),
            "visit outside [1, {batch}]: {:?}",
            r.metrics.batch_sizes
        );
        let ms = prediction_multiset(&r);
        match &base {
            None => base = Some(ms),
            Some(b) => assert_eq!(&ms, b, "batch cap {batch} changed predictions"),
        }
    }
}

/// Sorted-multiset subset check: every (label, pred) pair in `sub` must
/// appear in `sup` with at least the same multiplicity.
fn is_multisubset(sub: &[(usize, usize)], sup: &[(usize, usize)]) -> bool {
    let mut j = 0;
    'outer: for x in sub {
        while j < sup.len() {
            match sup[j].cmp(x) {
                std::cmp::Ordering::Less => j += 1,
                std::cmp::Ordering::Equal => {
                    j += 1;
                    continue 'outer;
                }
                std::cmp::Ordering::Greater => return false,
            }
        }
        return false;
    }
    true
}

/// The acceptance test for router-level SLO shedding: a pool whose every
/// class is far slower than the deadline serves only the cost-model
/// probes — every other request is shed at the router (or expires at the
/// pop) and **never occupies a replica**. The backend call counter is the
/// proof: infeasible requests cost zero accelerator time.
#[test]
fn router_sheds_infeasible_deadlines_before_replicas() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    struct SlowCounting {
        inner: Functional,
        calls: Arc<AtomicUsize>,
        delay: Duration,
    }
    impl Backend for SlowCounting {
        fn name(&self) -> &str {
            "slow-counting"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            self.inner.classify(map)
        }
    }

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let n_requests = 20;
    let cfg = ServerConfig {
        n_requests,
        seed: 42,
        clip: 8.0,
        workers: 1,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 1,
        // Far tighter than the 30 ms service time: once a class's cost
        // model seeds, no predicted completion can meet this.
        slo: Some(Duration::from_millis(4)),
        ..Default::default()
    };
    // No-SLO baseline on the same seed: whatever the SLO'd run serves
    // must predict identically (shedding changes *who* gets served,
    // never *what* a served request predicts).
    let baseline_cfg = ServerConfig { slo: None, ..cfg.clone() };
    let baseline =
        run_server(&profile, &Functional::new(qnet.clone()), &baseline_cfg).expect("baseline");
    let base = prediction_multiset(&baseline);

    let calls = Arc::new(AtomicUsize::new(0));
    let (qa, qb) = (qnet.clone(), qnet);
    let (ca, cb) = (Arc::clone(&calls), Arc::clone(&calls));
    let delay = Duration::from_millis(30);
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::new("a", 1, 1, move |_| {
            Ok(Box::new(SlowCounting {
                inner: Functional::new(qa.clone()),
                calls: Arc::clone(&ca),
                delay,
            }))
        }),
        ReplicaSpec::new("b", 1, 1, move |_| {
            Ok(Box::new(SlowCounting {
                inner: Functional::new(qb.clone()),
                calls: Arc::clone(&cb),
                delay,
            }))
        }),
    ])
    .expect("pool build");
    let r = run_pool(&profile, &pool, &cfg).expect("pool run");
    let m = &r.metrics;
    let classified = calls.load(Ordering::SeqCst);

    // Conservation with the deadline books.
    assert_eq!(m.total, classified, "every classification is recorded");
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        n_requests,
        "served + queue drops + deadline drops must cover the stream"
    );
    // The heart of the test: the replicas saw (almost) only the probe
    // traffic — infeasible requests were shed without a backend call.
    assert!(
        classified <= 6,
        "replicas classified {classified} of {n_requests} requests — infeasible \
         deadlines were not shed upstream"
    );
    assert!(
        m.deadline_router >= n_requests - 6 - m.deadline_ingress,
        "deadline sheds must land at the router/pop: router {} ingress {}",
        m.deadline_router,
        m.deadline_ingress
    );
    let class_ddl: usize = m.per_class.iter().map(|c| c.deadline_drops).sum();
    assert_eq!(class_ddl, m.deadline_router, "per-class deadline books must balance");
    // Attainment reflects reality: the 30 ms probes all finished past the
    // 4 ms deadline, so nothing was served in time.
    assert_eq!(m.deadline_met + m.deadline_missed, m.total);
    assert_eq!(m.slo_attainment(), Some(0.0));
    // Served multiset invariance: what *was* served predicts exactly as
    // the no-SLO baseline did.
    assert!(
        is_multisubset(&prediction_multiset(&r), &base),
        "SLO shedding changed a served request's prediction"
    );
}

/// The single-class path (no router thread) honors deadlines too: a slow
/// replica behind a deep queue sheds queued-too-long requests at the
/// worker pop, scores every served request against its deadline, and the
/// served multiset stays a sub-multiset of the no-SLO baseline.
#[test]
fn single_class_deadlines_enforced_without_router() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let cfg = ServerConfig {
        n_requests: 24,
        seed: 42,
        clip: 8.0,
        workers: 1,
        queue_depth: 8,
        drop_policy: DropPolicy::Block,
        batch: 1,
        // 10 ms service vs a 60 ms deadline: the first requests are
        // served comfortably in time (robust to CI jitter), then the
        // backlog (up to 8 × 10 ms of queue wait behind a full depth-8
        // queue) pushes later ones past their deadline before the worker
        // reaches them.
        slo: Some(Duration::from_millis(60)),
        ..Default::default()
    };
    let baseline_cfg = ServerConfig { slo: None, ..cfg.clone() };
    let baseline =
        run_server(&profile, &Functional::new(qnet.clone()), &baseline_cfg).expect("baseline");
    let base = prediction_multiset(&baseline);

    let backend = throttled(&profile, 10, 10);
    let r = run_server(&profile, &backend, &cfg).expect("slo run");
    let m = &r.metrics;
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        24,
        "books must balance under deadline shedding"
    );
    assert!(m.total >= 1, "an unloaded worker must serve the first request");
    assert!(
        m.deadline_drops() >= 1,
        "a 10 ms/req replica over 24 requests must blow the 60 ms SLO for some"
    );
    // No router ran: a single class, no probe accounting — the sheds are
    // pop-time expiries attributed to that class.
    assert_eq!(m.per_class.len(), 1);
    assert_eq!(m.per_class[0].unseeded, 0);
    assert_eq!(m.per_class[0].deadline_drops, m.deadline_router);
    assert_eq!(m.deadline_met + m.deadline_missed, m.total);
    assert_eq!(m.deadline_offered, 24);
    let att = m.slo_attainment().expect("SLO configured");
    assert!((0.0..1.0).contains(&att), "some but not all in deadline: {att}");
    assert!(
        is_multisubset(&prediction_multiset(&r), &base),
        "deadline shedding changed a served request's prediction"
    );
}

/// The autoscaler acceptance test: a burst into a deliberately slow
/// 1..3-replica class scales it up (backlog/deadline pressure), the idle
/// gap that follows scales it back down, replica counts never leave the
/// band, and the conservation property holds throughout.
#[test]
fn autoscaler_scales_up_under_pressure_and_down_when_idle() {
    use std::time::Instant;

    /// Burst, long idle gap, then a trickle — arrival is always "now".
    struct BurstSource {
        profile: DatasetProfile,
        rng: Rng,
        phases: Vec<(usize, Duration)>,
        phase: usize,
        in_phase: usize,
        total: usize,
    }
    impl EventSource for BurstSource {
        fn name(&self) -> &str {
            "burst"
        }
        fn geometry(&self) -> (usize, usize) {
            (self.profile.w, self.profile.h)
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            while self.phase < self.phases.len() {
                let (n, gap) = self.phases[self.phase];
                if self.in_phase < n {
                    self.in_phase += 1;
                    let label = self.total % self.profile.n_classes;
                    self.total += 1;
                    let events = self.profile.sample(label, &mut self.rng);
                    return Ok(Some(SourcedRequest {
                        label,
                        events,
                        arrival: Instant::now(),
                        tenant: DEFAULT_TENANT,
                        model: 0,
                        stream: None,
                    }));
                }
                std::thread::sleep(gap);
                self.phase += 1;
                self.in_phase = 0;
            }
            Ok(None)
        }
    }

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let n_burst = 40;
    let n_tail = 2;
    let source = BurstSource {
        profile: profile.clone(),
        rng: Rng::new(13),
        // The gap spans many autoscaler windows, so the scale-down side
        // is not a timing race even on a slow CI box.
        phases: vec![(n_burst, Duration::from_millis(600)), (n_tail, Duration::ZERO)],
        phase: 0,
        in_phase: 0,
        total: 0,
    };
    let qs = qnet.clone();
    let pool = ReplicaPool::build(vec![ReplicaSpec::new("work", 1, 1, move |_| {
        Ok(Box::new(Throttled {
            inner: Functional::new(qs.clone()),
            first: std::sync::atomic::AtomicBool::new(false),
            first_delay: Duration::ZERO,
            delay: Duration::from_millis(3),
        }))
    })
    .with_max_replicas(3)])
    .expect("pool build");
    let cfg = ServerConfig {
        queue_depth: 32,
        drop_policy: DropPolicy::Block,
        slo: Some(Duration::from_secs(30)), // generous: pressure comes from backlog
        autoscale: Some(AutoscaleConfig {
            interval: Duration::from_millis(5),
            window: Duration::from_millis(60),
            high_backlog: 2.0,
            low_util: 0.5,
        }),
        ..Default::default()
    };
    let r = run_pool_source(Box::new(source), &pool, &cfg).expect("autoscaled run");
    let m = &r.metrics;
    // Conservation holds while replicas come and go.
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        n_burst + n_tail,
        "conservation must hold under autoscaling"
    );
    let c = &m.per_class[0];
    assert_eq!((c.replicas_min, c.replicas_max), (1, 3));
    assert!(c.replicas_peak >= 2, "the burst must trigger a scale-up (peak {})", c.replicas_peak);
    assert!(c.replicas_peak <= 3 && c.replicas >= 1 && c.replicas <= 3, "band violated");
    assert!(
        m.scaling_events.iter().any(|e| e.to > e.from),
        "scale-up must be logged: {:?}",
        m.scaling_events
    );
    assert!(
        m.scaling_events.iter().any(|e| e.to < e.from),
        "the idle gap must log a scale-down: {:?}",
        m.scaling_events
    );
    for e in &m.scaling_events {
        assert!(e.from.abs_diff(e.to) <= 1, "one step per tick: {e:?}");
        assert!((1..=3).contains(&e.to), "event outside band: {e:?}");
    }
}

/// Cost-profile persistence: a cold two-class pool burns probe requests
/// to seed its routers; re-running with the learned profile seeds them
/// up front — zero probes — while predictions stay baseline-identical.
#[test]
fn seeded_cost_profile_eliminates_probes() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let make_pool = |qnet: &QuantizedNet| {
        let (qa, qb) = (qnet.clone(), qnet.clone());
        ReplicaPool::build(vec![
            ReplicaSpec::new("fast", 1, 4, move |_| Ok(Box::new(Functional::new(qa.clone())))),
            ReplicaSpec::new("slow", 1, 1, move |_| {
                Ok(Box::new(Throttled {
                    inner: Functional::new(qb.clone()),
                    first: std::sync::atomic::AtomicBool::new(false),
                    first_delay: Duration::ZERO,
                    delay: Duration::from_millis(2),
                }))
            }),
        ])
        .expect("pool build")
    };
    let cfg = ServerConfig { n_requests: 32, seed: 42, queue_depth: 8, ..Default::default() };
    let probes = |r: &ServerResult| r.metrics.per_class.iter().map(|c| c.unseeded).sum::<usize>();

    let cold = run_pool(&profile, &make_pool(&qnet), &cfg).expect("cold run");
    assert!(probes(&cold) >= 1, "a cold pool must probe to seed its cost models");
    let learned = cold.metrics.cost_profile.clone();
    assert!(!learned.is_empty(), "a routed run must leave a non-empty profile");
    assert!(learned.classes.contains_key("fast") && learned.classes.contains_key("slow"));

    let warm_cfg = ServerConfig { cost_profile: Some(learned), ..cfg.clone() };
    let warm = run_pool(&profile, &make_pool(&qnet), &warm_cfg).expect("seeded run");
    assert_eq!(warm.metrics.total, 32);
    assert_eq!(
        probes(&warm),
        0,
        "a profile-seeded pool must route every request with a prediction"
    );
    // Seeding changes routing knowledge, never predictions.
    assert_eq!(
        prediction_multiset(&warm),
        prediction_multiset(&cold),
        "cost seeding changed predictions"
    );
}

/// The delta serving tentpole, end to end: an overlapping multi-stream
/// source through a two-class pool whose first class runs incremental
/// execution behind sticky routing, with a twitchy autoscaler churning
/// replicas underneath. Delta + stickiness are performance machinery
/// only — the prediction multiset must be identical to a plain pool's,
/// conservation must hold, and the delta/sticky books must actually move.
#[test]
fn sticky_delta_pool_matches_plain_pool_predictions() {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    /// Paced delta-capable replica: ~1 ms per request keeps a backlog
    /// alive long enough for stream affinity to engage mid-run.
    struct Paced {
        inner: Functional,
        delay: Duration,
    }
    impl Backend for Paced {
        fn name(&self) -> &str {
            "paced"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            std::thread::sleep(self.delay);
            self.inner.classify(map)
        }
        fn supports_delta(&self) -> bool {
            self.inner.supports_delta()
        }
        fn classify_batch_delta(
            &self,
            streams: &[Option<u64>],
            maps: &[SparseMap<f32>],
        ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
            std::thread::sleep(self.delay * maps.len() as u32);
            self.inner.classify_batch_delta(streams, maps)
        }
        fn evict_stream(&self, stream: u64) {
            self.inner.evict_stream(stream);
        }
    }

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let mk_pool = |delta: bool| {
        let (qa, qb) = (qnet.clone(), qnet.clone());
        // One cache store shared across the class's replicas: scale-ups
        // and retirements move streams between workers without losing
        // their cached windows.
        let store: DeltaStore = Arc::new(Mutex::new(HashMap::new()));
        ReplicaPool::build(vec![
            ReplicaSpec::new("a", 1, 2, move |_| {
                let inner = if delta {
                    Functional::new(qa.clone()).with_delta_store(1.0, Arc::clone(&store))
                } else {
                    Functional::new(qa.clone())
                };
                Ok(Box::new(Paced { inner, delay: Duration::from_millis(1) }))
            })
            .with_max_replicas(3),
            ReplicaSpec::new("b", 1, 2, move |_| {
                Ok(Box::new(Paced {
                    inner: Functional::new(qb.clone()),
                    delay: Duration::from_millis(1),
                }))
            }),
        ])
        .expect("pool build")
    };
    let n_requests = 48;
    let cfg = ServerConfig {
        n_requests,
        seed: 17,
        clip: 8.0,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 2,
        overlap: 0.9,
        streams: 2,
        autoscale: Some(AutoscaleConfig {
            interval: Duration::from_millis(2),
            window: Duration::from_millis(20),
            high_backlog: 0.5,
            low_util: 0.9,
        }),
        ..Default::default()
    };

    let with_delta = run_pool(&profile, &mk_pool(true), &cfg).expect("delta run");
    let plain = run_pool(&profile, &mk_pool(false), &cfg).expect("plain run");
    for r in [&with_delta, &plain] {
        assert_eq!(
            r.metrics.total + r.metrics.dropped + r.metrics.deadline_drops(),
            n_requests,
            "conservation must hold under sticky routing and churn"
        );
        assert_eq!(r.metrics.total, n_requests, "blocking admission is lossless");
    }
    assert_eq!(
        prediction_multiset(&with_delta),
        prediction_multiset(&plain),
        "delta execution + sticky routing changed predictions"
    );

    let d = &with_delta.metrics.delta;
    assert!(d.attempts() > 0, "the delta class must see stream-tagged requests");
    assert!(d.hits >= 1, "an overlapping stream on a warm shared cache must delta-hit");
    assert_eq!(
        d.attempts() + d.not_applicable,
        with_delta.metrics.total,
        "delta statuses must partition the served stream"
    );
    assert!(
        d.sticky_hits + d.sticky_cold + d.sticky_retired + d.sticky_capacity > 0,
        "the sticky router must have made at least one placement decision"
    );

    let p = &plain.metrics.delta;
    assert_eq!(p.attempts(), 0, "a delta-free pool must never attempt delta execution");
    assert_eq!(p.not_applicable, plain.metrics.total);
    assert_eq!(
        p.sticky_hits + p.sticky_cold + p.sticky_retired + p.sticky_capacity,
        0,
        "sticky routing must stay inert without a delta-capable class"
    );
}

/// End-to-end over the real ingestion boundary: a generated dataset
/// replayed (time-compressed) through the serving runtime with a generous
/// SLO serves every sample within deadline — the `serve --source
/// replay:path@speed --slo-ms N` path, minus the CLI.
#[test]
fn replay_source_serves_end_to_end_with_slo() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let dir = std::env::temp_dir().join(format!("esda_replay_e2e_{}", std::process::id()));
    let (_train, test) =
        esda::events::io::generate_dataset_files(&profile, &dir, 1, 2, 7).expect("gen");
    let n = profile.n_classes * 2;

    let cfg = ServerConfig {
        workers: 2,
        queue_depth: 8,
        slo: Some(Duration::from_secs(60)),
        ..Default::default()
    };
    let source = ReplaySource::open(&test, 1e6).expect("open replay");
    let r = run_server_source(Box::new(source), &backend, &cfg).expect("replay serve");
    let m = &r.metrics;
    assert_eq!(m.total, n, "every replayed sample must be served");
    assert_eq!(m.deadline_offered, n);
    assert_eq!(m.slo_attainment(), Some(1.0), "unloaded run must meet a 60 s SLO");
    assert_eq!(m.deadline_drops(), 0);
    // Replay preserves the recorded labels (n_per_class_test = 2 of each).
    for c in 0..profile.n_classes {
        assert_eq!(
            r.predictions.iter().filter(|p| p.label == c).count(),
            2,
            "class {c} must appear exactly twice"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The ingestion-boundary regression test: a capture whose middle sample
/// is corrupt (unsorted events under the replay's reject policy) no
/// longer kills the run — the bad sample is skipped and counted under
/// `ingest_rejects` while every good sample around it is still served.
#[test]
fn replay_with_corrupt_sample_mid_capture_completes() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let dir = std::env::temp_dir().join(format!("esda_bad_sample_{}", std::process::id()));
    let mut rng = Rng::new(5);
    let good = |label: usize, rng: &mut Rng| esda::events::io::Sample {
        label: label as u32,
        events: profile.sample(label, rng),
    };
    let ev = |t: u32| esda::events::Event { t_us: t, x: 1, y: 1, polarity: true };
    // One unsorted sample sandwiched between good ones.
    let samples = vec![
        good(0, &mut rng),
        good(1, &mut rng),
        esda::events::io::Sample { label: 0, events: vec![ev(50), ev(10)] },
        good(2, &mut rng),
    ];
    let path = dir.join("corrupt_mid.esda");
    esda::events::io::write_dataset(&path, profile.w, profile.h, &samples).expect("write");

    let cfg = ServerConfig { queue_depth: 8, ..Default::default() };
    let source = ReplaySource::open(&path, 1e6).expect("open replay");
    let r = run_server_source(Box::new(source), &backend, &cfg).expect("run must complete");
    let m = &r.metrics;
    assert_eq!(m.total, 3, "every good sample is served");
    assert_eq!(m.ingest_rejects, 1, "the corrupt sample is counted, not fatal");
    assert_eq!(m.dropped, 0);
    // Single-tenant run: the reject lands on the implicit default tenant.
    assert_eq!(m.per_tenant.len(), 1);
    assert_eq!(m.per_tenant[0].ingest_rejects, 1);
    assert_eq!(m.per_tenant[0].offered(), 4, "3 served + 1 reject");
    std::fs::remove_dir_all(&dir).ok();
}

/// Randomized multi-tenant conservation: with random tenant tables
/// (weights, occasional per-tenant SLOs), random queue shapes, and
/// mid-stream recoverable rejects, every emission is accounted for
/// exactly once — globally, and per tenant via
/// `offered() == served + dropped + deadline-shed + ingest-rejected`.
#[test]
fn multi_tenant_serving_conserves_requests_property() {
    use esda::util::propcheck::{check, Gen};
    use std::time::Instant;

    /// Emits its plan in order: an admitted request tagged with a tenant,
    /// or a recoverable reject (tagged or untagged).
    struct TenantSource {
        profile: DatasetProfile,
        rng: Rng,
        plan: std::collections::VecDeque<Result<usize, Option<usize>>>,
        emitted: usize,
    }
    impl EventSource for TenantSource {
        fn name(&self) -> &str {
            "tenants"
        }
        fn geometry(&self) -> (usize, usize) {
            (self.profile.w, self.profile.h)
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            match self.plan.pop_front() {
                None => Ok(None),
                Some(Ok(tenant)) => {
                    let label = self.emitted % self.profile.n_classes;
                    self.emitted += 1;
                    let events = self.profile.sample(label, &mut self.rng);
                    Ok(Some(SourcedRequest {
                        label,
                        events,
                        arrival: Instant::now(),
                        tenant,
                        model: 0,
                        stream: None,
                    }))
                }
                Some(Err(tag)) => {
                    let e = IngestError::recoverable("injected mid-stream reject");
                    Err(match tag {
                        Some(t) => e.with_tenant(t),
                        None => e,
                    })
                }
            }
        }
    }

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    check("per-tenant books balance", 10, |g: &mut Gen| {
        let n_tenants = g.usize(1, 3);
        let tenants: Vec<TenantConfig> = (0..n_tenants)
            .map(|i| {
                let tc = TenantConfig::new(format!("t{i}"), g.usize(1, 4));
                if g.chance(0.3) {
                    tc.with_slo(Duration::from_micros(g.u64(1..=200_000)))
                } else {
                    tc
                }
            })
            .collect();
        let n_items = g.usize(6, 24);
        let mut sent = vec![0usize; n_tenants];
        let mut rejected = vec![0usize; n_tenants];
        let mut untagged = 0usize;
        let plan: std::collections::VecDeque<Result<usize, Option<usize>>> = (0..n_items)
            .map(|_| {
                if g.chance(0.2) {
                    if g.chance(0.25) {
                        untagged += 1;
                        Err(None)
                    } else {
                        let t = g.usize(0, n_tenants - 1);
                        rejected[t] += 1;
                        Err(Some(t))
                    }
                } else {
                    let t = g.usize(0, n_tenants - 1);
                    sent[t] += 1;
                    Ok(t)
                }
            })
            .collect();
        let cfg = ServerConfig {
            seed: g.u64(0..=1 << 40),
            workers: g.usize(1, 2),
            queue_depth: g.usize(1, 6),
            drop_policy: if g.bool() { DropPolicy::Block } else { DropPolicy::DropOldest },
            batch: g.usize(1, 3),
            slo: if g.chance(0.3) {
                Some(Duration::from_micros(g.u64(1..=100_000)))
            } else {
                None
            },
            tenants,
            ..Default::default()
        };
        let source = TenantSource {
            profile: profile.clone(),
            rng: Rng::new(g.u64(0..=1 << 32)),
            plan,
            emitted: 0,
        };
        let backend = Functional::new(qnet.clone());
        let r = run_server_source(Box::new(source), &backend, &cfg).expect("run");
        let m = &r.metrics;
        let n_ok: usize = sent.iter().sum();
        let n_rej: usize = rejected.iter().sum::<usize>() + untagged;
        assert_eq!(
            m.total + m.dropped + m.deadline_drops(),
            n_ok,
            "global books must cover every admitted emission"
        );
        assert_eq!(m.ingest_rejects, n_rej, "every injected reject is counted");
        assert_eq!(m.per_tenant.len(), n_tenants);
        for (i, ts) in m.per_tenant.iter().enumerate() {
            // Untagged rejects stay global-only on a multi-tenant table;
            // on a single-tenant table they land on the only tenant.
            let attributed = rejected[i] + if n_tenants == 1 { untagged } else { 0 };
            assert_eq!(
                ts.offered(),
                sent[i] + attributed,
                "tenant {i} ({}) books must balance: {ts:?}",
                ts.tenant
            );
        }
        let t_served: usize = m.per_tenant.iter().map(|t| t.served).sum();
        assert_eq!(t_served, m.total, "per-tenant served must sum to the total");
    });
}

/// The multi-tenant acceptance test: a tenant flooding the loopback TCP
/// front door cannot starve the quiet tenant. The quota gate sheds the
/// flood at admission, every quiet request is served, and the quiet
/// tenant's SLO attainment stays perfect.
#[test]
fn loopback_saturating_tenant_cannot_starve_the_quiet_one() {
    use std::io::Write as _;
    use std::net::TcpStream;

    let profile = DatasetProfile::n_mnist();
    let backend = throttled(&profile, 2, 2);
    let (n_flood, n_quiet) = (40u32, 5u32);
    let ncfg =
        NetConfig { tenants: 2, idle_timeout: Duration::from_secs(5), ..NetConfig::default() };
    let src = NetSource::tcp(0, profile.w, profile.h, ncfg)
        .expect("bind")
        .with_limit((n_flood + n_quiet) as usize);
    let port = src.local_port();
    fn ev(t: u32, x: u16, y: u16) -> esda::events::Event {
        esda::events::Event { t_us: t, x, y, polarity: true }
    }
    fn frame(tenant: u16, label: u32, x: u16) -> Vec<u8> {
        let pkt = encode_packet(tenant, label, &[ev(1, x, x), ev(2, x, x), ev(3, x, x)]);
        let mut f = (pkt.len() as u32).to_le_bytes().to_vec();
        f.extend_from_slice(&pkt);
        f
    }
    // The flood burst goes out back-to-back on one connection; the quiet
    // tenant trickles on another, landing mid-saturation.
    let flood = std::thread::spawn(move || {
        let mut c = TcpStream::connect(("127.0.0.1", port)).unwrap();
        for i in 0..n_flood {
            c.write_all(&frame(0, i % 10, 1)).unwrap();
        }
        c.flush().unwrap();
    });
    let quiet = std::thread::spawn(move || {
        let mut c = TcpStream::connect(("127.0.0.1", port)).unwrap();
        for i in 0..n_quiet {
            c.write_all(&frame(1, i % 10, 4)).unwrap();
            c.flush().unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
    });
    // Depth 16 split 1:1 gives each tenant a quota of 8: the flood can
    // hold at most 8 ingress slots, so the queue never fills and the
    // quiet tenant's (at most 5 concurrent) requests are always admitted.
    let cfg = ServerConfig {
        workers: 1,
        queue_depth: 16,
        drop_policy: DropPolicy::DropOldest,
        tenants: vec![
            TenantConfig::new("flood", 1),
            TenantConfig::new("quiet", 1).with_slo(Duration::from_secs(60)),
        ],
        ..Default::default()
    };
    let r = run_server_source(Box::new(src), &backend, &cfg).expect("loopback run");
    flood.join().unwrap();
    quiet.join().unwrap();
    let m = &r.metrics;
    assert_eq!(m.per_tenant.len(), 2);
    let f = &m.per_tenant[0];
    let q = &m.per_tenant[1];
    assert_eq!((f.tenant.as_str(), q.tenant.as_str()), ("flood", "quiet"));
    assert_eq!(q.served, n_quiet as usize, "the quiet tenant must not be starved");
    assert_eq!(q.dropped, 0);
    assert_eq!(q.slo_attainment(), Some(1.0), "quiet requests all land in deadline");
    assert!(f.dropped >= 1, "the flood must be shed at its quota: {f:?}");
    // TCP delivers everything: per-tenant and global books cover it all.
    assert_eq!(f.offered(), n_flood as usize, "{f:?}");
    assert_eq!(q.offered(), n_quiet as usize, "{q:?}");
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        (n_flood + n_quiet) as usize,
        "global books must cover the full loopback stream"
    );
}

/// Fleet conservation under randomized configs: a weighted model mix
/// through a pool with one class per model, random queue shapes, drop
/// policies, and an occasional tight SLO — the global books balance, and
/// every model's books independently cover exactly the share of the
/// stream the mix schedule assigned to it, whichever shed point each
/// request left through.
#[test]
fn multi_model_serving_conserves_requests_property() {
    use esda::util::propcheck::{check, Gen};

    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    check("per-model books balance", 10, |g: &mut Gen| {
        let n_models = g.usize(1, 3);
        let n_requests = g.usize(6, 20);
        // Random per-model weights; at least one slot in the mix cycle.
        let mut weights: Vec<usize> = (0..n_models).map(|_| g.usize(0, 3)).collect();
        if weights.iter().all(|w| *w == 0) {
            weights[0] = 1;
        }
        let specs: Vec<ReplicaSpec> = (0..n_models)
            .map(|i| {
                let q = qnet.clone();
                ReplicaSpec::new(format!("m{i}-c"), g.usize(1, 2), g.usize(1, 3), move |_| {
                    Ok(Box::new(Functional::new(q.clone())))
                })
                .for_model(format!("m{i}"))
            })
            .collect();
        let pool = ReplicaPool::build(specs).expect("pool build");
        let cfg = ServerConfig {
            n_requests,
            seed: g.u64(0..=1 << 40),
            queue_depth: g.usize(1, 4),
            drop_policy: if g.bool() { DropPolicy::Block } else { DropPolicy::DropOldest },
            batch: g.usize(1, 3),
            slo: if g.chance(0.3) {
                Some(Duration::from_micros(g.u64(1..=50_000)))
            } else {
                None
            },
            ..Default::default()
        };
        // The mix schedule is deterministic, so each model's offered load
        // is known exactly up front — before any drop or shed happens.
        let mut schedule: Vec<usize> = Vec::new();
        for (model, &w) in weights.iter().enumerate() {
            for _ in 0..w {
                schedule.push(model);
            }
        }
        let expected: Vec<usize> = (0..n_models)
            .map(|m| (0..n_requests).filter(|k| schedule[k % schedule.len()] == m).count())
            .collect();
        let src = MixSource::new(Box::new(synthetic_source(&profile, &cfg)), &weights);
        let r = run_pool_source(Box::new(src), &pool, &cfg).expect("fleet run");
        let m = &r.metrics;
        assert_eq!(
            m.total + m.dropped + m.deadline_drops(),
            n_requests,
            "global books must cover the mixed stream"
        );
        assert_eq!(m.per_model.len(), n_models);
        for (i, ms) in m.per_model.iter().enumerate() {
            assert_eq!(ms.model, format!("m{i}"));
            assert_eq!(
                ms.offered(),
                expected[i],
                "model m{i} books must cover exactly its share of the mix: {ms:?}"
            );
            assert!(ms.correct <= ms.served, "accuracy books inside the served count");
        }
        let served: usize = m.per_model.iter().map(|x| x.served).sum();
        assert_eq!(served, m.total, "per-model served must sum to the total");
        let dropped: usize = m.per_model.iter().map(|x| x.dropped).sum();
        assert_eq!(dropped, m.dropped, "per-model drops must sum to the global count");
        let shed: usize = m.per_model.iter().map(|x| x.deadline_drops()).sum();
        assert_eq!(shed, m.deadline_drops(), "per-model deadline sheds must sum up");
    });
}

/// The hot-swap acceptance test: flipping a [`Swappable`] model to a new
/// build mid-run loses not a single request. The swap is gated on
/// observed progress (a third of the stream served), blocking admission
/// stays lossless across the flip, the books balance, and both builds
/// actually served traffic.
#[test]
fn hot_swap_loses_no_requests() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Paces requests (so the swap lands mid-run) and counts them all.
    struct Paced {
        inner: Arc<dyn Backend>,
        calls: Arc<AtomicUsize>,
        delay: Duration,
    }
    impl Backend for Paced {
        fn name(&self) -> &str {
            "paced-swappable"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            self.inner.classify(map)
        }
    }
    /// Counts the requests the post-swap build serves.
    struct Counted {
        inner: Functional,
        calls: Arc<AtomicUsize>,
    }
    impl Backend for Counted {
        fn name(&self) -> &str {
            "candidate"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            self.inner.classify(map)
        }
    }

    let profile = DatasetProfile::n_mnist();
    let n_requests = 48;
    let handle = Arc::new(Swappable::new(
        "prod",
        Arc::new(Functional::new(qnet_for(&profile))) as Arc<dyn Backend>,
    ));
    let total_calls = Arc::new(AtomicUsize::new(0));
    let new_calls = Arc::new(AtomicUsize::new(0));
    let (h, tc) = (Arc::clone(&handle), Arc::clone(&total_calls));
    let pool = ReplicaPool::build(vec![ReplicaSpec::new("prod-c", 2, 2, move |_| {
        Ok(Box::new(Paced {
            inner: Arc::clone(&h) as Arc<dyn Backend>,
            calls: Arc::clone(&tc),
            delay: Duration::from_millis(1),
        }))
    })])
    .expect("pool build");
    let swapper = {
        let h = Arc::clone(&handle);
        let tc = Arc::clone(&total_calls);
        let nc = Arc::clone(&new_calls);
        let next = Functional::new(qnet_for(&profile));
        std::thread::spawn(move || {
            while tc.load(Ordering::SeqCst) < n_requests / 3 {
                std::thread::sleep(Duration::from_micros(200));
            }
            h.swap(Arc::new(Counted { inner: next, calls: nc }));
        })
    };
    let cfg = ServerConfig {
        n_requests,
        seed: 42,
        queue_depth: 8,
        drop_policy: DropPolicy::Block,
        batch: 2,
        ..Default::default()
    };
    let r = run_pool(&profile, &pool, &cfg).expect("swapped run");
    swapper.join().expect("swap thread");
    let m = &r.metrics;
    assert_eq!(handle.generation(), 1, "the scheduled swap must have landed");
    assert_eq!(m.total, n_requests, "blocking admission stays lossless across the flip");
    assert_eq!(m.dropped, 0);
    assert_eq!(m.deadline_drops(), 0);
    assert_eq!(r.predictions.len(), n_requests);
    assert_eq!(
        total_calls.load(Ordering::SeqCst),
        n_requests,
        "every request was classified exactly once"
    );
    let post = new_calls.load(Ordering::SeqCst);
    assert!(post >= 1, "the post-swap build must serve the tail of the stream");
    assert!(
        n_requests - post >= 10,
        "the pre-swap build must have served the head: only {} of {n_requests} pre-swap",
        n_requests - post
    );
    assert_eq!(m.per_model.len(), 1);
    assert_eq!(m.per_model[0].offered(), n_requests, "the model books must balance");
}
