// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Ablations for the design choices DESIGN.md calls out:
//!
//! A. **Dense-input overhead** (§4.3's caveat): at ≥70% NZ some blocks are
//!    slower than the dense baseline — quantify the dynamic-control
//!    overhead the token machinery costs.
//! B. **Co-optimized vs uniform PF** (the value of Eqn. 6): bottleneck
//!    latency of the sparsity-aware allocation vs the best uniform PF at
//!    equal resources.
//! C. **All-on-chip pipelining vs layer-sequential** (the NullHop
//!    architecture ablation) across input densities.
//! D. **FIFO depth sensitivity**: simulated latency vs inter-module queue
//!    depth (the paper's templates expose buffer sizes as parameters).

use esda::arch::builder::{build_pipeline, HwConfig};
use esda::arch::dense::dense_chain_latency;
use esda::arch::nullhop::{esda_latency_matched, nullhop_latency, NullHopConfig};
use esda::arch::simulate_inference;
use esda::hwopt::cost::{op_costs, total_resources};
use esda::hwopt::{allocate, stats::collect_stats, Budget};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::{Block, NetworkSpec};
use esda::report::Table;
use esda::sparse::{Bitmap, SparseMap, Token};
use esda::util::Rng;

fn random_input(rng: &mut Rng, w: usize, h: usize, c: usize, p: f64) -> SparseMap<f32> {
    let mut m = SparseMap::empty(w, h, c);
    for y in 0..h {
        for x in 0..w {
            if rng.chance(p) {
                let f: Vec<f32> = (0..c).map(|_| rng.f32() * 2.0 - 1.0).collect();
                m.push(Token::new(x as u16, y as u16), &f);
            }
        }
    }
    m
}

fn random_bitmaps(rng: &mut Rng, w: usize, h: usize, p: f64, n: usize) -> Vec<Bitmap> {
    (0..n)
        .map(|_| {
            let mut b = Bitmap::new(w, h);
            for y in 0..h {
                for x in 0..w {
                    if rng.chance(p) {
                        b.set(x, y);
                    }
                }
            }
            b
        })
        .collect()
}

fn ablation_a_dense_overhead() {
    println!("## A. dynamic-sparse control overhead at high density\n");
    let mut rng = Rng::new(0xAB1A);
    // An early-network-like block: large resolution, small channels — the
    // configuration §4.3 flags as overhead-prone.
    let spec = NetworkSpec {
        name: "blk0".into(),
        w: 64,
        h: 64,
        cin: 8,
        n_classes: 2,
        blocks: vec![Block::MBConv { cout: 8, expand: 1, k: 3, stride: 1 }],
    };
    let ops = spec.ops();
    let pfs = vec![8usize; ops.len()];
    let weights = FloatWeights::random(&spec, 1);
    let mut t = Table::new(
        "early block (64×64, C=8): sparse vs dense cycles",
        &["NZ ratio", "sparse", "dense", "speedup"],
    );
    for &p in &[0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
        let calib = vec![random_input(&mut rng, 64, 64, 8, p)];
        let qnet = quantize_network(&spec, &weights, &calib);
        let input = random_input(&mut rng, 64, 64, 8, p);
        let qin = esda::model::exec::quantize_input(&qnet, &input);
        let cfg = HwConfig { pf: pfs.clone(), fifo_depth: 8 };
        let mut pipe = build_pipeline(&qnet, &cfg, &qin);
        let sparse = pipe.run(10_000_000_000).unwrap().cycles as f64;
        let dense = dense_chain_latency(&ops, &pfs, 64, 64) as f64;
        t.row(vec![
            format!("{p:.1}"),
            format!("{sparse:.0}"),
            format!("{dense:.0}"),
            format!("{:.2}×", dense / sparse),
        ]);
    }
    println!("{}", t.render());
    println!("(speedup < 1× near-dense reproduces the paper's §4.3 caveat)\n");
}

fn ablation_b_allocation() {
    println!("## B. Eqn.6 co-optimized allocation vs best uniform PF\n");
    let mut rng = Rng::new(0xAB1B);
    let spec = NetworkSpec::compact("compact", 64, 64, 3);
    let stats = collect_stats(&spec, &random_bitmaps(&mut rng, 64, 64, 0.12, 4));
    let budget = Budget { dsp: 512, bram: 512 };
    let opt = allocate(&spec, &stats, &budget).unwrap();
    // Best uniform PF that fits the same budget.
    let ops = spec.ops();
    let mut best_uniform: Option<(usize, f64)> = None;
    for pf in [1, 2, 4, 8, 16, 32, 64, 128] {
        let pfs: Vec<usize> = ops.iter().map(|o| if o.has_weights() { pf } else { 1 }).collect();
        let costs = op_costs(&spec, &stats, &pfs);
        let r = total_resources(&costs);
        if r.dsp > budget.dsp || r.bram > budget.bram {
            continue;
        }
        let lat = costs.iter().map(|c| c.latency).fold(0.0, f64::max);
        if best_uniform.map_or(true, |(_, l)| lat < l) {
            best_uniform = Some((pf, lat));
        }
    }
    let (upf, ulat) = best_uniform.unwrap();
    println!(
        "co-optimized: {:.0} cycles ({} DSP, {} BRAM) | best uniform PF={}: {:.0} cycles → {:.2}× worse\n",
        opt.latency,
        opt.resources.dsp,
        opt.resources.bram,
        upf,
        ulat,
        ulat / opt.latency
    );
}

fn ablation_c_pipelining() {
    println!("## C. all-on-chip pipeline vs layer-sequential (NullHop-style) across density\n");
    let spec = NetworkSpec::compact("compact", 64, 64, 3);
    let mut t = Table::new(
        "cycles per inference (matched 1282-PE budget)",
        &["NZ ratio", "layer-sequential", "ESDA pipeline", "speedup"],
    );
    let mut rng = Rng::new(0xAB1C);
    for &p in &[0.02, 0.05, 0.12, 0.3, 0.6] {
        let stats = collect_stats(&spec, &random_bitmaps(&mut rng, 64, 64, p, 4));
        let nh = nullhop_latency(&spec, &stats, &NullHopConfig::default());
        let esda = esda_latency_matched(&spec, &stats, 1282);
        t.row(vec![
            format!("{p:.2}"),
            format!("{nh:.0}"),
            format!("{esda:.0}"),
            format!("{:.1}×", nh / esda),
        ]);
    }
    println!("{}", t.render());
}

fn ablation_d_fifo_depth() {
    println!("## D. FIFO depth sensitivity\n");
    let profile = esda::events::DatasetProfile::n_mnist();
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 3);
    let mut rng = Rng::new(0xAB1D);
    let mk = |rng: &mut Rng, i: usize| {
        let es = profile.sample(i % profile.n_classes, rng);
        esda::events::repr::histogram2_norm(&es, profile.w, profile.h, 8.0)
    };
    let calib: Vec<_> = (0..3).map(|i| mk(&mut rng, i)).collect();
    let qnet = quantize_network(&spec, &weights, &calib);
    let input = mk(&mut rng, 7);
    let mut t = Table::new("simulated cycles vs inter-module FIFO depth", &["depth", "cycles"]);
    for depth in [1, 2, 4, 8, 16, 64] {
        let cfg = HwConfig { pf: vec![8; spec.ops().len()], fifo_depth: depth };
        let (_, report) = simulate_inference(&qnet, &cfg, &input, 10_000_000_000).unwrap();
        t.row(vec![depth.to_string(), report.cycles.to_string()]);
    }
    println!("{}", t.render());
    println!("(shallow FIFOs serialize the pipeline; returns diminish past ~8 — the template default)\n");
}

fn main() {
    println!("# Ablations\n");
    ablation_a_dense_overhead();
    ablation_b_allocation();
    ablation_c_pipelining();
    ablation_d_fifo_depth();
}
