//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! The exact same algorithm is implemented in `python/compile/data.py`.
//! Cross-language determinism matters: the synthetic event datasets used to
//! train the model (python) and to drive the hardware simulator (rust) must
//! be bit-identical so that golden-vector tests are meaningful.

/// splitmix64 step — used to expand a single u64 seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free bound is
    /// overkill here; modulo bias is negligible for our n << 2^64 and would
    /// complicate the python mirror).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// mirrored exactly in python).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a stream-independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    /// Golden values — these same numbers are asserted by
    /// `python/tests/test_data.py` to pin cross-language agreement.
    #[test]
    fn golden_sequence_seed_1234() {
        let mut r = Rng::new(1234);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // Computed once from this implementation; the python mirror must match.
        let expect: Vec<u64> = golden_seed_1234();
        assert_eq!(got, expect);
    }

    fn golden_seed_1234() -> Vec<u64> {
        // Recompute with an independent transcription of the algorithm to
        // guard against typos in the main implementation.
        let mut sm = 1234u64;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            *v = z ^ (z >> 31);
        }
        let mut out = Vec::new();
        for _ in 0..4 {
            let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
            let t = s[1] << 17;
            s[2] ^= s[0];
            s[3] ^= s[1];
            s[1] ^= s[2];
            s[0] ^= s[3];
            s[2] ^= t;
            s[3] = s[3].rotate_left(45);
            out.push(result);
        }
        out
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
