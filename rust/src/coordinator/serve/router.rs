//! Stage 3: the cost-aware router — moves admitted requests from the
//! ingress queue to class sub-queues by predicted completion time,
//! restricted to classes serving the request's model, shedding requests
//! no eligible class can finish in time, and attempting the sticky
//! (cache-affinity) fast path first for live streams.

use super::state::{ClassCtx, SharedCtx};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

/// What the router decided for one request.
pub(super) struct RouteDecision {
    /// Chosen class index.
    pub(super) class: usize,
    /// Per-request service-seconds prediction the decision was based on
    /// (NaN for a probe), recorded so the caller logs exactly what the
    /// router saw — not a re-query that a concurrent `observe` may have
    /// seeded in the meantime.
    pub(super) predicted_s: f64,
    /// Predicted *completion* seconds including queueing ahead (NaN when
    /// unknown — a probe, or every class unseeded). The deadline shed
    /// compares this against the request's remaining budget.
    pub(super) completion_s: f64,
}

/// Pick the class minimizing predicted completion time for a request in
/// `bucket`, considering only classes serving `model` — the model tag is
/// a hard filter, not a cost input. Unseeded classes are probed eagerly
/// (their real cost is unknown and must be learned) but only up to one
/// outstanding request per replica while any alternative — seeded, or
/// under its probe cap — exists. In the cold-start corner where *every*
/// class is unseeded and probe-capped, requests spread by per-replica
/// backlog (and each sub-queue's bounded depth caps how much can ever
/// stack behind one slow class). Ties break toward the smaller
/// per-replica backlog.
///
/// Every clamped model id has at least one serving class by construction
/// (the model table is derived from the class tags); the `best = 0`
/// initialization is a defensive fallback, never a routing decision.
pub(super) fn route(classes: &[ClassCtx<'_>], bucket: usize, model: usize) -> RouteDecision {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    let mut best_load = f64::INFINITY;
    let mut best_pred = f64::NAN;
    let mut found = false;
    for (i, c) in classes.iter().enumerate() {
        if c.model != model {
            continue;
        }
        let backlog = c.backlog.load(Ordering::SeqCst);
        // Active (not instantiated) replicas: the autoscaler moves this,
        // and routing decisions must follow the live serving capacity.
        let replicas = c.active.load(Ordering::SeqCst).max(1);
        // Queued + in-service requests per replica: the tie-break key, so
        // a 1-replica class doesn't absorb as much as a 4-replica one.
        let load = backlog as f64 / replicas as f64;
        let pred = c.cost.predict(bucket);
        let cost = match pred {
            // Predicted completion ≈ own service time scaled by how many
            // requests already wait ahead of it per replica.
            Some(s) => s * (load + 1.0),
            None if backlog < replicas => f64::NEG_INFINITY,
            None => f64::INFINITY,
        };
        if !found || cost < best_cost || (cost == best_cost && load < best_load) {
            best = i;
            best_cost = cost;
            best_load = load;
            best_pred = pred.unwrap_or(f64::NAN);
            found = true;
        }
    }
    RouteDecision {
        class: best,
        predicted_s: best_pred,
        completion_s: if best_cost.is_finite() { best_cost } else { f64::NAN },
    }
}

/// The router stage body: drain the ingress until it closes, placing
/// each request sticky-first, then cost-aware within its model's
/// classes, shedding on predicted deadline infeasibility.
pub(super) fn router_stage(sx: &SharedCtx<'_, '_>) {
    let multi_tenant = sx.tenants.len() > 1;
    while let Some(mut req) = sx.ingress.pop() {
        // Out of the ingress queue: the tenant's quota slot is free again
        // whatever happens downstream.
        if multi_tenant {
            sx.tenants[req.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
        }
        // Sticky fast path: land a live stream back on the worker
        // holding its delta cache. Expired requests skip it (the cost
        // path below sheds and counts them); any miss falls through to
        // cost routing.
        if let Some(sc) = sx.sticky {
            if !req.expired(Instant::now()) {
                match sc.try_route(req, sx.classes) {
                    None => continue,
                    Some(back) => req = back,
                }
            }
        }
        let d = route(sx.classes, req.bucket, req.model);
        if let Some(dl) = req.deadline {
            let now = Instant::now();
            // Shed when the deadline has passed, or when even the *best*
            // class's predicted completion misses it. An unknown
            // completion (probe traffic, cold pool) is never shed
            // predictively — the probe's value is the cost observation
            // itself.
            let predicted_done = d.completion_s.is_finite().then(|| {
                // Clamp: any sane SLO is far under 1e6 s, and
                // `from_secs_f64` must not overflow on a pathological
                // EWMA.
                now + Duration::from_secs_f64(d.completion_s.clamp(0.0, 1e6))
            });
            if now >= dl || predicted_done.is_some_and(|t| t > dl) {
                sx.classes[d.class].deadline_drops.fetch_add(1, Ordering::Relaxed);
                sx.tenants[req.tenant].deadline_router.fetch_add(1, Ordering::Relaxed);
                sx.models[req.model].deadline_router.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        }
        let class = &sx.classes[d.class];
        req.predicted_s = d.predicted_s;
        class.backlog.fetch_add(1, Ordering::SeqCst);
        if class.queue.push(req).is_err() {
            break; // aborted downstream
        }
    }
    for c in sx.classes {
        c.queue.close();
    }
}
