//! Address-Event Representation (AER) primitives.
//!
//! Each event is `[x, y, p, t]` (paper §2.1): pixel coordinate, polarity of
//! the intensity change, and a microsecond timestamp.

/// One DVS event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Timestamp in microseconds from recording start.
    pub t_us: u32,
    pub x: u16,
    pub y: u16,
    /// `true` = ON (intensity increase), `false` = OFF.
    pub polarity: bool,
}

/// Borrowed view over a time-ordered event slice with window helpers.
///
/// Every helper assumes the slice is time-sorted ([`is_time_sorted`]) —
/// binary search over unsorted events silently returns wrong windows, not
/// an error. Sortedness is *enforced at the ingestion boundary*
/// ([`coordinator::ingest`](crate::coordinator::ingest)): file-backed
/// sources reject or stable-sort unsorted samples per their
/// `UnsortedPolicy` before events reach any consumer of this type.
pub struct EventSlice<'a>(pub &'a [Event]);

impl<'a> EventSlice<'a> {
    /// Events with `t ∈ [t0, t1)`, via binary search (slice must be
    /// time-sorted).
    pub fn window(&self, t0: u32, t1: u32) -> &'a [Event] {
        let lo = self.0.partition_point(|e| e.t_us < t0);
        let hi = self.0.partition_point(|e| e.t_us < t1);
        &self.0[lo..hi]
    }

    /// Recording duration: timestamp of the last event, or 0 for an empty
    /// stream (an empty recording has an empty profile, not a panic —
    /// event cameras emit nothing for a static scene).
    pub fn duration_us(&self) -> u32 {
        self.0.last().map_or(0, |e| e.t_us)
    }

    /// Split into fixed-interval windows covering the whole recording
    /// (paper §4.1: "clips event recordings with a fixed time interval").
    /// An empty stream or a zero interval yields no windows.
    pub fn fixed_windows(&self, interval_us: u32) -> Vec<&'a [Event]> {
        let mut out = Vec::new();
        if self.0.is_empty() || interval_us == 0 {
            return out;
        }
        let t_end = self.duration_us();
        let mut t0 = 0u32;
        loop {
            let (w, next) = match t0.checked_add(interval_us) {
                Some(t1) => (self.window(t0, t1), Some(t1)),
                None => {
                    // Window clipped at the u32 range: take everything from
                    // t0 through the end of the recording (inclusive, so a
                    // u32::MAX-timestamped event is not silently dropped).
                    let lo = self.0.partition_point(|e| e.t_us < t0);
                    (&self.0[lo..], None)
                }
            };
            if !w.is_empty() {
                out.push(w);
            }
            match next {
                Some(n) if n <= t_end => t0 = n,
                _ => break,
            }
        }
        out
    }
}

/// Time span covered by a window slice: `last.t - first.t`, or 0 for an
/// empty (or single-event) window.
pub fn span_us(events: &[Event]) -> u32 {
    match (events.first(), events.last()) {
        (Some(a), Some(b)) => b.t_us.saturating_sub(a.t_us),
        _ => 0,
    }
}

/// Check events are time-sorted (non-strict: DVS readout can emit several
/// events in the same microsecond).
pub fn is_time_sorted(events: &[Event]) -> bool {
    events.windows(2).all(|w| w[0].t_us <= w[1].t_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u32) -> Event {
        Event { t_us: t, x: 0, y: 0, polarity: true }
    }

    #[test]
    fn window_selects_half_open_range() {
        let es = vec![ev(0), ev(10), ev(20), ev(30)];
        let s = EventSlice(&es);
        let w = s.window(10, 30);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].t_us, 10);
        assert_eq!(w[1].t_us, 20);
    }

    #[test]
    fn fixed_windows_cover_all_events() {
        let es: Vec<Event> = (0..100).map(|i| ev(i * 7)).collect();
        let s = EventSlice(&es);
        let ws = s.fixed_windows(100);
        let total: usize = ws.iter().map(|w| w.len()).sum();
        assert_eq!(total, es.len());
        for w in &ws {
            assert!(!w.is_empty());
            assert!(span_us(w) < 100);
        }
    }

    /// Regression: an empty event stream has a 0-duration, zero-window
    /// profile — no panic anywhere on the windowing path.
    #[test]
    fn empty_stream_has_empty_profile() {
        let s = EventSlice(&[]);
        assert_eq!(s.duration_us(), 0);
        assert!(s.window(0, 1000).is_empty());
        assert!(s.fixed_windows(100).is_empty());
        assert_eq!(span_us(&[]), 0);
        assert!(is_time_sorted(&[]));
    }

    /// Degenerate inputs the old loop mishandled: a zero interval must not
    /// spin forever, and a max-timestamp event must not overflow.
    #[test]
    fn degenerate_windows_terminate() {
        let es = vec![ev(0), ev(50)];
        assert!(EventSlice(&es).fixed_windows(0).is_empty());
        let far = vec![ev(u32::MAX)];
        let ws = EventSlice(&far).fixed_windows(1 << 30);
        let total: usize = ws.iter().map(|w| w.len()).sum();
        assert_eq!(total, 1);
        assert_eq!(span_us(&far), 0);
    }

    #[test]
    fn sorted_check() {
        assert!(is_time_sorted(&[ev(1), ev(1), ev(2)]));
        assert!(!is_time_sorted(&[ev(2), ev(1)]));
    }
}
