//! Synthetic DVS scene models.
//!
//! A DVS pixel fires when the log-intensity crosses a threshold; in
//! practice events trace the *moving edges* of objects. We model scenes as
//! sets of line segments ("strokes") under rigid motion: at every
//! micro-step, each pixel newly covered by a stroke emits an ON event and
//! each pixel newly uncovered emits an OFF event (plus shot noise). This
//! reproduces the edge-locality and polarity structure of real recordings,
//! which is what determines the spatial sparsity the paper exploits.
//!
//! Classes differ by shape (stroke set) and motion (rotation/translation/
//! oscillation parameters), mimicking gesture/letter datasets.

use super::aer::Event;
use crate::util::Rng;

/// A stroke: line segment in object coordinates (pixels, origin at object
/// center).
#[derive(Clone, Copy, Debug)]
pub struct Stroke {
    pub x0: f64,
    pub y0: f64,
    pub x1: f64,
    pub y1: f64,
}

/// Rigid motion applied to the stroke set over time.
#[derive(Clone, Copy, Debug)]
pub enum Motion {
    /// Rotation about the object center: radians/second (signed).
    Rotate { omega: f64 },
    /// Linear oscillation along (dx, dy) with period `period_s`.
    Oscillate { dx: f64, dy: f64, period_s: f64 },
    /// Circular translation of the center: radius px, radians/second.
    Orbit { radius: f64, omega: f64 },
}

/// A class-defining scene: strokes + motion + center placement.
#[derive(Clone, Debug)]
pub struct Scene {
    pub strokes: Vec<Stroke>,
    pub motion: Motion,
    /// Object center as a fraction of the frame (0..1).
    pub cx_frac: f64,
    pub cy_frac: f64,
}

impl Scene {
    /// Pixels covered by the scene at time `t` (seconds), as a sorted,
    /// deduplicated list of raveled coordinates.
    fn cover(&self, t: f64, w: usize, h: usize, jx: f64, jy: f64) -> Vec<u32> {
        let (cx, cy) = (self.cx_frac * w as f64 + jx, self.cy_frac * h as f64 + jy);
        let (rot, tx, ty) = match self.motion {
            Motion::Rotate { omega } => (omega * t, 0.0, 0.0),
            Motion::Oscillate { dx, dy, period_s } => {
                let ph = (2.0 * std::f64::consts::PI * t / period_s).sin();
                (0.0, dx * ph, dy * ph)
            }
            Motion::Orbit { radius, omega } => {
                let a = omega * t;
                (0.0, radius * a.cos(), radius * a.sin())
            }
        };
        let (s, c) = rot.sin_cos();
        let mut pix: Vec<u32> = Vec::new();
        for st in &self.strokes {
            let p0 = (
                cx + tx + st.x0 * c - st.y0 * s,
                cy + ty + st.x0 * s + st.y0 * c,
            );
            let p1 = (
                cx + tx + st.x1 * c - st.y1 * s,
                cy + ty + st.x1 * s + st.y1 * c,
            );
            raster_line(p0, p1, w, h, &mut pix);
        }
        pix.sort_unstable();
        pix.dedup();
        pix
    }
}

/// Bresenham-style rasterization of a segment into raveled pixel indices
/// (integer DDA on the major axis; clips to the frame).
fn raster_line(p0: (f64, f64), p1: (f64, f64), w: usize, h: usize, out: &mut Vec<u32>) {
    let steps = (p1.0 - p0.0).abs().max((p1.1 - p0.1).abs()).ceil() as usize + 1;
    for i in 0..steps {
        let f = i as f64 / steps.max(1) as f64;
        let x = (p0.0 + (p1.0 - p0.0) * f).round() as isize;
        let y = (p0.1 + (p1.1 - p0.1) * f).round() as isize;
        if x >= 0 && y >= 0 && (x as usize) < w && (y as usize) < h {
            out.push((y as usize * w + x as usize) as u32);
        }
    }
}

/// Event-generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SynthParams {
    pub w: usize,
    pub h: usize,
    /// Recording length (µs).
    pub duration_us: u32,
    /// Scene sampling step (µs) — DVS-like high temporal resolution.
    pub step_us: u32,
    /// Probability an edge pixel that changed actually fires (sensor
    /// efficiency; controls event density).
    pub fire_p: f64,
    /// Background noise events per step (shot noise).
    pub noise_per_step: f64,
    /// Center-placement jitter amplitude in pixels.
    pub jitter_px: f64,
}

/// Generate one recording of `scene` under `params`. Events are
/// time-sorted. The per-sample RNG controls jitter, firing, and noise so
/// every sample of a class differs.
pub fn generate(scene: &Scene, params: &SynthParams, rng: &mut Rng) -> Vec<Event> {
    let (w, h) = (params.w, params.h);
    let jx = (rng.f64() * 2.0 - 1.0) * params.jitter_px;
    let jy = (rng.f64() * 2.0 - 1.0) * params.jitter_px;
    let mut events: Vec<Event> = Vec::new();
    let mut prev = scene.cover(0.0, w, h, jx, jy);
    let mut t = params.step_us;
    while t <= params.duration_us {
        let ts = t as f64 * 1e-6;
        let cur = scene.cover(ts, w, h, jx, jy);
        // Newly covered pixels -> ON; newly uncovered -> OFF (sorted-merge diff).
        let (mut i, mut j) = (0usize, 0usize);
        while i < cur.len() || j < prev.len() {
            let a = cur.get(i).copied();
            let b = prev.get(j).copied();
            match (a, b) {
                (Some(x), Some(y)) if x == y => {
                    i += 1;
                    j += 1;
                }
                (Some(x), None) | (Some(x), Some(_)) if b.map_or(true, |y| x < y) => {
                    if rng.chance(params.fire_p) {
                        events.push(Event {
                            t_us: t,
                            x: (x as usize % w) as u16,
                            y: (x as usize / w) as u16,
                            polarity: true,
                        });
                    }
                    i += 1;
                }
                (_, Some(y)) => {
                    if rng.chance(params.fire_p) {
                        events.push(Event {
                            t_us: t,
                            x: (y as usize % w) as u16,
                            y: (y as usize / w) as u16,
                            polarity: false,
                        });
                    }
                    j += 1;
                }
                (None, None) => break,
                // lint:allow(panic): arms above cover every (a, b) shape;
                // this placates exhaustiveness over the guard conditions
                _ => unreachable!(),
            }
        }
        // Shot noise.
        let n_noise = poisson_draw(rng, params.noise_per_step);
        for _ in 0..n_noise {
            events.push(Event {
                t_us: t,
                x: rng.index(w) as u16,
                y: rng.index(h) as u16,
                polarity: rng.chance(0.5),
            });
        }
        prev = cur;
        t = t.saturating_add(params.step_us);
    }
    events
}

/// Small-λ Poisson draw via inversion (λ < ~30 in all profiles).
fn poisson_draw(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l || k > 1000 {
            return k;
        }
        k += 1;
    }
}

/// Build the stroke set for a class id: deterministic, class-distinctive
/// shapes — `n_arms` radial arms plus a chord whose angle encodes the class,
/// under a class-dependent motion.
pub fn class_scene(class: usize, n_classes: usize, extent_px: f64) -> Scene {
    let golden = 0.6180339887498949;
    let frac = class as f64 / n_classes.max(1) as f64;
    let n_arms = 1 + class % 4;
    let base_angle = 2.0 * std::f64::consts::PI * ((class as f64 * golden) % 1.0);
    let mut strokes = Vec::new();
    for a in 0..n_arms {
        let ang = base_angle + a as f64 * 2.0 * std::f64::consts::PI / n_arms as f64;
        strokes.push(Stroke {
            x0: 0.0,
            y0: 0.0,
            x1: extent_px * ang.cos(),
            y1: extent_px * ang.sin(),
        });
    }
    // Class-encoding chord.
    let ca = base_angle + std::f64::consts::FRAC_PI_3;
    strokes.push(Stroke {
        x0: 0.5 * extent_px * ca.cos(),
        y0: 0.5 * extent_px * ca.sin(),
        x1: 0.5 * extent_px * (ca + 1.0).cos(),
        y1: 0.5 * extent_px * (ca + 1.0).sin(),
    });
    let motion = match class % 3 {
        0 => Motion::Rotate { omega: 4.0 + 6.0 * frac },
        1 => Motion::Oscillate {
            dx: extent_px * (0.5 + frac),
            dy: extent_px * 0.3,
            period_s: 0.15 + 0.1 * frac,
        },
        _ => Motion::Orbit { radius: extent_px * 0.5, omega: 6.0 + 4.0 * frac },
    };
    Scene {
        strokes,
        motion,
        cx_frac: 0.5,
        cy_frac: 0.5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::aer::is_time_sorted;

    fn params() -> SynthParams {
        SynthParams {
            w: 64,
            h: 64,
            duration_us: 50_000,
            step_us: 1_000,
            fire_p: 0.8,
            noise_per_step: 0.5,
            jitter_px: 2.0,
        }
    }

    #[test]
    fn generates_sorted_in_bounds_events() {
        let mut rng = Rng::new(1);
        let scene = class_scene(0, 10, 20.0);
        let es = generate(&scene, &params(), &mut rng);
        assert!(es.len() > 100, "got only {} events", es.len());
        assert!(is_time_sorted(&es));
        for e in &es {
            assert!((e.x as usize) < 64 && (e.y as usize) < 64);
        }
    }

    #[test]
    fn both_polarities_present() {
        let mut rng = Rng::new(2);
        let scene = class_scene(1, 10, 20.0);
        let es = generate(&scene, &params(), &mut rng);
        let on = es.iter().filter(|e| e.polarity).count();
        let off = es.len() - on;
        assert!(on > 10 && off > 10, "on {on} off {off}");
    }

    #[test]
    fn classes_produce_distinct_signatures() {
        let mut rng = Rng::new(3);
        let p = params();
        // Compare per-class active-pixel sets over the recording.
        let mut sigs: Vec<std::collections::BTreeSet<(u16, u16)>> = Vec::new();
        for c in 0..4 {
            let scene = class_scene(c, 10, 20.0);
            let es = generate(&scene, &p, &mut rng);
            sigs.push(es.iter().map(|e| (e.x, e.y)).collect());
        }
        for a in 0..4 {
            for b in (a + 1)..4 {
                let inter = sigs[a].intersection(&sigs[b]).count();
                let union = sigs[a].union(&sigs[b]).count();
                let iou = inter as f64 / union.max(1) as f64;
                assert!(iou < 0.9, "classes {a},{b} overlap too much: IoU {iou}");
            }
        }
    }

    #[test]
    fn samples_of_same_class_differ_but_overlap() {
        let p = params();
        let scene = class_scene(2, 10, 20.0);
        let mut r1 = Rng::new(10);
        let mut r2 = Rng::new(11);
        let e1 = generate(&scene, &p, &mut r1);
        let e2 = generate(&scene, &p, &mut r2);
        assert_ne!(e1, e2);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = params();
        let scene = class_scene(5, 10, 20.0);
        let a = generate(&scene, &p, &mut Rng::new(42));
        let b = generate(&scene, &p, &mut Rng::new(42));
        assert_eq!(a, b);
    }
}
