//! ESDA's composable dynamic sparse dataflow architecture as a cycle-level
//! model (the paper's §3, with the FPGA fabric replaced by a clocked
//! discrete simulator — see DESIGN.md §2 for why this preserves the
//! paper's claims).
//!
//! - [`stream`]: token-feature channels with ready/valid handshakes (Eqn. 1)
//! - [`module`]: the steppable-module abstraction
//! - [`conv1x1`]: pointwise conv module (§3.3.1)
//! - [`slb`]: sparse line buffers, stride 1 and 2 (§3.3.4–5, Eqns. 3–4)
//! - [`convkxk`]: k×k weighted-sum PE module with kernel-offset stream
//!   (§3.3.2–3)
//! - [`residual`]: fork / shortcut / merge chaining (§3.3.7)
//! - [`pool_fc`]: global pooling + classifier, stream endpoints (§3.3.6)
//! - [`builder`]: network spec → pipeline composition (Fig. 2)
//! - [`sim`]: the clocked scheduler, deadlock watchdog, reports
//! - [`dense`]: the dense sliding-window baseline of Fig. 13
//! - [`nullhop`]: a NullHop-style layer-sequential bitmap-skipping
//!   accelerator model (Table 1 comparator / ablation)
pub mod stream;
pub mod module;
pub mod conv1x1;
pub mod slb;
pub mod convkxk;
pub mod residual;
pub mod pool_fc;
pub mod builder;
pub mod sim;
pub mod dense;
pub mod nullhop;

pub use builder::{build_pipeline, simulate_inference, HwConfig};
pub use sim::{Pipeline, SimError, SimReport};
