//! Cross-language integration tests: the python-trained artifacts must
//! agree with the rust functional oracle AND with the PJRT-executed AOT
//! artifact — the three-way correctness spine of DESIGN.md §6.
//!
//! These tests skip (pass trivially with a notice) until `make artifacts`
//! has produced `artifacts/compact_n_mnist.*`.

use esda::model::exec::{argmax, forward_f32, forward_i8};
use esda::model::quant::quantize_network;
use esda::model::weights::{load_float_weights, read_tensors, Tensor};
use esda::model::NetworkSpec;
use esda::runtime::{artifact_available, artifacts_dir, Engine};
use esda::sparse::SparseMap;

const STEM: &str = "compact_n_mnist";

type Golden =
    (NetworkSpec, esda::model::weights::FloatWeights, Vec<SparseMap<f32>>, Vec<Vec<f32>>);

fn load_golden() -> Option<Golden> {
    if !artifact_available(STEM) {
        eprintln!("skipping: run `make artifacts` to build artifacts/{STEM}.*");
        return None;
    }
    let dir = artifacts_dir();
    let meta_src = std::fs::read_to_string(dir.join(format!("{STEM}.meta.json"))).unwrap();
    let meta = esda::util::json::parse(&meta_src).unwrap();
    let (w, h) = (
        meta.get("w").unwrap().as_usize().unwrap(),
        meta.get("h").unwrap().as_usize().unwrap(),
    );
    let n_classes = meta.get("n_classes").unwrap().as_usize().unwrap();
    let spec = NetworkSpec::compact("compact", w, h, n_classes);
    let weights_path = dir.join(format!("{STEM}_weights.esdw"));
    let fw = load_float_weights(&weights_path, &spec).expect("python-exported weights must align");
    let tensors = read_tensors(&weights_path).unwrap();
    let (inputs, logits) = match (&tensors["golden.inputs"], &tensors["golden.logits"]) {
        (Tensor::F32 { dims: di, data: xi }, Tensor::F32 { dims: dl, data: xl }) => {
            let n = di[0];
            assert_eq!(dl[0], n);
            let (hh, ww, c) = (di[1], di[2], di[3]);
            assert_eq!((hh, ww, c), (h, w, 2));
            let per = hh * ww * c;
            let inputs: Vec<SparseMap<f32>> = (0..n)
                .map(|i| SparseMap::from_dense(&xi[i * per..(i + 1) * per], ww, hh, c))
                .collect();
            let logits: Vec<Vec<f32>> = (0..n)
                .map(|i| xl[i * n_classes..(i + 1) * n_classes].to_vec())
                .collect();
            (inputs, logits)
        }
        _ => panic!("golden tensors must be f32"),
    };
    Some((spec, fw, inputs, logits))
}

fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
    let scale = a.iter().fold(1f32, |m, &v| m.max(v.abs()));
    a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * scale)
}

/// Rust functional f32 forward == python/JAX golden logits.
#[test]
fn rust_oracle_matches_python_golden() {
    let Some((spec, fw, inputs, golden)) = load_golden() else { return };
    for (input, want) in inputs.iter().zip(&golden) {
        let got = forward_f32(&spec, &fw, input);
        assert!(
            close(&got, want, 5e-3),
            "rust {got:?}\npython {want:?}"
        );
    }
}

/// PJRT-executed AOT artifact (Pallas kernels inside) == golden logits.
#[test]
fn pjrt_engine_matches_python_golden() {
    if !esda::runtime::pjrt_enabled() {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    let Some((_spec, _fw, inputs, golden)) = load_golden() else { return };
    let engine = Engine::load(&artifacts_dir().join(format!("{STEM}.hlo.txt"))).unwrap();
    for (input, want) in inputs.iter().zip(&golden) {
        let got = engine.infer_sparse(input).unwrap();
        assert!(
            close(&got, want, 1e-4),
            "pjrt {got:?}\npython {want:?}"
        );
    }
}

/// The int8 hardware path classifies the golden samples like the f32 path
/// (trained weights ⇒ argmax is stable under quantization).
#[test]
fn quantized_path_agrees_on_golden_argmax() {
    let Some((spec, fw, inputs, golden)) = load_golden() else { return };
    let qnet = quantize_network(&spec, &fw, &inputs);
    let mut agree = 0;
    for (input, want) in inputs.iter().zip(&golden) {
        let li = forward_i8(&qnet, input);
        if argmax(&li) == argmax(want) {
            agree += 1;
        }
    }
    assert!(
        agree >= inputs.len().saturating_sub(1),
        "int8 argmax agreement {agree}/{}",
        inputs.len()
    );
}

/// Cycle-level simulator on the trained network == functional int8, and
/// latency is in a plausible hardware range.
#[test]
fn simulator_matches_functional_on_trained_net() {
    let Some((spec, fw, inputs, _)) = load_golden() else { return };
    let qnet = quantize_network(&spec, &fw, &inputs);
    let stats = {
        let bitmaps: Vec<_> = inputs.iter().map(|m| m.bitmap()).collect();
        esda::hwopt::collect_stats(&spec, &bitmaps)
    };
    let alloc = esda::hwopt::allocate(&spec, &stats, &esda::hwopt::Budget::zcu102())
        .expect("compact must fit ZCU102");
    let cfg = esda::arch::HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
    let input = &inputs[0];
    let want = forward_i8(&qnet, input);
    let (got, report) = esda::arch::simulate_inference(&qnet, &cfg, input, 5_000_000_000).unwrap();
    assert_eq!(got, want);
    // Eqn.5 predicted bottleneck and simulated cycles agree within 3×
    // (the model is an average over the dataset; the sample varies).
    let ratio = report.cycles as f64 / alloc.latency.max(1.0);
    assert!(
        (0.2..5.0).contains(&ratio),
        "sim {} vs model {} (ratio {ratio})",
        report.cycles,
        alloc.latency
    );
}
