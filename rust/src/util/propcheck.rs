//! Minimal property-based testing harness (proptest is not vendored).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! harness runs it for `cases` seeds; on failure it retries the failing seed
//! with progressively "smaller" size hints to produce a reduced
//! counterexample, then panics with the seed so the case is replayable.
//!
//! ```no_run
//! // (no_run: doctest binaries don't receive the workspace rpath flags in
//! // this offline environment; the same property runs in the unit tests.)
//! use esda::util::propcheck::{check, Gen};
//! check("reverse twice is identity", 256, |g: &mut Gen| {
//!     let xs: Vec<u8> = g.vec(0..=255u64, 0, 64).iter().map(|&x| x as u8).collect();
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use super::rng::Rng;
use std::ops::RangeInclusive;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Seeded value source handed to properties. `size` scales collection
/// lengths so the shrink pass can retry a failing seed with smaller data.
pub struct Gen {
    rng: Rng,
    /// Collection size multiplier in (0, 1]; 1.0 for the primary pass.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size, seed }
    }

    /// Uniform u64 in an inclusive range.
    pub fn u64(&mut self, r: RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform i64 in an inclusive range.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Collection length between `lo..=hi`, scaled by the shrink size.
    pub fn len(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.size).round() as usize;
        self.usize(lo, hi_scaled.max(lo))
    }

    /// Vec of u64 draws.
    pub fn vec(&mut self, r: RangeInclusive<u64>, lo: usize, hi: usize) -> Vec<u64> {
        let n = self.len(lo, hi);
        (0..n).map(|_| self.u64(r.clone())).collect()
    }

    /// Pick one of the provided items.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }

    /// Access the raw RNG (e.g. for domain generators that take `&mut Rng`).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. Panics (failing the enclosing
/// `#[test]`) with a replayable seed on the first failing case, after
/// attempting a size-reduction pass.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Deterministic base seed derived from the property name: stable across
    // runs, different across properties.
    let mut h = 0xcbf29ce484222325u64; // FNV-1a
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let seed = h ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = run_silent(&prop, seed, 1.0);
        if let Err(msg) = result {
            // Shrink: same seed, smaller collection sizes.
            let mut best: Option<(f64, String)> = None;
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                if let Err(m) = run_silent(&prop, seed, size) {
                    best = Some((size, m));
                }
            }
            let (size, detail) = best.unwrap_or((1.0, msg));
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {size}):\n{detail}\n\
                 replay: Gen::new({seed:#x}, {size})"
            );
        }
    }
}

fn run_silent<F>(prop: &F, seed: u64, size: f64) -> Result<(), String>
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // silence expected panics
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let mut g = Gen::new(seed, size);
        prop(&mut g);
    }));
    std::panic::set_hook(prev_hook);
    match outcome {
        Ok(()) => Ok(()),
        Err(e) => Err(super::panic_message(e.as_ref())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 64, |g| {
            let a = g.i64(-1000, 1000);
            let b = g.i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = catch_unwind(|| {
            check("always fails on large vec", 64, |g| {
                let xs = g.vec(0..=9, 0, 32);
                assert!(xs.len() < 5, "vec too long: {}", xs.len());
            });
        });
        let msg = match r {
            Err(e) => crate::util::panic_message(e.as_ref()),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed"), "message was: {msg}");
        assert!(msg.contains("replay"), "message was: {msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(3, 1.0);
        for _ in 0..200 {
            let v = g.u64(10..=20);
            assert!((10..=20).contains(&v));
            let w = g.i64(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }
}
