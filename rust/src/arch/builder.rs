//! Compose a network's op program into a hardware pipeline — the paper's
//! "straightforward cascading of dataflow modules corresponding with the
//! model definition" (§3.1, Fig. 2/10).

use super::conv1x1::Conv1x1Mod;
use super::convkxk::{KxkComputeMod, PeKind};
use super::module::Module;
use super::pool_fc::{PoolFcMod, SinkMod, SourceMod};
use super::residual::{AddMod, ForkMod};
use super::sim::Pipeline;
use super::slb::{SlbS1, SlbS2};
use super::stream::Fabric;
use crate::model::graph::Op;
use crate::model::quant::QuantizedNet;
use crate::sparse::SparseMap;

/// Hardware configuration for one accelerator instance.
#[derive(Clone, Debug)]
pub struct HwConfig {
    /// Parallel factor per op index (Eqn. 5's PF; entries for weightless
    /// ops are ignored).
    pub pf: Vec<usize>,
    /// Default inter-module FIFO depth.
    pub fifo_depth: usize,
}

impl HwConfig {
    /// Uniform PF for every op.
    pub fn uniform(n_ops: usize, pf: usize) -> HwConfig {
        HwConfig { pf: vec![pf; n_ops], fifo_depth: 8 }
    }
}

/// Build a full-network pipeline for one quantized input sample.
pub fn build_pipeline(qnet: &QuantizedNet, cfg: &HwConfig, input: &SparseMap<i8>) -> Pipeline {
    let spec = &qnet.spec;
    let ops = spec.ops();
    let res = spec.op_resolutions();
    assert_eq!(cfg.pf.len(), ops.len(), "PF config must cover every op");
    let mut fab = Fabric::default();
    let mut modules: Vec<Box<dyn Module + Send>> = Vec::new();

    let src_ch = fab.add_chan(cfg.fifo_depth);
    modules.push(Box::new(SourceMod::new("source", src_ch, input)));
    let mut cur_ch = src_ch;
    // Stack of shortcut channels for fork/add pairs.
    let mut shortcut: Vec<usize> = Vec::new();
    let mut pool_seen = false;

    for (i, op) in ops.iter().enumerate() {
        let (w, h) = res[i];
        match *op {
            Op::Conv1x1 { cin, cout, .. } => {
                let q = qnet.per_op[i].as_ref().unwrap();
                let out_ch = fab.add_chan(cfg.fifo_depth);
                modules.push(Box::new(Conv1x1Mod::new(
                    format!("op{i}_conv1x1_{cin}x{cout}"),
                    cur_ch,
                    out_ch,
                    cin,
                    cout,
                    cfg.pf[i],
                    q.w.clone(),
                    q.b.clone(),
                    q.rq,
                )));
                cur_ch = out_ch;
            }
            Op::ConvKxK { k, stride, .. } | Op::DwConv { k, stride, .. } => {
                // SLB + k×k compute module pair.
                let q = qnet.per_op[i].as_ref().unwrap();
                let win_ch = fab.add_chan(cfg.fifo_depth);
                let out_ch = fab.add_chan(cfg.fifo_depth);
                if stride == 1 {
                    modules.push(Box::new(SlbS1::new(
                        format!("op{i}_slb_s1"),
                        cur_ch,
                        win_ch,
                        k,
                        w,
                        h,
                    )));
                } else {
                    modules.push(Box::new(SlbS2::new(
                        format!("op{i}_slb_s2"),
                        cur_ch,
                        win_ch,
                        k,
                        w,
                        h,
                    )));
                }
                let (kind, label) = match *op {
                    Op::DwConv { c, .. } => {
                        (PeKind::Depthwise { c }, format!("op{i}_dwconv{k}x{k}_s{stride}"))
                    }
                    Op::ConvKxK { cin, cout, .. } => {
                        (PeKind::Full { cin, cout }, format!("op{i}_conv{k}x{k}_s{stride}"))
                    }
                    _ => unreachable!(),
                };
                modules.push(Box::new(KxkComputeMod::new(
                    label,
                    win_ch,
                    out_ch,
                    k,
                    kind,
                    cfg.pf[i],
                    q.w.clone(),
                    q.b.clone(),
                    q.rq,
                )));
                cur_ch = out_ch;
            }
            Op::ResFork => {
                let main_ch = fab.add_chan(cfg.fifo_depth);
                // Shortcut FIFO must absorb every token buffered inside the
                // branch (SLB holds up to k rows): size generously.
                let depth = 4 * 3 * w + 64;
                let sc_ch = fab.add_chan(depth);
                modules.push(Box::new(ForkMod::new(format!("op{i}_fork"), cur_ch, main_ch, sc_ch)));
                shortcut.push(sc_ch);
                cur_ch = main_ch;
            }
            Op::ResAdd => {
                let sc_ch = shortcut.pop().expect("ResAdd without ResFork");
                let out_ch = fab.add_chan(cfg.fifo_depth);
                modules.push(Box::new(AddMod::new(format!("op{i}_add"), cur_ch, sc_ch, out_ch)));
                cur_ch = out_ch;
            }
            Op::GlobalPool { .. } => {
                pool_seen = true; // merged into the Fc op below (Fig. 9)
            }
            Op::Fc { cin, cout } => {
                assert!(pool_seen, "Fc without preceding GlobalPool");
                let q = qnet.per_op[i].as_ref().unwrap();
                let out_ch = fab.add_chan(2);
                modules.push(Box::new(PoolFcMod::new(
                    format!("op{i}_poolfc"),
                    cur_ch,
                    out_ch,
                    cin,
                    cout,
                    cfg.pf[i],
                    q.w.clone(),
                    q.b.clone(),
                )));
                cur_ch = out_ch;
            }
        }
    }
    let (ow, oh) = *res.last().unwrap();
    modules.push(Box::new(SinkMod::new("sink", cur_ch, ow, oh, 1)));
    Pipeline { fabric: fab, modules }
}

/// Convenience: simulate one inference; returns (logits, report).
pub fn simulate_inference(
    qnet: &QuantizedNet,
    cfg: &HwConfig,
    input_f32: &SparseMap<f32>,
    max_cycles: u64,
) -> Result<(Vec<i32>, super::sim::SimReport), super::sim::SimError> {
    let qin = crate::model::exec::quantize_input(qnet, input_f32);
    let mut pipe = build_pipeline(qnet, cfg, &qin);
    let report = pipe.run(max_cycles)?;
    // The sink is always the last module the builder appends.
    let sink = pipe.modules.last().unwrap();
    let sink = sink
        .as_any()
        .downcast_ref::<SinkMod>()
        .expect("last module must be the sink");
    let logits = sink.logits.clone().expect("pipeline finished without logits");
    Ok((logits, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::exec::forward_i8;
    use crate::model::quant::quantize_network;
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;
    use crate::util::Rng;

    fn input_for(p: &DatasetProfile, seed: u64) -> SparseMap<f32> {
        let mut rng = Rng::new(seed);
        let es = p.sample(seed as usize % p.n_classes, &mut rng);
        histogram2_norm(&es, p.w, p.h, 8.0)
    }

    /// The headline correctness result: the cycle-level pipeline produces
    /// bit-identical logits to the functional int8 reference, end to end.
    #[test]
    fn full_pipeline_matches_functional_i8() {
        let p = DatasetProfile::n_mnist();
        let spec = NetworkSpec::tiny(p.w, p.h, p.n_classes);
        let w = FloatWeights::random(&spec, 11);
        let calib: Vec<SparseMap<f32>> = (0..3).map(|s| input_for(&p, s)).collect();
        let qnet = quantize_network(&spec, &w, &calib);
        let cfg = HwConfig::uniform(spec.ops().len(), 8);
        for seed in 20..24u64 {
            let input = input_for(&p, seed);
            let want = forward_i8(&qnet, &input);
            let (got, report) = simulate_inference(&qnet, &cfg, &input, 50_000_000).unwrap();
            assert_eq!(got, want, "seed {seed}");
            assert!(report.cycles > 0);
        }
    }

    #[test]
    fn compact_net_simulates_and_matches() {
        let p = DatasetProfile::roshambo17();
        let spec = NetworkSpec::compact("compact", p.w, p.h, p.n_classes);
        let w = FloatWeights::random(&spec, 13);
        let calib: Vec<SparseMap<f32>> = (0..2).map(|s| input_for(&p, s)).collect();
        let qnet = quantize_network(&spec, &w, &calib);
        let cfg = HwConfig::uniform(spec.ops().len(), 16);
        let input = input_for(&p, 31);
        let want = forward_i8(&qnet, &input);
        let (got, report) = simulate_inference(&qnet, &cfg, &input, 200_000_000).unwrap();
        assert_eq!(got, want);
        // Pipeline parallelism sanity: busy-cycle max should be well below
        // total cycles × module count.
        let bn = report.bottleneck().unwrap();
        assert!(bn.1.busy <= report.cycles);
    }

    #[test]
    fn higher_pf_is_faster() {
        let p = DatasetProfile::n_mnist();
        let spec = NetworkSpec::tiny(p.w, p.h, p.n_classes);
        let w = FloatWeights::random(&spec, 17);
        let calib: Vec<SparseMap<f32>> = vec![input_for(&p, 1)];
        let qnet = quantize_network(&spec, &w, &calib);
        let input = input_for(&p, 40);
        let slow_cfg = HwConfig::uniform(spec.ops().len(), 1);
        let fast_cfg = HwConfig::uniform(spec.ops().len(), 16);
        let (_, slow) = simulate_inference(&qnet, &slow_cfg, &input, 500_000_000).unwrap();
        let (_, fast) = simulate_inference(&qnet, &fast_cfg, &input, 500_000_000).unwrap();
        assert!(
            slow.cycles > fast.cycles * 2,
            "PF1 {} vs PF16 {}",
            slow.cycles,
            fast.cycles
        );
    }
}
