// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Incremental (delta) inference + sticky routing demo: two event
//! streams share a serving pool — a near-static camera whose successive
//! windows overlap ~95% (a fixed background plus a small drifting
//! object), and a scene-cut stream whose windows share nothing. The
//! delta-capable class diffs each window against the stream's cached
//! previous one and recomputes only changed sites; the sticky router
//! pins each stream to the replica holding its cache. The overlapping
//! stream delta-hits, the scene-cut stream falls back over-threshold,
//! and a control run with delta disabled proves the machinery changes
//! **throughput accounting only**: predictions are bit-equal.
//!
//! With `--report-out path` a machine-readable JSON summary is written —
//! CI greps it for `null` to catch NaN/inf leaking into reports.
//!
//! Run: `cargo run --release --example delta_serving`
//! (add `--smoke` for the quick CI-sized run)

use esda::coordinator::{
    run_pool_source, AutoscaleConfig, Backend, BackendError, Classification, DeltaStatus,
    DeltaStore, DropPolicy, EventSource, Functional, IngestError, ReplicaPool, ReplicaSpec,
    ServerConfig, ServerResult, SourcedRequest, DEFAULT_TENANT,
};
use esda::events::{repr::histogram2_norm, DatasetProfile, Event};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::json::Json;
use esda::util::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Paced replica with full delta delegation: ~1 ms per request keeps a
/// backlog alive long enough for stream affinity to engage mid-run.
struct Paced {
    inner: Functional,
    delay: Duration,
}

impl Backend for Paced {
    fn name(&self) -> &str {
        "paced"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
    fn supports_delta(&self) -> bool {
        self.inner.supports_delta()
    }
    fn classify_batch_delta(
        &self,
        streams: &[Option<u64>],
        maps: &[SparseMap<f32>],
    ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
        std::thread::sleep(self.delay * maps.len() as u32);
        self.inner.classify_batch_delta(streams, maps)
    }
    fn evict_stream(&self, stream: u64) {
        self.inner.evict_stream(stream);
    }
}

const PATCH: usize = 6;

/// Two interleaved streams. Stream 1 ("camera"): a fixed background of
/// events plus a small patch of fresh events that drifts a few pixels
/// per window — consecutive windows overlap ~95%. Stream 2 ("cuts"):
/// every window is a fresh full-frame scatter. Labels are the request
/// ordinal, so multiset prediction equality between two runs implies
/// per-request bit-equality.
struct TwoStreamSource {
    w: usize,
    h: usize,
    n_total: usize,
    emitted: usize,
    bg: Vec<Event>,
    rng: Rng,
}

impl TwoStreamSource {
    fn new(w: usize, h: usize, n_total: usize) -> TwoStreamSource {
        let mut rng = Rng::new(4242);
        let bg = (0..600)
            .map(|j| Event {
                t_us: j as u32,
                x: rng.below(w as u64) as u16,
                y: rng.below(h as u64) as u16,
                polarity: rng.chance(0.5),
            })
            .collect();
        TwoStreamSource { w, h, n_total, emitted: 0, bg, rng }
    }
}

impl EventSource for TwoStreamSource {
    fn name(&self) -> &str {
        "two-stream"
    }
    fn geometry(&self) -> (usize, usize) {
        (self.w, self.h)
    }
    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        if self.emitted >= self.n_total {
            return Ok(None);
        }
        let i = self.emitted;
        self.emitted += 1;
        let (events, stream) = if i % 2 == 0 {
            // Camera: background + a patch drifting with the window index.
            let k = i / 2;
            let (px, py) = ((5 * k) % (self.w - PATCH), (7 * k) % (self.h - PATCH));
            let mut es = self.bg.clone();
            for j in 0..30 {
                es.push(Event {
                    t_us: (600 + j) as u32,
                    x: (px + self.rng.index(PATCH)) as u16,
                    y: (py + self.rng.index(PATCH)) as u16,
                    polarity: self.rng.chance(0.5),
                });
            }
            (es, 1)
        } else {
            // Scene cuts: a fresh scatter, nothing shared between windows.
            let es = (0..300)
                .map(|j| Event {
                    t_us: j as u32,
                    x: self.rng.below(self.w as u64) as u16,
                    y: self.rng.below(self.h as u64) as u16,
                    polarity: self.rng.chance(0.5),
                })
                .collect();
            (es, 2)
        };
        Ok(Some(SourcedRequest {
            label: i,
            events,
            arrival: Instant::now(),
            tenant: DEFAULT_TENANT,
            model: 0,
            stream: Some(stream),
        }))
    }
}

fn prediction_multiset(r: &ServerResult) -> Vec<(usize, usize)> {
    let mut v: Vec<(usize, usize)> = r.predictions.iter().map(|p| (p.label, p.pred)).collect();
    v.sort_unstable();
    v
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["smoke"]).unwrap();
    let smoke = args.has("smoke");
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);

    // Two classes (the sticky router only exists when there is a routing
    // decision to make): "delta" runs incremental execution against a
    // cache store shared across its replicas, "plain" recomputes every
    // window. Same weights, so class placement cannot change predictions.
    let mk_pool = |delta: bool| {
        let (qa, qb) = (qnet.clone(), qnet.clone());
        let store: DeltaStore = Arc::new(Mutex::new(HashMap::new()));
        ReplicaPool::build(vec![
            ReplicaSpec::new("delta", 1, 2, move |_| {
                let inner = if delta {
                    Functional::new(qa.clone()).with_delta_store(0.35, Arc::clone(&store))
                } else {
                    Functional::new(qa.clone())
                };
                Ok(Box::new(Paced { inner, delay: Duration::from_millis(1) }))
            })
            .with_max_replicas(2),
            ReplicaSpec::new("plain", 1, 2, move |_| {
                Ok(Box::new(Paced {
                    inner: Functional::new(qb.clone()),
                    delay: Duration::from_millis(1),
                }))
            }),
        ])
        .expect("pool build")
    };
    let n_offered = if smoke { 40 } else { 160 };
    let cfg = ServerConfig {
        queue_depth: 8,
        drop_policy: DropPolicy::Block,
        batch: 2,
        autoscale: Some(AutoscaleConfig {
            interval: Duration::from_millis(5),
            window: Duration::from_millis(50),
            high_backlog: 2.0,
            low_util: 0.3,
        }),
        ..Default::default()
    };
    let source = |n| Box::new(TwoStreamSource::new(profile.w, profile.h, n));

    let with_delta =
        run_pool_source(source(n_offered), &mk_pool(true), &cfg).expect("delta run");
    let control =
        run_pool_source(source(n_offered), &mk_pool(false), &cfg).expect("control run");

    let m = &with_delta.metrics;
    let d = &m.delta;
    println!("== two streams into delta+plain classes ({n_offered} requests) ==");
    println!(
        "  {} served / {} offered | {} queue drop(s) | {} scaling event(s)",
        m.total,
        n_offered,
        m.dropped,
        m.scaling_events.len(),
    );
    if let Some(line) = esda::report::delta_line(m) {
        println!("  {line}");
    }
    println!("{}", esda::report::pool_table(m).render());

    // The demo is also an acceptance check: lossless conservation, live
    // delta + sticky books, and bit-equal predictions vs. the control.
    let conservation_ok = m.total + m.dropped + m.deadline_drops() == n_offered;
    assert!(conservation_ok, "conservation must hold under sticky routing");
    assert_eq!(m.total, n_offered, "blocking admission is lossless");
    assert!(d.attempts() > 0, "the delta class must see stream-tagged requests");
    assert!(d.hits >= 1, "the overlapping stream must delta-hit on its cached window");
    assert_eq!(
        d.attempts() + d.not_applicable,
        m.total,
        "delta statuses must partition the served stream"
    );
    let sticky_total = d.sticky_hits + d.sticky_cold + d.sticky_retired + d.sticky_capacity;
    assert!(sticky_total > 0, "the sticky router must have made placement decisions");
    let bit_equal = prediction_multiset(&with_delta) == prediction_multiset(&control);
    assert!(bit_equal, "delta execution changed predictions");
    println!(
        "control (delta off): bit-equal predictions over {} request(s) — ok",
        control.metrics.total
    );

    // Machine-readable summary (CI greps this for `null`).
    if let Some(out) = args.get("report-out") {
        let doc = Json::obj(vec![
            ("offered", Json::Num(n_offered as f64)),
            ("served", Json::Num(m.total as f64)),
            ("queue_drops", Json::Num(m.dropped as f64)),
            ("deadline_drops", Json::Num(m.deadline_drops() as f64)),
            ("conservation_ok", Json::Bool(conservation_ok)),
            ("delta_hits", Json::Num(d.hits as f64)),
            ("delta_full_cold", Json::Num(d.full_cold as f64)),
            ("delta_full_geometry", Json::Num(d.full_geometry as f64)),
            ("delta_full_over_threshold", Json::Num(d.full_over_threshold as f64)),
            ("delta_attempts", Json::Num(d.attempts() as f64)),
            ("delta_hit_rate", Json::Num(d.hit_rate())),
            ("sticky_hits", Json::Num(d.sticky_hits as f64)),
            ("sticky_cold", Json::Num(d.sticky_cold as f64)),
            ("sticky_retired", Json::Num(d.sticky_retired as f64)),
            ("sticky_capacity", Json::Num(d.sticky_capacity as f64)),
            ("bit_equal_vs_control", Json::Bool(bit_equal)),
        ]);
        std::fs::write(out, doc.to_string()).expect("write report");
        println!("report written -> {out}");
    }
}
