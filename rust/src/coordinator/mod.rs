//! L3 serving coordinator: a threaded event-vision pipeline that composes
//! the substrates into the deployable system of Fig. 2 —
//!
//! ```text
//! event source → representation builder → accelerator → classifications
//!   (camera/        (histogram2, on the     (cycle-sim or
//!    synthetic)      "PS" thread)            PJRT engine)
//! ```
//!
//! Stages run on std threads connected by bounded channels (backpressure),
//! since the offline build vendors no async runtime. Throughput/latency
//! metrics are collected per stage.
pub mod pipeline;
pub mod metrics;

pub use pipeline::{run_pipeline, Backend, PipelineConfig, PipelineResult};
