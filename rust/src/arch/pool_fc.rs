//! Global pooling + fully-connected classifier module — paper §3.3.6,
//! Fig. 9, plus stream endpoints (source, sink).

use super::module::{pe_cycles, Countdown, Module};
use super::stream::{ChanId, Fabric, Item, ModStats};
use crate::sparse::{SparseMap, Token};

/// Global average pool over tokens, then linear classifier; emits
/// [`Item::Logits`] when the `.end` flag arrives.
pub struct PoolFcMod {
    name: String,
    in_ch: ChanId,
    out_ch: ChanId,
    c: usize,
    n_classes: usize,
    pf: usize,
    wfc: Vec<i8>,
    bfc: Vec<i32>,
    acc: Vec<i64>,
    count: u64,
    cd: Countdown,
    pending: Option<Item>,
    stats: ModStats,
    done: bool,
}

impl PoolFcMod {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        in_ch: ChanId,
        out_ch: ChanId,
        c: usize,
        n_classes: usize,
        pf: usize,
        wfc: Vec<i8>,
        bfc: Vec<i32>,
    ) -> Self {
        assert_eq!(wfc.len(), c * n_classes);
        assert_eq!(bfc.len(), n_classes);
        PoolFcMod {
            name: name.into(),
            in_ch,
            out_ch,
            c,
            n_classes,
            pf: pf.max(1),
            wfc,
            bfc,
            acc: vec![0; c],
            count: 0,
            cd: Countdown::default(),
            pending: None,
            stats: ModStats::default(),
            done: false,
        }
    }

    fn finalize(&self) -> Vec<i32> {
        // Integer average with round-half-up (matches
        // `sparse::conv::global_avg_pool_i8`), then int8-weight classifier.
        let n = self.count.max(1) as i64;
        let pooled: Vec<i32> = self
            .acc
            .iter()
            .map(|&s| {
                let half = if s >= 0 { n / 2 } else { -(n / 2) };
                ((s + half) / n) as i32
            })
            .collect();
        (0..self.n_classes)
            .map(|co| {
                let mut a = self.bfc[co];
                for ci in 0..self.c {
                    a += pooled[ci] * self.wfc[ci * self.n_classes + co] as i32;
                }
                a
            })
            .collect()
    }
}

impl Module for PoolFcMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        if let Some(item) = self.pending.take() {
            if fab.can_push(self.out_ch) {
                fab.chan(self.out_ch).push(item);
                self.stats.produced += 1;
                self.done = true;
            } else {
                self.pending = Some(item);
                self.stats.stall_out += 1;
            }
            return;
        }
        if self.cd.busy() {
            self.stats.busy += 1;
            if self.cd.tick() {
                self.pending = Some(Item::Logits(self.finalize()));
            }
            return;
        }
        if self.done {
            return;
        }
        match fab.chan(self.in_ch).pop() {
            Some(Item::Feat { f, .. }) => {
                self.stats.consumed += 1;
                self.stats.busy += 1;
                for (a, &v) in self.acc.iter_mut().zip(&f) {
                    *a += v as i64;
                }
                self.count += 1;
            }
            Some(Item::End) => {
                self.stats.consumed += 1;
                // Division (~C cycles serial) + classifier matvec.
                let cycles = self.c as u64 + pe_cycles(self.c * self.n_classes, self.pf);
                self.cd.start(cycles.max(1));
            }
            Some(other) => panic!("{}: unexpected {other:?}", self.name),
            None => self.stats.stall_in += 1,
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn next_event(&self) -> Option<u64> {
        if self.pending.is_some() {
            // Will attempt the push on the very next step — blocks skipping.
            Some(1)
        } else if self.cd.busy() {
            Some(self.cd.0)
        } else {
            None
        }
    }

    fn fast_forward(&mut self, k: u64) {
        debug_assert!(self.cd.0 > k);
        self.cd.0 -= k;
        self.stats.busy += k;
    }

    fn dsp(&self) -> usize {
        self.pf
    }
}

/// Stream source: feeds a quantized sparse map at one beat per cycle (the
/// PS→PL input DMA of Fig. 2), then the end flag.
pub struct SourceMod {
    name: String,
    out_ch: ChanId,
    items: std::vec::IntoIter<(Token, Vec<i8>)>,
    sent_end: bool,
    stats: ModStats,
}

impl SourceMod {
    pub fn new(name: impl Into<String>, out_ch: ChanId, input: &SparseMap<i8>) -> Self {
        let items: Vec<(Token, Vec<i8>)> = input
            .tokens
            .iter()
            .enumerate()
            .map(|(i, t)| (*t, input.feat(i).to_vec()))
            .collect();
        SourceMod {
            name: name.into(),
            out_ch,
            items: items.into_iter(),
            sent_end: false,
            stats: ModStats::default(),
        }
    }
}

impl Module for SourceMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        if self.sent_end {
            return;
        }
        if !fab.can_push(self.out_ch) {
            self.stats.stall_out += 1;
            return;
        }
        match self.items.next() {
            Some((t, f)) => {
                fab.chan(self.out_ch).push(Item::Feat { t, f });
                self.stats.produced += 1;
                self.stats.busy += 1;
            }
            None => {
                fab.chan(self.out_ch).push(Item::End);
                self.sent_end = true;
                self.stats.produced += 1;
            }
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.sent_end
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Stream sink: collects the pipeline output — either classifier logits or
/// a token-feature stream (single-block simulations).
pub struct SinkMod {
    name: String,
    in_ch: ChanId,
    pub logits: Option<Vec<i32>>,
    pub map: SparseMap<i8>,
    stats: ModStats,
    done: bool,
}

impl SinkMod {
    pub fn new(name: impl Into<String>, in_ch: ChanId, w: usize, h: usize, c: usize) -> Self {
        SinkMod {
            name: name.into(),
            in_ch,
            logits: None,
            map: SparseMap::empty(w, h, c),
            stats: ModStats::default(),
            done: false,
        }
    }
}

impl Module for SinkMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        match fab.chan(self.in_ch).pop() {
            Some(Item::Feat { t, f }) => {
                self.stats.consumed += 1;
                self.map.push(t, &f);
            }
            Some(Item::Logits(l)) => {
                self.stats.consumed += 1;
                self.logits = Some(l);
                self.done = true;
            }
            Some(Item::End) => {
                self.stats.consumed += 1;
                self.done = true;
            }
            Some(other) => panic!("{}: unexpected {other:?}", self.name),
            None => self.stats.stall_in += 1,
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::conv::{fc_i8, global_avg_pool_i8};

    #[test]
    fn pool_fc_matches_functional() {
        let mut rng = crate::util::Rng::new(5);
        let (c, n_classes) = (6, 4);
        let mut input: SparseMap<i8> = SparseMap::empty(8, 8, c);
        for y in 0..8u16 {
            for x in 0..8u16 {
                if rng.chance(0.4) {
                    let f: Vec<i8> = (0..c).map(|_| rng.range_i64(-100, 100) as i8).collect();
                    input.push(Token::new(x, y), &f);
                }
            }
        }
        let wfc: Vec<i8> = (0..c * n_classes).map(|_| rng.range_i64(-50, 50) as i8).collect();
        let bfc: Vec<i32> = (0..n_classes).map(|_| rng.range_i64(-99, 99) as i32).collect();

        let mut fab = Fabric::default();
        let ch_in = fab.add_chan(4);
        let ch_out = fab.add_chan(2);
        let mut src = SourceMod::new("src", ch_in, &input);
        let mut pool =
            PoolFcMod::new("poolfc", ch_in, ch_out, c, n_classes, 4, wfc.clone(), bfc.clone());
        let mut sink = SinkMod::new("sink", ch_out, 1, 1, 1);
        for _ in 0..10_000 {
            sink.step(&mut fab);
            pool.step(&mut fab);
            src.step(&mut fab);
            if sink.done() {
                break;
            }
        }
        assert!(sink.done());
        let pooled = global_avg_pool_i8(&input);
        let want = fc_i8(&pooled, &wfc, &bfc, n_classes);
        assert_eq!(sink.logits.as_ref().unwrap(), &want);
    }

    #[test]
    fn empty_stream_still_classifies() {
        let input: SparseMap<i8> = SparseMap::empty(4, 4, 2);
        let mut fab = Fabric::default();
        let ch_in = fab.add_chan(2);
        let ch_out = fab.add_chan(2);
        let mut src = SourceMod::new("src", ch_in, &input);
        let mut pool =
            PoolFcMod::new("poolfc", ch_in, ch_out, 2, 3, 1, vec![1i8; 6], vec![7, 8, 9]);
        let mut sink = SinkMod::new("sink", ch_out, 1, 1, 1);
        for _ in 0..1000 {
            sink.step(&mut fab);
            pool.step(&mut fab);
            src.step(&mut fab);
            if sink.done() {
                break;
            }
        }
        assert_eq!(sink.logits.as_ref().unwrap(), &vec![7, 8, 9]); // bias only
    }
}
