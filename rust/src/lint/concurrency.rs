//! Concurrency-discipline rules for `coordinator/`: **lock-order**,
//! **lock-span**, **atomic-rmw**, and **atomic-ordering**.
//!
//! The serving runtime is 5+ thread roles (ingress pump, router, workers,
//! scaler, net receive threads) sharing mutexes, condvars, and atomics
//! across the coordinator tree — exactly the regime where a lock-order
//! inversion or a misordered atomic silently corrupts the conservation
//! identities. These rules make the synchronization contracts textual and
//! machine-checked:
//!
//! - **lock-order** — every `Mutex`/`Condvar`/`RwLock` declaration in
//!   `coordinator/` carries a `// lint: lock-rank(N): <name>` directive
//!   (ranks live in `coordinator::lock_ranks`). The scanner then tracks
//!   nested `.lock()` acquisitions per function body by brace depth and
//!   flags any acquisition whose rank is not strictly above every rank
//!   already held — a static partial-order proof of deadlock freedom.
//!   `util::lockcheck::RankedMutex` asserts the same invariant
//!   dynamically in debug builds.
//! - **lock-span** — flags a bound guard lexically alive across a
//!   blocking call (`recv`, `join`, `sleep`, `wait_timeout`,
//!   `pop_batch*`, `classify*`). The condvar sleep idiom is legitimate
//!   (waiting *is* the point of releasing the lock) and is annotated
//!   `// lint:allow(lock-span): <reason>` at its one site.
//! - **atomic-rmw** — flags `.load(..)` followed by `.store(..)` on the
//!   same declared atomic field within one function: a lost-update
//!   window that must be a `fetch_*`/`compare_exchange` (like the
//!   retire-token CAS).
//! - **atomic-ordering** — every atomic field declares its contract via
//!   `// lint: atomic(relaxed|seqcst): <reason>`; any use of the field
//!   with a different `Ordering` is a finding, so a field's memory-order
//!   story lives in exactly one place.
//!
//! The declaration registry is ident-keyed and cross-file (a field
//! declared in `serve/state.rs` is recognized at its `serve/workers.rs`
//! use sites), which in turn requires every registered ident to mean one
//! lock tree-wide — the rules flag conflicting re-declarations.

use super::scan::{Scanned, ScannedLine};
use super::{emit, is_ident, token_positions, word_in, Finding, SourceFile};
use std::collections::HashMap;

/// Tokens that make a line a lock *declaration* (field, local, static,
/// or parameter). `Mutex<` needs the `<` so constructor calls
/// (`Mutex::new`) and doc prose don't trigger; the condvar types are
/// filtered against a following `::` instead.
const LOCK_DECL_TOKENS: [&str; 5] =
    ["RankedMutex<", "Mutex<", "RwLock<", "RankedCondvar", "Condvar"];

/// Calls that can block for unbounded time: holding a lock across one
/// stalls every sibling contender (`.wait(` is deliberately absent —
/// a condvar wait *releases* the guard it is handed).
const BLOCKING_TOKENS: [&str; 6] =
    [".recv(", ".join(", "sleep(", ".wait_timeout(", ".pop_batch", ".classify"];

/// Atomic integer/bool types whose declarations need an ordering
/// contract.
const ATOMIC_TYPES: [&str; 6] =
    ["AtomicBool", "AtomicUsize", "AtomicU64", "AtomicU32", "AtomicI64", "AtomicIsize"];

/// Method tokens that read or write an atomic.
const ATOMIC_OPS: [&str; 11] = [
    ".load(",
    ".store(",
    ".fetch_add(",
    ".fetch_sub(",
    ".fetch_max(",
    ".fetch_min(",
    ".fetch_or(",
    ".fetch_and(",
    ".compare_exchange(",
    ".compare_exchange_weak(",
    ".swap(",
];

const ORDERING_WORDS: [&str; 5] = ["SeqCst", "Relaxed", "Acquire", "Release", "AcqRel"];

/// A lock ident's declared place in the global order.
struct LockDecl {
    rank: u32,
    file: String,
    /// 1-based declaration line.
    line: usize,
}

/// An atomic ident's declared ordering contract.
struct AtomicDecl {
    seqcst: bool,
    file: String,
    line: usize,
}

#[derive(Default)]
struct Registry {
    locks: HashMap<String, LockDecl>,
    atomics: HashMap<String, AtomicDecl>,
}

/// Do these rules apply to `rel` at all?
fn scoped(rel: &str) -> bool {
    rel.starts_with("coordinator/")
}

/// Entry point, called by `lint_sources` with every scanned file.
pub(super) fn rules(scanned: &[(&SourceFile, Scanned)], out: &mut Vec<Finding>) {
    let mut reg = Registry::default();
    for (f, s) in scanned {
        if scoped(&f.rel_path) {
            register_and_check_decls(f, s, &mut reg, out);
        }
    }
    for (f, s) in scanned {
        if scoped(&f.rel_path) {
            walk_file(f, s, &reg, out);
        }
    }
}

/// The comment sites a directive for line `idx` may live on: the line's
/// own trailing comment, or the run of pure-comment lines immediately
/// above (mirrors the allow-directive reach).
fn directive_sites(lines: &[ScannedLine], idx: usize) -> Vec<usize> {
    let mut sites = vec![idx];
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.code.trim().is_empty() && !l.comment.trim().is_empty() {
            sites.push(j);
        } else {
            break;
        }
    }
    sites
}

/// Parse a `lint: lock-rank(N): <name>` directive out of comment text.
/// `None`: no directive present. `Some(Err)`: present but malformed.
fn lock_rank_marker(comment: &str) -> Option<Result<u32, String>> {
    let pos = comment.find("lint: lock-rank(")?;
    let rest = &comment[pos + "lint: lock-rank(".len()..];
    let Some(close) = rest.find(')') else {
        return Some(Err("unclosed `lint: lock-rank(`".to_string()));
    };
    let Ok(rank) = rest[..close].trim().parse::<u32>() else {
        return Some(Err(format!("unparsable rank `{}`", rest[..close].trim())));
    };
    let after = rest[close + 1..].trim_start();
    let name = after.strip_prefix(':').map(str::trim).unwrap_or("");
    if name.is_empty() {
        return Some(Err("missing the `: <name>` tail".to_string()));
    }
    Some(Ok(rank))
}

/// Parse a `lint: atomic(relaxed|seqcst): <reason>` directive.
fn atomic_marker(comment: &str) -> Option<Result<bool, String>> {
    let pos = comment.find("lint: atomic(")?;
    let rest = &comment[pos + "lint: atomic(".len()..];
    let Some(close) = rest.find(')') else {
        return Some(Err("unclosed `lint: atomic(`".to_string()));
    };
    let mode = rest[..close].trim();
    let seqcst = match mode {
        "seqcst" => true,
        "relaxed" => false,
        other => return Some(Err(format!("mode must be relaxed|seqcst, not `{other}`"))),
    };
    let after = rest[close + 1..].trim_start();
    if after.strip_prefix(':').map(str::trim).unwrap_or("").is_empty() {
        return Some(Err("missing the `: <reason>` tail".to_string()));
    }
    Some(Ok(seqcst))
}

/// The identifiers a declaration line binds. `let` lines yield the
/// pattern idents (tuple destructures included); field/param/static
/// lines yield the first ident directly followed by a `:`.
fn binding_idents(code: &str) -> Vec<String> {
    let t = code.trim();
    if word_in(t, "let") {
        let Some(pos) = t.find("let") else {
            return Vec::new();
        };
        let after = &t[pos + 3..];
        let end = after.find(['=', ':']).unwrap_or(after.len());
        return idents_in(&after[..end])
            .into_iter()
            .filter(|w| w != "mut" && w != "ref")
            .filter(|w| !w.starts_with(char::is_uppercase))
            .collect();
    }
    let b = t.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if is_ident(b[i] as char) && (i == 0 || !is_ident(b[i - 1] as char)) {
            let start = i;
            while i < b.len() && is_ident(b[i] as char) {
                i += 1;
            }
            let mut j = i;
            while j < b.len() && (b[j] as char).is_whitespace() {
                j += 1;
            }
            if j < b.len() && b[j] == b':' && b.get(j + 1) != Some(&b':') {
                return vec![t[start..i].to_string()];
            }
        } else {
            i += 1;
        }
    }
    Vec::new()
}

fn idents_in(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in s.chars() {
        if is_ident(c) {
            cur.push(c);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Does this code line declare a lock? Returns the matched type token.
fn lock_decl_trigger(code: &str) -> Option<&'static str> {
    for tok in LOCK_DECL_TOKENS {
        for at in token_positions(code, tok) {
            let after = &code[at + tok.len()..];
            if (tok == "Condvar" || tok == "RankedCondvar") && after.starts_with("::") {
                continue;
            }
            return Some(tok);
        }
    }
    None
}

/// Does this code line declare an atomic? Returns the matched type.
fn atomic_decl_trigger(code: &str) -> Option<&'static str> {
    for tok in ATOMIC_TYPES {
        for at in token_positions(code, tok) {
            if code[at + tok.len()..].starts_with("::") {
                continue;
            }
            return Some(tok);
        }
    }
    None
}

/// Pass 1 over a file: every lock/atomic declaration must carry its
/// directive, every directive registers its line's binding idents in the
/// cross-file registry, and conflicting re-declarations are findings.
fn register_and_check_decls(
    f: &SourceFile,
    s: &Scanned,
    reg: &mut Registry,
    out: &mut Vec<Finding>,
) {
    for (i, line) in s.lines.iter().enumerate() {
        if line.in_test || line.code.trim().is_empty() {
            continue;
        }
        let t = line.code.trim_start();
        if t.starts_with("use ") || t.starts_with("pub use ") {
            continue;
        }
        let mut rank: Option<u32> = None;
        let mut mode: Option<bool> = None;
        for &k in &directive_sites(&s.lines, i) {
            let comment = &s.lines[k].comment;
            match lock_rank_marker(comment) {
                Some(Ok(r)) => rank = rank.or(Some(r)),
                Some(Err(why)) => out.push(Finding {
                    file: f.rel_path.clone(),
                    line: k + 1,
                    rule: "lock-order",
                    message: format!("malformed lock-rank directive: {why}"),
                    fix: "spell it `// lint: lock-rank(N): <name>`".to_string(),
                }),
                None => {}
            }
            match atomic_marker(comment) {
                Some(Ok(m)) => mode = mode.or(Some(m)),
                Some(Err(why)) => out.push(Finding {
                    file: f.rel_path.clone(),
                    line: k + 1,
                    rule: "atomic-ordering",
                    message: format!("malformed atomic directive: {why}"),
                    fix: "spell it `// lint: atomic(relaxed|seqcst): <reason>`".to_string(),
                }),
                None => {}
            }
        }
        if let Some(rank) = rank {
            for ident in binding_idents(&line.code) {
                register_lock(f, i, ident, rank, reg, out);
            }
        }
        if let Some(seqcst) = mode {
            for ident in binding_idents(&line.code) {
                register_atomic(f, i, ident, seqcst, reg, out);
            }
        }
        if rank.is_none() {
            if let Some(tok) = lock_decl_trigger(&line.code) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "lock-order",
                    format!("`{tok}` declared without a lock rank"),
                    "add `// lint: lock-rank(N): <name>` (ranks: coordinator::lock_ranks)"
                        .to_string(),
                );
            }
        }
        if mode.is_none() {
            if let Some(tok) = atomic_decl_trigger(&line.code) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "atomic-ordering",
                    format!("`{tok}` declared without an ordering contract"),
                    "add `// lint: atomic(relaxed|seqcst): <reason>`".to_string(),
                );
            }
        }
    }
}

fn register_lock(
    f: &SourceFile,
    i: usize,
    ident: String,
    rank: u32,
    reg: &mut Registry,
    out: &mut Vec<Finding>,
) {
    if let Some(prev) = reg.locks.get(&ident) {
        if prev.rank != rank {
            out.push(Finding {
                file: f.rel_path.clone(),
                line: i + 1,
                rule: "lock-order",
                message: format!(
                    "lock `{ident}` re-declared at rank {rank} (rank {} at {}:{})",
                    prev.rank, prev.file, prev.line
                ),
                fix: "one registry ident means one lock: rename one of them".to_string(),
            });
        }
        return;
    }
    reg.locks.insert(ident, LockDecl { rank, file: f.rel_path.clone(), line: i + 1 });
}

fn register_atomic(
    f: &SourceFile,
    i: usize,
    ident: String,
    seqcst: bool,
    reg: &mut Registry,
    out: &mut Vec<Finding>,
) {
    if let Some(prev) = reg.atomics.get(&ident) {
        if prev.seqcst != seqcst {
            out.push(Finding {
                file: f.rel_path.clone(),
                line: i + 1,
                rule: "atomic-ordering",
                message: format!(
                    "atomic `{ident}` re-declared {} ({} at {}:{})",
                    mode_name(seqcst),
                    mode_name(prev.seqcst),
                    prev.file,
                    prev.line
                ),
                fix: "one registry ident means one contract: rename one of them".to_string(),
            });
        }
        return;
    }
    reg.atomics.insert(ident, AtomicDecl { seqcst, file: f.rel_path.clone(), line: i + 1 });
}

fn mode_name(seqcst: bool) -> &'static str {
    if seqcst {
        "seqcst"
    } else {
        "relaxed"
    }
}

/// A lexically-live bound guard.
struct Guard {
    rank: u32,
    /// Registry ident of the lock (for messages).
    lock: String,
    /// The bound variable (for `drop(x)` matching).
    var: String,
    /// Brace depth the binding lives at; popped when the enclosing
    /// block closes.
    depth: i64,
}

/// Pass 2 over a file: track `.lock()` acquisitions against the
/// registry by brace depth (lock-order, lock-span) and atomic op sites
/// against the contracts (atomic-ordering, atomic-rmw).
fn walk_file(f: &SourceFile, s: &Scanned, reg: &Registry, out: &mut Vec<Finding>) {
    let mut depth: i64 = 0;
    let mut stack: Vec<Guard> = Vec::new();
    // Atomic ident -> 0-based line of its last `.load(` in the current fn.
    let mut loads: HashMap<String, usize> = HashMap::new();
    for (i, line) in s.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        if word_in(code, "fn") {
            loads.clear();
        }
        let mut evs: Vec<(usize, Ev)> = Vec::new();
        for (b, c) in code.char_indices() {
            match c {
                '{' => evs.push((b, Ev::Open)),
                '}' => evs.push((b, Ev::Close)),
                _ => {}
            }
        }
        for at in token_positions(code, ".lock()") {
            evs.push((at, Ev::Lock));
        }
        for tok in BLOCKING_TOKENS {
            for at in token_positions(code, tok) {
                evs.push((at, Ev::Block(tok)));
            }
        }
        for at in token_positions(code, "drop(") {
            let ident: String =
                code[at + "drop(".len()..].chars().take_while(|&c| is_ident(c)).collect();
            evs.push((at, Ev::Drop(ident)));
        }
        evs.sort_by_key(|e| e.0);
        for (at, ev) in evs {
            match ev {
                Ev::Open => depth += 1,
                Ev::Close => {
                    depth -= 1;
                    while stack.last().is_some_and(|g| g.depth > depth) {
                        stack.pop();
                    }
                }
                Ev::Lock => on_lock(f, s, reg, i, at, depth, &mut stack, out),
                Ev::Block(tok) => {
                    if let Some(top) = stack.last() {
                        emit(
                            out,
                            &f.rel_path,
                            &s.lines,
                            i,
                            "lock-span",
                            format!(
                                "guard of `{}` (rank {}) held across blocking `{tok}..)`",
                                top.lock, top.rank
                            ),
                            "drop the guard first, or annotate \
                             `// lint:allow(lock-span): <why>`"
                                .to_string(),
                        );
                    }
                }
                Ev::Drop(ident) => {
                    if let Some(pos) = stack.iter().rposition(|g| g.var == ident) {
                        stack.remove(pos);
                    }
                }
            }
        }
        atomic_ops(f, s, reg, i, &mut loads, out);
    }
}

enum Ev {
    Open,
    Close,
    Lock,
    Block(&'static str),
    Drop(String),
}

/// Handle one `.lock()` at byte `at` of line `i`.
#[allow(clippy::too_many_arguments)]
fn on_lock(
    f: &SourceFile,
    s: &Scanned,
    reg: &Registry,
    i: usize,
    at: usize,
    depth: i64,
    stack: &mut Vec<Guard>,
    out: &mut Vec<Finding>,
) {
    let recv = receiver_ident(&s.lines, i, at);
    let Some(decl) = reg.locks.get(&recv) else {
        let what = if recv.is_empty() { "<expr>".to_string() } else { format!("`{recv}`") };
        emit(
            out,
            &f.rel_path,
            &s.lines,
            i,
            "lock-order",
            format!(".lock() on {what}, which has no declared rank"),
            "declare it with `// lint: lock-rank(N): <name>` at the declaration".to_string(),
        );
        return;
    };
    if let Some(top) = stack.last() {
        if decl.rank <= top.rank {
            emit(
                out,
                &f.rel_path,
                &s.lines,
                i,
                "lock-order",
                format!(
                    "acquiring `{recv}` (rank {}) while holding `{}` (rank {}) inverts \
                     the lock order",
                    decl.rank, top.lock, top.rank
                ),
                format!("drop the `{}` guard first, or re-rank the locks", top.lock),
            );
        }
    }
    if stmt_has_let(&s.lines, i) && bound_guard_tail(&s.lines, i, at + ".lock()".len()) {
        let var = stmt_binding(&s.lines, i).unwrap_or_else(|| "_".to_string());
        stack.push(Guard { rank: decl.rank, lock: recv, var, depth });
    }
}

/// The receiver identifier of a method token at byte `at` of line `i`:
/// the trailing ident of the join of up to two preceding lines and the
/// current line up to `at` (rustfmt may split a chain across lines).
/// Only *trailing* whitespace is trimmed — stripping interior whitespace
/// would weld a keyword onto the ident (`if stop` -> `ifstop`) and make
/// the receiver unresolvable against the registry.
fn receiver_ident(lines: &[ScannedLine], i: usize, at: usize) -> String {
    let mut ctx = String::new();
    for l in &lines[i.saturating_sub(2)..i] {
        ctx.push_str(&l.code);
    }
    ctx.push_str(&lines[i].code[..at]);
    let t = ctx.trim_end();
    let b = t.as_bytes();
    let mut start = b.len();
    while start > 0 && is_ident(b[start - 1] as char) {
        start -= 1;
    }
    t[start..].to_string()
}

/// 0-based line where the statement containing line `i` starts: walk
/// back (bounded) until the previous line plausibly ends a statement.
fn stmt_start(lines: &[ScannedLine], i: usize) -> usize {
    let mut j = i;
    for _ in 0..6 {
        if j == 0 {
            break;
        }
        let prev = lines[j - 1].code.trim();
        if prev.is_empty()
            || prev.ends_with(';')
            || prev.ends_with('{')
            || prev.ends_with('}')
            || prev.ends_with(',')
        {
            break;
        }
        j -= 1;
    }
    j
}

fn stmt_has_let(lines: &[ScannedLine], i: usize) -> bool {
    let j = stmt_start(lines, i);
    lines[j..=i].iter().any(|l| word_in(&l.code, "let"))
}

fn stmt_binding(lines: &[ScannedLine], i: usize) -> Option<String> {
    let j = stmt_start(lines, i);
    binding_idents(&lines[j].code).into_iter().next()
}

/// Is the expression after `.lock()` exactly the guard-binding tail —
/// `.unwrap()` or the poison-tolerant `.unwrap_or_else(|x| x.into_inner())`
/// — with nothing chained after? Anything longer is a temporary whose
/// guard dies at the end of the statement.
fn bound_guard_tail(lines: &[ScannedLine], i: usize, from: usize) -> bool {
    let mut t = lines[i].code[from..].to_string();
    let mut j = i + 1;
    while !t.contains(';') && j < lines.len() && j <= i + 6 {
        t.push_str(&lines[j].code);
        j += 1;
    }
    t.retain(|c| !c.is_whitespace());
    let t = t.split(';').next().unwrap_or("");
    if t == ".unwrap()" {
        return true;
    }
    let Some(rest) = t.strip_prefix(".unwrap_or_else(|") else {
        return false;
    };
    let Some(bar) = rest.find('|') else {
        return false;
    };
    let var = &rest[..bar];
    !var.is_empty() && rest[bar + 1..] == format!("{var}.into_inner())")
}

/// The argument text of a call whose `(` sits at byte `open` of line
/// `i`, joined across up to six lines and cut at the matching `)`.
fn call_args(lines: &[ScannedLine], i: usize, open: usize) -> String {
    let mut t = lines[i].code[open..].to_string();
    for l in lines.iter().skip(i + 1).take(6) {
        t.push_str(&l.code);
    }
    t.retain(|c| !c.is_whitespace());
    let mut depth = 0i64;
    for (p, c) in t.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return t[..p].to_string();
                }
            }
            _ => {}
        }
    }
    t
}

/// Check every atomic op on line `i` against the declared contracts
/// (atomic-ordering) and the per-function load/store pairing
/// (atomic-rmw).
fn atomic_ops(
    f: &SourceFile,
    s: &Scanned,
    reg: &Registry,
    i: usize,
    loads: &mut HashMap<String, usize>,
    out: &mut Vec<Finding>,
) {
    for tok in ATOMIC_OPS {
        for at in token_positions(&s.lines[i].code, tok) {
            let recv = receiver_ident(&s.lines, i, at);
            let args = call_args(&s.lines, i, at + tok.len() - 1);
            let used: Vec<&str> =
                ORDERING_WORDS.iter().copied().filter(|w| word_in(&args, w)).collect();
            let Some(decl) = reg.atomics.get(&recv) else {
                if !used.is_empty() {
                    emit(
                        out,
                        &f.rel_path,
                        &s.lines,
                        i,
                        "atomic-ordering",
                        format!("atomic op on `{recv}`, which has no declared contract"),
                        "declare the field with `// lint: atomic(relaxed|seqcst): <why>`"
                            .to_string(),
                    );
                }
                continue;
            };
            let want = if decl.seqcst { "SeqCst" } else { "Relaxed" };
            for w in used {
                if w != want {
                    emit(
                        out,
                        &f.rel_path,
                        &s.lines,
                        i,
                        "atomic-ordering",
                        format!(
                            "`{recv}` is declared {} but used with `{w}`",
                            mode_name(decl.seqcst)
                        ),
                        format!("use Ordering::{want}, or change the declared contract"),
                    );
                }
            }
            if tok == ".load(" {
                loads.insert(recv, i);
            } else if tok == ".store(" {
                if let Some(&l0) = loads.get(&recv) {
                    emit(
                        out,
                        &f.rel_path,
                        &s.lines,
                        i,
                        "atomic-rmw",
                        format!(
                            "`{recv}` is loaded (line {}) then stored back in the same \
                             function — a lost-update window",
                            l0 + 1
                        ),
                        "make it one atomic RMW: fetch_add/fetch_sub/compare_exchange"
                            .to_string(),
                    );
                }
            }
        }
    }
}
