"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes, sparsity, strides, and activations with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import submanifold as pk

jax.config.update("jax_platform_name", "cpu")


def random_sparse(rng, h, w, c, p):
    mask = rng.random((h, w)) < p
    x = (rng.standard_normal((h, w, c)).astype(np.float32)) * mask[..., None]
    return jnp.asarray(x), jnp.asarray(mask)


shape_st = st.tuples(
    st.integers(3, 16),  # h
    st.integers(3, 16),  # w
    st.integers(1, 6),   # c
    st.integers(0, 10_000),  # seed
    st.floats(0.05, 0.9),    # density
)


@settings(max_examples=30, deadline=None)
@given(shape_st, st.sampled_from(["none", "relu", "relu6"]))
def test_pointwise_matches_ref(shape, act):
    h, w, c, seed, p = shape
    rng = np.random.default_rng(seed)
    x, mask = random_sparse(rng, h, w, c, p)
    cout = int(rng.integers(1, 6))
    wt = jnp.asarray(rng.standard_normal((c, cout)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(cout).astype(np.float32))
    got, gm = pk.pointwise(x, mask, wt, b, act=act)
    want, wm = ref.conv1x1(x, mask, wt, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(gm == wm))


@settings(max_examples=30, deadline=None)
@given(shape_st, st.sampled_from([1, 2]), st.sampled_from(["none", "relu6"]))
def test_dwconv_matches_ref(shape, stride, act):
    h, w, c, seed, p = shape
    rng = np.random.default_rng(seed)
    x, mask = random_sparse(rng, h, w, c, p)
    wt = jnp.asarray(rng.standard_normal((3, 3, c)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(c).astype(np.float32))
    got, gm = pk.dwconv3x3(x, mask, wt, b, stride=stride, act=act)
    want, wm = ref.submanifold_dwconv(x, mask, wt, b, stride=stride, act=act)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert bool(jnp.all(gm == wm))


@settings(max_examples=25, deadline=None)
@given(shape_st, st.sampled_from([1, 2]))
def test_full_conv_matches_ref(shape, stride):
    h, w, c, seed, p = shape
    rng = np.random.default_rng(seed)
    x, mask = random_sparse(rng, h, w, c, p)
    cout = int(rng.integers(1, 5))
    wt = jnp.asarray(rng.standard_normal((3, 3, c, cout)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(cout).astype(np.float32))
    got, gm = pk.conv3x3(x, mask, wt, b, stride=stride, act="none")
    want, wm = ref.submanifold_conv(x, mask, wt, b, stride=stride, act="none")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert bool(jnp.all(gm == wm))


@settings(max_examples=20, deadline=None)
@given(shape_st)
def test_pool_fc_matches_ref(shape):
    h, w, c, seed, p = shape
    rng = np.random.default_rng(seed)
    x, mask = random_sparse(rng, h, w, c, p)
    ncls = int(rng.integers(2, 8))
    wt = jnp.asarray(rng.standard_normal((c, ncls)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(ncls).astype(np.float32))
    got = pk.pool_fc(x, mask, wt, b)
    want = ref.global_pool_fc(x, mask, wt, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Submanifold semantics (paper §3.2)
# ---------------------------------------------------------------------------


def test_stride1_preserves_token_set():
    rng = np.random.default_rng(0)
    x, mask = random_sparse(rng, 12, 12, 3, 0.2)
    wt = jnp.asarray(rng.standard_normal((3, 3, 3)).astype(np.float32))
    b = jnp.zeros(3, jnp.float32)
    out, om = pk.dwconv3x3(x, mask, wt, b, stride=1)
    # No dilation: outputs only at input tokens.
    out_nonzero = jnp.any(jnp.abs(out) > 0, axis=-1)
    assert bool(jnp.all(out_nonzero <= mask))
    assert bool(jnp.all(om == mask))


def test_standard_conv_dilates_but_submanifold_does_not():
    x = np.zeros((9, 9, 1), np.float32)
    x[4, 4, 0] = 1.0
    mask = jnp.asarray(x[..., 0] > 0)
    xj = jnp.asarray(x)
    wt = jnp.ones((3, 3, 1, 1), jnp.float32)
    b = jnp.zeros(1, jnp.float32)
    _, m_std = ref.standard_conv(xj, mask, wt, b)
    sub, m_sub = ref.submanifold_conv(xj, mask, wt, b)
    assert int(m_std.sum()) == 9  # dilated to the 3x3 neighbourhood
    assert int(m_sub.sum()) == 1  # token set preserved
    assert float(sub[4, 4, 0]) == 1.0


def test_stride2_grid_rule():
    mask = np.zeros((6, 6), bool)
    mask[1, 1] = True  # grid (0,0)
    mask[5, 4] = True  # grid (2,2)
    dm = ref.downsample_mask(jnp.asarray(mask))
    assert dm.shape == (3, 3)
    assert bool(dm[0, 0]) and bool(dm[2, 2])
    assert int(dm.sum()) == 2


def test_odd_sizes_stride2_shapes():
    rng = np.random.default_rng(3)
    x, mask = random_sparse(rng, 7, 9, 2, 0.4)
    wt = jnp.asarray(rng.standard_normal((3, 3, 2)).astype(np.float32))
    b = jnp.zeros(2, jnp.float32)
    out, om = pk.dwconv3x3(x, mask, wt, b, stride=2)
    assert out.shape == (4, 5, 2)
    assert om.shape == (4, 5)


def test_vmem_footprint_estimate():
    # Whole-slab at the largest paper layer slightly exceeds 16 MB VMEM —
    # which is exactly why the documented schedule tiles by rows; the
    # row-tiled footprint fits with wide margin.
    whole = pk.vmem_footprint_bytes(180, 240, 48, 48)
    tiled = pk.vmem_footprint_bytes(180, 240, 48, 48, tile_h=16)
    assert whole > 16 * 2**20
    assert tiled < 4 * 2**20
    assert tiled < whole
