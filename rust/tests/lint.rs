//! Fixture tests for the in-tree linter (`esda lint`), one cluster per
//! rule — each proves the violation is caught, the clean form passes,
//! and `lint:allow` suppression works (with the reason mandatory) —
//! plus the self-check: the shipped tree must lint clean, so `esda
//! lint` in CI is a real gate and not an aspiration.

use esda::lint::{collect_files, lint_sources, SourceFile};
use std::path::PathBuf;

/// Lint a single in-memory file (no README → drift-flags is skipped).
fn lint_one(rel: &str, text: &str) -> Vec<String> {
    lint_files(&[(rel, text)], None)
}

fn lint_files(files: &[(&str, &str)], readme: Option<&str>) -> Vec<String> {
    let files: Vec<SourceFile> = files
        .iter()
        .map(|(rel, text)| SourceFile { rel_path: rel.to_string(), text: text.to_string() })
        .collect();
    lint_sources(&files, readme).iter().map(|f| f.render()).collect()
}

fn assert_clean(findings: &[String]) {
    assert!(findings.is_empty(), "expected no findings, got:\n{}", findings.join("\n"));
}

// ---------------------------------------------------------------- panic

#[test]
fn panic_rule_catches_unwrap_on_the_serving_path() {
    let found = lint_one("coordinator/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n");
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].starts_with("coordinator/fixture.rs:1: panic:"), "{}", found[0]);
    assert!(found[0].contains(".unwrap()"), "{}", found[0]);
}

#[test]
fn panic_rule_catches_every_token_and_reports_each_line() {
    let text = "fn f() {\n    todo!()\n}\nfn g() {\n    unreachable!()\n}\n";
    let found = lint_one("sparse/fixture.rs", text);
    assert_eq!(found.len(), 2, "{found:?}");
    assert!(found[0].contains(":2: panic:"), "{}", found[0]);
    assert!(found[1].contains(":5: panic:"), "{}", found[1]);
}

#[test]
fn panic_rule_skips_unscoped_files_clean_files_and_test_code() {
    // Same violation, but outside the panic scope.
    assert_clean(&lint_one("util/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n"));
    // Clean scoped file.
    assert_clean(&lint_one("events/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n"));
    // Violations inside #[cfg(test)] / #[test] items are exempt.
    let text = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { panic!(\"boom\") }\n}\n";
    assert_clean(&lint_one("events/fixture.rs", text));
}

#[test]
fn panic_rule_allows_the_lock_poisoning_idiom_by_pattern() {
    assert_clean(&lint_one("coordinator/fixture.rs", "fn f(m: &M) { m.lock().unwrap(); }\n"));
    // ... including rustfmt-split chains.
    let split =
        "fn f(s: &S) {\n    s.inner\n        .lock()\n        .unwrap()\n        .push(1);\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", split));
    // But not arbitrary unwraps that merely mention lock elsewhere.
    let found = lint_one("coordinator/fixture.rs", "fn f(x: Option<u8>) { x.unwrap(); }\n");
    assert_eq!(found.len(), 1, "{found:?}");
}

#[test]
fn allow_with_reason_suppresses_on_same_or_preceding_comment_line() {
    let same = "fn f(x: Option<u8>) { x.unwrap(); } // lint:allow(panic): guarded above\n";
    assert_clean(&lint_one("coordinator/fixture.rs", same));
    let above = "fn f(x: Option<u8>) {\n    // lint:allow(panic): guarded by the caller\n    \
                 x.unwrap();\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", above));
}

#[test]
fn reasonless_allow_is_itself_a_finding_and_does_not_suppress_silently() {
    let text = "fn f(x: Option<u8>) {\n    // lint:allow(panic)\n    x.unwrap();\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("without a reason"), "{}", found[0]);
    assert!(found[0].contains(":2:"), "flagged at the marker line: {}", found[0]);
}

#[test]
fn allow_for_a_different_rule_does_not_suppress() {
    let text = "fn f(x: Option<u8>) {\n    // lint:allow(cast): wrong rule\n    x.unwrap();\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("panic"), "{}", found[0]);
}

#[test]
fn tokens_inside_strings_and_comments_are_not_violations() {
    let text = "fn f() -> &'static str {\n    // a comment saying panic! and .unwrap()\n    \
                \"panic! .unwrap() todo!\"\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", text));
}

// ------------------------------------------------------------ hot-alloc

#[test]
fn hot_alloc_catches_allocation_inside_a_marked_region() {
    let text = "// lint: hot-path\nfn k(v: &[u8]) -> Vec<u8> {\n    v.to_vec()\n}\n\
                // lint: hot-path end\n";
    let found = lint_one("anywhere.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":3: hot-alloc:"), "{}", found[0]);
    assert!(found[0].contains(".to_vec()"), "{}", found[0]);
}

#[test]
fn hot_alloc_ignores_allocation_outside_regions() {
    let text = "fn setup() -> Vec<u8> {\n    vec![0; 8]\n}\n// lint: hot-path\n\
                fn k(acc: &mut [u8]) { acc[0] = 1; }\n// lint: hot-path end\n";
    assert_clean(&lint_one("anywhere.rs", text));
}

#[test]
fn hot_alloc_flags_unbalanced_markers() {
    let unclosed = lint_one("anywhere.rs", "// lint: hot-path\nfn k() {}\n");
    assert_eq!(unclosed.len(), 1, "{unclosed:?}");
    assert!(unclosed[0].contains("never closed"), "{}", unclosed[0]);
    let orphan = lint_one("anywhere.rs", "fn k() {}\n// lint: hot-path end\n");
    assert_eq!(orphan.len(), 1, "{orphan:?}");
    assert!(orphan[0].contains("without an open region"), "{}", orphan[0]);
}

#[test]
fn hot_alloc_respects_allow_annotations() {
    let text = "// lint: hot-path\nfn k() {\n    // lint:allow(hot-alloc): first call sizes \
                the arena\n    let v = Vec::new();\n    drop(v);\n}\n// lint: hot-path end\n";
    assert_clean(&lint_one("anywhere.rs", text));
}

// ----------------------------------------------------------------- cast

#[test]
fn cast_rule_catches_bare_narrowing_casts_in_wire_files_only() {
    let text = "fn f(v: u64) -> u32 { v as u32 }\n";
    let found = lint_one("events/io.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("cast: bare `as u32`"), "{}", found[0]);
    // The same text in a non-wire file is out of scope.
    assert_clean(&lint_one("events/other.rs", text));
}

#[test]
fn cast_rule_ignores_widening_and_annotated_casts() {
    assert_clean(&lint_one("coordinator/net.rs", "fn f(v: u16) -> u64 { v as u64 }\n"));
    let annotated = "fn f(v: usize) -> u16 {\n    // lint:allow(cast): v < 4 by construction\n    \
                     v as u16\n}\n";
    assert_clean(&lint_one("coordinator/net.rs", annotated));
}

// ---------------------------------------------------------------- print

#[test]
fn print_rule_bans_println_in_library_modules_only() {
    let text = "fn f() {\n    println!(\"hi\");\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("print: `println!`"), "{}", found[0]);
    assert_clean(&lint_one("main.rs", text));
    assert_clean(&lint_one("report/fixture.rs", text));
}

// -------------------------------------------------------- drift-metrics

const METRICS_FIXTURE: &str = "pub struct Metrics {\n    pub served: usize,\n    \
                               pub ghosts: usize,\n    pub rate: f64,\n}\n";

#[test]
fn drift_metrics_flags_counters_never_referenced_in_report() {
    let report = "pub fn line(m: &Metrics) -> String { m.served.to_string() }\n";
    let found = lint_files(
        &[("coordinator/metrics.rs", METRICS_FIXTURE), ("report/mod.rs", report)],
        None,
    );
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("Metrics.ghosts"), "{}", found[0]);
    assert!(!found[0].contains("rate"), "non-usize fields are not counters: {}", found[0]);
}

#[test]
fn drift_metrics_passes_when_every_counter_is_rendered_and_skips_bare_lists() {
    let report = "pub fn line(m: &Metrics) -> String {\n    \
                  format!(\"{} {}\", m.served, m.ghosts)\n}\n";
    assert_clean(&lint_files(
        &[("coordinator/metrics.rs", METRICS_FIXTURE), ("report/mod.rs", report)],
        None,
    ));
    // Linting metrics.rs alone (no report files in the set) skips the
    // rule instead of flagging everything.
    assert_clean(&lint_files(&[("coordinator/metrics.rs", METRICS_FIXTURE)], None));
}

// ---------------------------------------------------------- drift-flags

#[test]
fn drift_flags_requires_parsed_flags_to_be_documented() {
    let cli = "fn f(a: &Args) -> bool { a.has(\"verbose\") || a.has(\"mystery\") }\n";
    let readme = "Usage: pass `--verbose` for more output.\n";
    let found = lint_files(&[("main.rs", cli)], Some(readme));
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("--mystery"), "{}", found[0]);
    // With the flag documented, the set is clean.
    let full = "Usage: `--verbose`, `--mystery`.\n";
    assert_clean(&lint_files(&[("main.rs", cli)], Some(full)));
    // Without a README in reach the rule is skipped, not exploded.
    assert_clean(&lint_files(&[("main.rs", cli)], None));
}

#[test]
fn drift_flags_ignores_non_accessor_strings() {
    let cli = "fn f() -> String { String::from(\"mystery\") }\n";
    assert_clean(&lint_files(&[("main.rs", cli)], Some("no flags here\n")));
}

// ----------------------------------------------------------- module-size

/// A fixture module with `n` counted code lines (plus optional padding
/// the rule must ignore).
fn module_of(n: usize, padding: &str) -> String {
    format!("fn f() {{\n{}}}\n{padding}", "    let _x = 1;\n".repeat(n.saturating_sub(2)))
}

#[test]
fn module_size_flags_oversized_library_modules_at_line_one() {
    let found = lint_one("coordinator/fixture.rs", &module_of(901, ""));
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].starts_with("coordinator/fixture.rs:1: module-size:"), "{}", found[0]);
    assert!(found[0].contains("901"), "{}", found[0]);
    assert!(found[0].contains("900"), "{}", found[0]);
}

#[test]
fn module_size_passes_at_the_cap_and_ignores_blank_comment_and_test_lines() {
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(900, "")));
    // Blank lines and comments are not code: 900 code lines plus a sea
    // of padding still pass.
    let padding = "\n// commentary\n".repeat(300);
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(900, &padding)));
    // #[cfg(test)] items don't count toward the cap either.
    let tests =
        format!("#[cfg(test)]\nmod tests {{\n{}}}\n", "    fn t() {}\n".repeat(600));
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(890, &tests)));
    // main.rs is the binary, not a library module.
    assert_clean(&lint_one("main.rs", &module_of(1200, "")));
}

#[test]
fn module_size_respects_a_reasoned_allow_on_line_one() {
    let text = format!(
        "// lint:allow(module-size): split scheduled for the next PR\n{}",
        module_of(950, "")
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

// ------------------------------------------------------------ self-check

/// The shipped tree lints clean: every genuine violation is fixed and
/// every intentional site is annotated, so the CI `esda lint` gate is
/// armed at zero. If this fails, run `cargo run -- lint --fix-plan`.
#[test]
fn shipped_tree_is_lint_clean() {
    let src = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let files = collect_files(&[src]).expect("walk rust/src");
    assert!(files.len() > 20, "walk found only {} file(s)", files.len());
    let readme = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"))
        .expect("README.md at the repo root");
    let findings = lint_sources(&files, Some(&readme));
    let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
    assert!(rendered.is_empty(), "shipped tree has lint findings:\n{}", rendered.join("\n"));
}
