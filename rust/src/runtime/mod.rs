//! PJRT runtime: load the AOT-compiled JAX model (HLO text emitted by
//! `python/compile/aot.py`) and execute it from Rust — the dense-inference
//! engine that (a) validates the L2/L1 artifacts against the rust oracle
//! and (b) serves as the "GPU dense" platform stand-in in Fig. 14.
//!
//! Python never runs on this path: the HLO text is compiled once by the
//! PJRT CPU client at load time and executed with concrete buffers
//! thereafter (see /opt/xla-example/load_hlo for the pattern, and
//! DESIGN.md for why HLO *text* is the interchange format).

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A loaded, compiled model artifact.
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Input geometry of the dense representation (h, w, c).
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub n_classes: usize,
}

impl Engine {
    /// Load an HLO-text artifact plus its metadata JSON
    /// (`<stem>.meta.json` next to it).
    pub fn load(hlo_path: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse HLO {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        // Metadata: <stem>.meta.json next to <stem>.hlo.txt.
        let stem = hlo_path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_suffix(".hlo.txt"))
            .ok_or_else(|| anyhow!("artifact path must end in .hlo.txt: {hlo_path:?}"))?;
        let meta_path = hlo_path.with_file_name(format!("{stem}.meta.json"));
        let meta_src = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?}"))?;
        let meta = crate::util::json::parse(&meta_src).map_err(|e| anyhow!("meta json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("meta missing '{k}'"))
        };
        Ok(Engine {
            client,
            exe,
            h: get("h")?,
            w: get("w")?,
            c: get("c")?,
            n_classes: get("n_classes")?,
        })
    }

    /// Run one dense inference: input is a dense `h × w × c` f32 buffer
    /// (channel-minor); returns the logits.
    pub fn infer_dense(&self, dense: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(dense.len() == self.h * self.w * self.c, "bad input size");
        let input = xla::Literal::vec1(dense)
            .reshape(&[self.h as i64, self.w as i64, self.c as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True ⇒ 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let logits = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(logits.len() == self.n_classes, "logit arity");
        Ok(logits)
    }

    /// Run one inference on a sparse map (densifies at the boundary — this
    /// engine is the *dense* platform model).
    pub fn infer_sparse(&self, m: &crate::sparse::SparseMap<f32>) -> Result<Vec<f32>> {
        self.infer_dense(&m.to_dense())
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

/// Default artifact directory (next to the workspace root).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("ESDA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when the AOT artifacts for `stem` exist (tests skip gracefully
/// otherwise, so `cargo test` passes before `make artifacts`).
pub fn artifact_available(stem: &str) -> bool {
    artifacts_dir().join(format!("{stem}.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke: client construction works in this environment.
    #[test]
    fn pjrt_cpu_client_constructs() {
        let c = xla::PjRtClient::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
    }

    /// Full artifact round-trip — only once `make artifacts` has run.
    #[test]
    fn engine_loads_and_infers_if_artifacts_present() {
        let stem = "tiny_nmnist";
        if !artifact_available(stem) {
            eprintln!("skipping: artifacts/{stem}.hlo.txt not built yet");
            return;
        }
        let eng = Engine::load(&artifacts_dir().join(format!("{stem}.hlo.txt"))).unwrap();
        let dense = vec![0f32; eng.h * eng.w * eng.c];
        let logits = eng.infer_dense(&dense).unwrap();
        assert_eq!(logits.len(), eng.n_classes);
    }
}
