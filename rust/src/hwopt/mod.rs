//! Sparsity-aware algorithm/hardware co-optimization — paper §3.4.
//!
//! - [`stats`]: per-layer spatial (S_s) and kernel (S_k) sparsity
//!   statistics collected from dataset samples (the paper collects these
//!   "from all the samples in the dataset").
//! - [`cost`]: the Eqn. 5 latency / BRAM / DSP model per dataflow module,
//!   extended with FF/LUT regressions and SLB buffer costs.
//! - [`allocate`]: the Eqn. 6 solver — minimize the pipeline bottleneck
//!   latency subject to DSP and BRAM budgets, over per-layer parallel
//!   factors (exact min-bottleneck via candidate-latency search; checked
//!   against an exhaustive reference on small programs).
//! - [`power`]: power/energy model calibrated by least squares against the
//!   paper's Table 1 rows.
pub mod stats;
pub mod cost;
pub mod allocate;
pub mod power;

pub use allocate::{allocate, AllocResult, Budget};
pub use cost::{op_costs, OpCost};
pub use stats::{collect_stats, LayerStats};
