//! Event-camera substrate: AER events, synthetic dataset generation, and
//! 2D representation construction.
//!
//! The paper evaluates on five event datasets (DvsGesture, RoShamBo17,
//! ASL-DVS, N-MNIST, N-Caltech101) that are not redistributable here, so
//! this module provides a **synthetic event generator** whose per-dataset
//! profiles match the published spatial resolutions and input nonzero
//! ratios (Fig. 12: 1.1%–23.1%). Scene models emit AER events from moving
//! shapes exactly the way a DVS does — intensity edges in motion produce
//! polarity-signed events — so the *spatial sparsity structure* that every
//! downstream result depends on is preserved (see DESIGN.md §2).
//!
//! The same generated datasets are consumed by the python training path via
//! the binary container in [`io`] (`esda gen-data` → `artifacts/data/`), so
//! training and hardware simulation see identical inputs.
pub mod aer;
pub mod synth;
pub mod profile;
pub mod repr;
pub mod io;

pub use aer::{Event, EventSlice};
pub use profile::DatasetProfile;
