//! Hardware stream fabric: items, bounded FIFOs, and the shared fabric the
//! modules communicate through.
//!
//! A FIFO models an AXI-stream-like channel with a compile-time depth.
//! One item transfer per clock edge per endpoint; `ready` = not full,
//! `valid` = not empty — the handshake of Eqn. 1's token-feature interface.

use crate::sparse::Token;
use std::collections::VecDeque;

/// One beat on a channel.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    /// Token + int8 feature vector (the unified sparse token-feature
    /// interface).
    Feat { t: Token, f: Vec<i8> },
    /// A gathered k×k window: output token + (kernel-offset, feature)
    /// pairs in offset order — the SLB → compute-module stream (§3.3.3).
    Window { t: Token, offs: Vec<(u8, Vec<i8>)> },
    /// End-of-stream marker (the `.end` flag of Eqn. 1).
    End,
    /// Classifier output (PoolFc → sink).
    Logits(Vec<i32>),
}

impl Item {
    pub fn is_end(&self) -> bool {
        matches!(self, Item::End)
    }
}

/// Bounded FIFO channel.
#[derive(Debug)]
pub struct Fifo {
    pub cap: usize,
    q: VecDeque<Item>,
    /// Cumulative counters for occupancy statistics.
    pub pushes: u64,
    pub max_occupancy: usize,
    /// Pushes + successful pops (event-skip activity signal).
    pub transfers: u64,
}

impl Fifo {
    pub fn new(cap: usize) -> Fifo {
        assert!(cap >= 1);
        Fifo { cap, q: VecDeque::with_capacity(cap), pushes: 0, max_occupancy: 0, transfers: 0 }
    }

    #[inline]
    pub fn can_push(&self) -> bool {
        self.q.len() < self.cap
    }

    #[inline]
    pub fn push(&mut self, item: Item) {
        debug_assert!(self.can_push(), "push on full FIFO");
        self.q.push_back(item);
        self.pushes += 1;
        self.transfers += 1;
        self.max_occupancy = self.max_occupancy.max(self.q.len());
    }

    #[inline]
    pub fn peek(&self) -> Option<&Item> {
        self.q.front()
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Item> {
        let item = self.q.pop_front();
        if item.is_some() {
            self.transfers += 1;
        }
        item
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.q.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Channel id into the fabric.
pub type ChanId = usize;

/// The set of channels a pipeline's modules communicate through.
#[derive(Debug, Default)]
pub struct Fabric {
    pub chans: Vec<Fifo>,
    /// Monotone counter of channel transfers (pushes + pops) — the
    /// scheduler's cheap "did anything move this cycle" signal for the
    /// event-skip fast path (§Perf).
    pub activity: u64,
}

impl Fabric {
    pub fn add_chan(&mut self, cap: usize) -> ChanId {
        self.chans.push(Fifo::new(cap));
        self.chans.len() - 1
    }
    #[inline]
    pub fn chan(&mut self, id: ChanId) -> &mut Fifo {
        &mut self.chans[id]
    }

    /// Total transfers across all channels (pushes + successful pops).
    pub fn total_transfers(&self) -> u64 {
        self.chans.iter().map(|c| c.transfers).sum()
    }
    #[inline]
    pub fn can_push(&self, id: ChanId) -> bool {
        self.chans[id].can_push()
    }
    #[inline]
    pub fn peek(&self, id: ChanId) -> Option<&Item> {
        self.chans[id].peek()
    }
}

/// Per-module activity counters (bottleneck analysis, Fig. 13 / §Perf).
#[derive(Debug, Default, Clone)]
pub struct ModStats {
    /// Cycles doing useful work (compute countdown active).
    pub busy: u64,
    /// Cycles stalled waiting for input (starved).
    pub stall_in: u64,
    /// Cycles stalled on output backpressure.
    pub stall_out: u64,
    /// Items consumed / produced.
    pub consumed: u64,
    pub produced: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_bounded_fifo_order() {
        let mut f = Fifo::new(2);
        assert!(f.can_push());
        f.push(Item::End);
        f.push(Item::Logits(vec![1]));
        assert!(!f.can_push());
        assert!(f.peek().unwrap().is_end());
        assert!(f.pop().unwrap().is_end());
        assert_eq!(f.pop(), Some(Item::Logits(vec![1])));
        assert_eq!(f.pop(), None);
        assert_eq!(f.pushes, 2);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    fn fabric_allocates_channels() {
        let mut fab = Fabric::default();
        let a = fab.add_chan(4);
        let b = fab.add_chan(8);
        assert_ne!(a, b);
        fab.chan(a).push(Item::End);
        assert!(fab.peek(a).unwrap().is_end());
        assert!(fab.peek(b).is_none());
    }
}
