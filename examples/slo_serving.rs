// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Deadline-aware serving demo: a recorded dataset replayed as a live
//! stream, with a per-request latency SLO driving admission.
//!
//! The run generates a small `.esda` dataset, then serves it three ways:
//! 1. replay at 1× with a generous SLO — everything lands in deadline,
//! 2. replay **time-compressed** (speed ≫ 1) through a deliberately slow
//!    replica with a tight SLO — requests expire at the ingress and at
//!    the worker pop; the report separates those deadline drops from
//!    queue-full drops,
//! 3. a two-class pool (fast + slow) under the same pressure — the
//!    cost-aware router sheds predicted-infeasible requests *before*
//!    they occupy a replica, and the per-class table shows where the
//!    deadline drops landed.
//!
//! Run: `cargo run --release --example slo_serving -- --dataset n_mnist`

use esda::coordinator::{
    run_pool_source, run_server_source, Backend, BackendError, Classification, Functional,
    ReplaySource, ReplicaPool, ReplicaSpec, ServerConfig, ServerResult,
};
use esda::events::{io::generate_dataset_files, repr::histogram2_norm, DatasetProfile};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::stats::fmt_secs;
use esda::util::Rng;
use std::time::Duration;

/// A deliberately slow backend so deadlines actually bite.
struct Throttled {
    inner: Functional,
    delay: Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled-functional"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

fn report(label: &str, r: &ServerResult) {
    let m = &r.metrics;
    let e2e = m.e2e_percentiles();
    println!("== {label} ==");
    println!(
        "  {} served / {} offered | e2e p50 {} p95 {} | {:.0} req/s",
        m.total,
        m.offered(),
        fmt_secs(e2e.p50),
        fmt_secs(e2e.p95),
        m.throughput(),
    );
    if let Some(line) = esda::report::slo_line(m) {
        println!("  {line}");
    }
    println!();
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let name = args.get_or("dataset", "n_mnist");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);

    // A small recorded dataset to replay (self-contained demo; in
    // production this is a real capture).
    let dir = std::env::temp_dir().join(format!("esda_slo_demo_{}", std::process::id()));
    let (_train, test) =
        generate_dataset_files(&profile, &dir, 2, 3, 7).expect("generate replay dataset");
    println!("replaying {} as a live stream\n", test.display());
    let open = |speed: f64| ReplaySource::open(&test, speed).expect("open replay");

    // 1: real-time replay, generous SLO — the SLO machinery is inert.
    let cfg = ServerConfig {
        queue_depth: 8,
        slo: Some(Duration::from_secs(2)),
        ..Default::default()
    };
    let backend = Functional::new(qnet.clone());
    let r = run_server_source(Box::new(open(1.0)), &backend, &cfg).expect("serve");
    report("replay @1x, SLO 2 s — unloaded, everything in deadline", &r);

    // 2: time-compressed replay into one slow replica, tight SLO —
    // ingress expiries and pop-time expiries shed the doomed work.
    let cfg = ServerConfig {
        queue_depth: 4,
        slo: Some(Duration::from_millis(30)),
        ..Default::default()
    };
    let slow = Throttled { inner: Functional::new(qnet.clone()), delay: Duration::from_millis(8) };
    let r = run_server_source(Box::new(open(500.0)), &slow, &cfg).expect("serve");
    report("replay @500x into a slow replica, SLO 30 ms — deadline shedding", &r);

    // 3: fast + slow classes under the same pressure — the router sheds
    // predicted-infeasible requests before they occupy a replica.
    let (qf, qs) = (qnet.clone(), qnet);
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::new("fast", 1, 4, move |_| Ok(Box::new(Functional::new(qf.clone())))),
        ReplicaSpec::new("slow", 1, 1, move |_| {
            Ok(Box::new(Throttled {
                inner: Functional::new(qs.clone()),
                delay: Duration::from_millis(8),
            }))
        }),
    ])
    .expect("pool build");
    let cfg = ServerConfig {
        queue_depth: 4,
        slo: Some(Duration::from_millis(30)),
        ..Default::default()
    };
    let r = run_pool_source(Box::new(open(500.0)), &pool, &cfg).expect("pool serve");
    report("same pressure, fast+slow pool — router-level SLO shedding", &r);
    println!("{}", esda::report::pool_table(&r.metrics).render());

    std::fs::remove_dir_all(&dir).ok();
}
