//! Figure 12: spatial sparsity of standard vs submanifold convolution on
//! every event dataset, at every feature resolution of the network's
//! downsample ladder, plus the accuracy comparison from the training run.
//!
//! Regenerates the paper's figure as a text table: x-axis = feature
//! resolution, series = {standard conv NZ%, submanifold conv NZ%}.
//! Expected shape (paper §4.2): submanifold stays near the input sparsity
//! while standard dilates toward dense — up to 3.4× sparser on ASL-DVS.

use esda::events::{repr::histogram2, DatasetProfile};
use esda::model::graph::Op;
use esda::model::NetworkSpec;
use esda::report::Table;
use esda::sparse::Bitmap;
use esda::util::Rng;

/// Propagate one input bitmap through the op ladder under both rules,
/// recording NZ ratio at each resolution stage (input of each stage).
fn propagate(spec: &NetworkSpec, input: &Bitmap) -> Vec<(usize, usize, f64, f64)> {
    let mut sub = input.clone();
    let mut std_ = input.clone();
    let mut out = vec![(sub.w, sub.h, sub.nz_ratio(), std_.nz_ratio())];
    for op in spec.ops() {
        match op {
            Op::ConvKxK { k, stride, .. } | Op::DwConv { k, stride, .. } => {
                if stride == 1 {
                    // submanifold: identity; standard: dilation.
                    std_ = std_.dilate(k);
                } else {
                    sub = sub.downsample_sparse(2);
                    std_ = std_.downsample_standard(k, 2);
                    out.push((sub.w, sub.h, sub.nz_ratio(), std_.nz_ratio()));
                }
            }
            _ => {}
        }
    }
    out
}

fn main() {
    println!("# Fig. 12 — standard vs submanifold activation sparsity\n");
    let n_samples = 12;
    for profile in DatasetProfile::all() {
        // The paper uses MobileNetV2 for the large datasets and the
        // customized ladder for the small ones (§4.2).
        let spec = if profile.w.min(profile.h) >= 128 {
            NetworkSpec::mobilenet_v2_05("mbv2", profile.w, profile.h, profile.n_classes)
        } else {
            NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes)
        };
        let mut rng = Rng::new(0xF16_12);
        // Average stage ratios over samples.
        let mut acc: Vec<(usize, usize, f64, f64)> = Vec::new();
        for i in 0..n_samples {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            let bm = histogram2(&es, profile.w, profile.h).bitmap();
            let stages = propagate(&spec, &bm);
            if acc.is_empty() {
                acc = stages;
            } else {
                for (a, s) in acc.iter_mut().zip(stages) {
                    a.2 += s.2;
                    a.3 += s.3;
                }
            }
        }
        for a in acc.iter_mut() {
            a.2 /= n_samples as f64;
            a.3 /= n_samples as f64;
        }
        let mut t = Table::new(
            &format!("{} ({})", profile.name, spec.name),
            &["resolution", "submanifold NZ%", "standard NZ%", "ratio (std/sub)"],
        );
        for (w, h, sub, std_) in &acc {
            t.row(vec![
                format!("{w}×{h}"),
                format!("{:.1}", sub * 100.0),
                format!("{:.1}", std_ * 100.0),
                format!("{:.1}×", std_ / sub.max(1e-9)),
            ]);
        }
        println!("{}", t.render());
    }
    // Accuracy legend (paper prints accuracies in Fig. 12's legends).
    if let Ok(src) = std::fs::read_to_string("artifacts/train_summary.json") {
        if let Ok(j) = esda::util::json::parse(&src) {
            println!("trained accuracies (synthetic datasets, submanifold nets):");
            if let Some(obj) = j.as_obj() {
                for (ds, v) in obj {
                    println!(
                        "  {ds}: test acc {:.3}",
                        v.get("test_acc").and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
                    );
                }
            }
        }
    } else {
        println!("(train_summary.json missing — run `make artifacts` for the accuracy legend)");
    }
}
