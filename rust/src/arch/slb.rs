//! Sparse Line Buffer modules — paper §3.3.4 (stride 1, Fig. 7) and
//! §3.3.5 (stride 2, Fig. 8).
//!
//! The SLB buffers `k` rows of sparse features plus a token FIFO and a
//! small occupancy bitmap. The head token denotes the next output window
//! center; the *tail* (most recently seen input, including one waiting at
//! the input — the paper's deadlock-freedom argument relies on the arrival
//! of a later token proving earlier rows complete) decides when the window
//! has all its data:
//!
//! - stride 1 (Eqn. 3): output tokens = input tokens; the head is valid
//!   when the tail's ravel order passes the window's bottom-right corner
//!   `(h.x+u, h.y+u)` (clipped), or the stream has ended.
//! - stride 2 (Eqn. 4): candidate output tokens are kept in two FIFOs fed
//!   by even/odd input rows; a token-merge unit emits the smaller of the
//!   two downsampled heads once the tail passes the corresponding 2×2
//!   grid's corner.
//!
//! Output is the [`Item::Window`] stream: the output token plus the
//! (kernel-offset, feature) pairs of the nonzero neighbours — the "kernel
//! offset stream" consumed by the k×k compute module.

use super::module::Module;
use super::stream::{ChanId, Fabric, Item, ModStats};
use crate::sparse::Token;
use std::collections::{HashMap, VecDeque};

/// Shared buffer state for both strides.
struct RowBuf {
    /// (x, y) → feature (only rows within the live window are retained).
    feats: HashMap<(u16, u16), Vec<i8>>,
}

impl RowBuf {
    fn new() -> Self {
        RowBuf { feats: HashMap::new() }
    }
    fn insert(&mut self, t: Token, f: Vec<i8>) {
        self.feats.insert((t.x, t.y), f);
    }
    /// Drop all rows strictly below `min_y`.
    fn evict_below(&mut self, min_y: isize) {
        if min_y <= 0 {
            return;
        }
        self.feats.retain(|&(_, y), _| (y as isize) >= min_y);
    }
    /// Gather the k×k window centered per `origin` (top-left input coord of
    /// the window): returns (offset, feature) pairs in offset order.
    fn gather(&self, ox: isize, oy: isize, k: usize, w: usize, h: usize) -> Vec<(u8, Vec<i8>)> {
        let mut out = Vec::new();
        for dy in 0..k as isize {
            for dx in 0..k as isize {
                let x = ox + dx;
                let y = oy + dy;
                if x < 0 || y < 0 || x as usize >= w || y as usize >= h {
                    continue;
                }
                if let Some(f) = self.feats.get(&(x as u16, y as u16)) {
                    out.push(((dy as usize * k + dx as usize) as u8, f.clone()));
                }
            }
        }
        out
    }
}

/// Effective tail: the later of the last-accepted token and the token
/// currently presented at the input (not yet consumed). `None` means no
/// information; `end_seen` short-circuits validity.
fn effective_tail(last: Option<Token>, input_peek: Option<&Item>) -> (Option<Token>, bool) {
    match input_peek {
        Some(Item::End) => (last, true),
        Some(Item::Feat { t, .. }) => {
            let t = *t;
            (Some(match last {
                Some(l) if l > t => l,
                _ => t,
            }), false)
        }
        _ => (last, false),
    }
}

// ---------------------------------------------------------------------------
// Stride 1
// ---------------------------------------------------------------------------

pub struct SlbS1 {
    name: String,
    in_ch: ChanId,
    out_ch: ChanId,
    k: usize,
    u: usize,
    w: usize,
    h: usize,
    buf: RowBuf,
    toks: VecDeque<Token>,
    last_in: Option<Token>,
    in_end: bool,
    stats: ModStats,
    done: bool,
}

impl SlbS1 {
    pub fn new(
        name: impl Into<String>,
        in_ch: ChanId,
        out_ch: ChanId,
        k: usize,
        w: usize,
        h: usize,
    ) -> Self {
        assert!(k % 2 == 1 && k >= 3);
        SlbS1 {
            name: name.into(),
            in_ch,
            out_ch,
            k,
            u: (k - 1) / 2,
            w,
            h,
            buf: RowBuf::new(),
            toks: VecDeque::new(),
            last_in: None,
            in_end: false,
            stats: ModStats::default(),
            done: false,
        }
    }

    /// Window corner (bottom-right, clipped) whose arrival proves the
    /// head's window complete.
    fn corner_ravel(&self, head: Token) -> usize {
        let cx = (head.x as usize + self.u).min(self.w - 1);
        let cy = (head.y as usize + self.u).min(self.h - 1);
        cy * self.w + cx
    }

    fn head_valid(&self, fab: &Fabric) -> bool {
        let head = match self.toks.front() {
            Some(h) => *h,
            None => return false,
        };
        if self.in_end {
            return true;
        }
        let (tail, end_at_input) = effective_tail(self.last_in, fab.peek(self.in_ch));
        if end_at_input {
            return true;
        }
        match tail {
            Some(t) => t.ravel(self.w) > self.corner_ravel(head),
            None => false,
        }
    }
}

impl Module for SlbS1 {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        // Emit phase: one window (or End) per cycle.
        let mut emitted = false;
        if fab.can_push(self.out_ch) {
            if self.head_valid(fab) {
                let head = *self.toks.front().unwrap();
                let offs = self.buf.gather(
                    head.x as isize - self.u as isize,
                    head.y as isize - self.u as isize,
                    self.k,
                    self.w,
                    self.h,
                );
                debug_assert!(!offs.is_empty(), "window must contain its own center");
                fab.chan(self.out_ch).push(Item::Window { t: head, offs });
                self.toks.pop_front();
                self.buf.evict_below(head.y as isize - self.u as isize);
                self.stats.produced += 1;
                self.stats.busy += 1;
                emitted = true;
            } else if self.in_end && self.toks.is_empty() && !self.done {
                fab.chan(self.out_ch).push(Item::End);
                self.done = true;
                self.stats.produced += 1;
                emitted = true;
            }
        } else {
            self.stats.stall_out += 1;
        }

        // Intake phase: the paper's ready condition — accept only while the
        // new token still lies within the buffered rows of the current head
        // (r = t.y − h.y ≤ u); unconditionally when no head is pending.
        if !self.in_end {
            let accept = match (fab.peek(self.in_ch), self.toks.front()) {
                (Some(Item::Feat { t, .. }), Some(h)) => {
                    t.y as isize - h.y as isize <= self.u as isize
                }
                (Some(Item::Feat { .. }), None) => true,
                (Some(Item::End), _) => true,
                _ => false,
            };
            if accept {
                match fab.chan(self.in_ch).pop() {
                    Some(Item::Feat { t, f }) => {
                        self.buf.insert(t, f);
                        self.toks.push_back(t);
                        self.last_in = Some(t);
                        self.stats.consumed += 1;
                    }
                    Some(Item::End) => {
                        self.in_end = true;
                        self.stats.consumed += 1;
                    }
                    _ => unreachable!(),
                }
            } else if !emitted {
                self.stats.stall_in += 1;
            }
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// Stride 2
// ---------------------------------------------------------------------------

pub struct SlbS2 {
    name: String,
    in_ch: ChanId,
    out_ch: ChanId,
    k: usize,
    pad: usize,
    w: usize,
    h: usize,
    ow: usize,
    buf: RowBuf,
    /// Downsampled candidate tokens from even / odd input rows (paper's two
    /// token FIFOs), consecutive duplicates merged at insert.
    even_q: VecDeque<Token>,
    odd_q: VecDeque<Token>,
    last_in: Option<Token>,
    in_end: bool,
    stats: ModStats,
    done: bool,
}

impl SlbS2 {
    pub fn new(
        name: impl Into<String>,
        in_ch: ChanId,
        out_ch: ChanId,
        k: usize,
        w: usize,
        h: usize,
    ) -> Self {
        assert!(k % 2 == 1 && k >= 3);
        SlbS2 {
            name: name.into(),
            in_ch,
            out_ch,
            k,
            pad: (k - 1) / 2,
            w,
            h,
            ow: (w + 1) / 2,
            buf: RowBuf::new(),
            even_q: VecDeque::new(),
            odd_q: VecDeque::new(),
            last_in: None,
            in_end: false,
            stats: ModStats::default(),
            done: false,
        }
    }

    /// Token-merge unit (Eqn. 4): the next output token is the smaller of
    /// the two downsampled heads.
    fn merged_head(&self) -> Option<Token> {
        match (self.even_q.front(), self.odd_q.front()) {
            (Some(&e), Some(&o)) => Some(if o.ravel(self.ow) < e.ravel(self.ow) { o } else { e }),
            (Some(&e), None) => Some(e),
            (None, Some(&o)) => Some(o),
            (None, None) => None,
        }
    }

    /// Bottom-right input corner of the candidate's window: covers both the
    /// 2×2 grid (token rule) and the k×k window (feature rule); for k=3,
    /// pad=1 they coincide at (2gx+1, 2gy+1).
    fn corner_ravel(&self, g: Token) -> usize {
        let cx = (2 * g.x as usize + self.k - 1 - self.pad).min(self.w - 1);
        let cy = (2 * g.y as usize + self.k - 1 - self.pad).min(self.h - 1);
        cy * self.w + cx
    }

    fn head_valid(&self, fab: &Fabric) -> bool {
        let g = match self.merged_head() {
            Some(g) => g,
            None => return false,
        };
        if self.in_end {
            return true;
        }
        let (tail, end_at_input) = effective_tail(self.last_in, fab.peek(self.in_ch));
        if end_at_input {
            return true;
        }
        match tail {
            Some(t) => t.ravel(self.w) > self.corner_ravel(g),
            None => false,
        }
    }

    fn pop_head(&mut self, g: Token) {
        if self.even_q.front() == Some(&g) {
            self.even_q.pop_front();
        }
        if self.odd_q.front() == Some(&g) {
            self.odd_q.pop_front();
        }
    }
}

impl Module for SlbS2 {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        let mut emitted = false;
        if fab.can_push(self.out_ch) {
            if self.head_valid(fab) {
                let g = self.merged_head().unwrap();
                let offs = self.buf.gather(
                    2 * g.x as isize - self.pad as isize,
                    2 * g.y as isize - self.pad as isize,
                    self.k,
                    self.w,
                    self.h,
                );
                debug_assert!(!offs.is_empty(), "stride-2 window must contain a nonzero");
                fab.chan(self.out_ch).push(Item::Window { t: g, offs });
                self.pop_head(g);
                self.buf.evict_below(2 * g.y as isize - self.pad as isize);
                self.stats.produced += 1;
                self.stats.busy += 1;
                emitted = true;
            } else if self.in_end && self.even_q.is_empty() && self.odd_q.is_empty() && !self.done {
                fab.chan(self.out_ch).push(Item::End);
                self.done = true;
                self.stats.produced += 1;
                emitted = true;
            }
        } else {
            self.stats.stall_out += 1;
        }

        if !self.in_end {
            // Ready: new input must stay within k input rows of the pending
            // head's grid (bounds the row buffer as in the stride-1 case).
            let accept = match (fab.peek(self.in_ch), self.merged_head()) {
                (Some(Item::Feat { t, .. }), Some(g)) => {
                    t.y as isize - (2 * g.y as isize) <= (self.k - 1) as isize
                }
                (Some(Item::Feat { .. }), None) => true,
                (Some(Item::End), _) => true,
                _ => false,
            };
            if accept {
                match fab.chan(self.in_ch).pop() {
                    Some(Item::Feat { t, f }) => {
                        self.buf.insert(t, f);
                        let cand = Token::new(t.x / 2, t.y / 2);
                        let q = if t.y % 2 == 0 { &mut self.even_q } else { &mut self.odd_q };
                        if q.back() != Some(&cand) {
                            q.push_back(cand);
                        }
                        self.last_in = Some(t);
                        self.stats.consumed += 1;
                    }
                    Some(Item::End) => {
                        self.in_end = true;
                        self.stats.consumed += 1;
                    }
                    _ => unreachable!(),
                }
            } else if !emitted {
                self.stats.stall_in += 1;
            }
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseMap;
    use crate::util::propcheck::check;

    /// Drive an SLB standalone: feed a sparse map, collect windows, check
    /// tokens and gathered neighbourhoods against a direct computation.
    fn run_slb(input: &SparseMap<i8>, k: usize, stride: usize) -> Vec<(Token, Vec<(u8, Vec<i8>)>)> {
        let mut fab = Fabric::default();
        let in_ch = fab.add_chan(2);
        let out_ch = fab.add_chan(2);
        let mut slb: Box<dyn Module> = if stride == 1 {
            Box::new(SlbS1::new("slb", in_ch, out_ch, k, input.w, input.h))
        } else {
            Box::new(SlbS2::new("slb", in_ch, out_ch, k, input.w, input.h))
        };
        let mut feed = input.tokens.iter().enumerate();
        let mut next = feed.next();
        let mut sent_end = false;
        let mut out = Vec::new();
        let mut cycles = 0u64;
        let mut finished = false;
        while !finished && cycles < 2_000_000 {
            if fab.can_push(in_ch) {
                if let Some((i, t)) = next {
                    fab.chan(in_ch).push(Item::Feat { t: *t, f: input.feat(i).to_vec() });
                    next = feed.next();
                } else if !sent_end {
                    fab.chan(in_ch).push(Item::End);
                    sent_end = true;
                }
            }
            slb.step(&mut fab);
            while let Some(item) = fab.chan(out_ch).pop() {
                match item {
                    Item::Window { t, offs } => out.push((t, offs)),
                    Item::End => finished = true,
                    other => panic!("unexpected {other:?}"),
                }
            }
            cycles += 1;
        }
        assert!(finished, "SLB deadlocked or overran (stride {stride})");
        out
    }

    fn random_i8_map(
        g: &mut crate::util::propcheck::Gen,
        w: usize,
        h: usize,
        c: usize,
        p: f64,
    ) -> SparseMap<i8> {
        let mut m = SparseMap::empty(w, h, c);
        for y in 0..h {
            for x in 0..w {
                if g.chance(p) {
                    let f: Vec<i8> = (0..c).map(|_| g.i64(-100, 100) as i8).collect();
                    m.push(Token::new(x as u16, y as u16), &f);
                }
            }
        }
        m
    }

    #[test]
    fn s1_emits_every_token_in_order_with_correct_windows() {
        check("SLB s1 token identity + window contents", 48, |g| {
            let w = g.usize(3, 16);
            let h = g.usize(3, 16);
            let c = g.usize(1, 3);
            let m = random_i8_map(g, w, h, c, 0.35);
            let out = run_slb(&m, 3, 1);
            // Submanifold: output tokens == input tokens, in order.
            let toks: Vec<Token> = out.iter().map(|(t, _)| *t).collect();
            assert_eq!(toks, m.tokens);
            let bm = m.bitmap();
            for (t, offs) in &out {
                // Expected offsets: every in-bounds nonzero neighbour.
                let mut want = Vec::new();
                for dy in 0..3isize {
                    for dx in 0..3isize {
                        let x = t.x as isize + dx - 1;
                        let y = t.y as isize + dy - 1;
                        if x >= 0
                            && y >= 0
                            && (x as usize) < w
                            && (y as usize) < h
                            && bm.get(x as usize, y as usize)
                        {
                            want.push((dy * 3 + dx) as u8);
                        }
                    }
                }
                let got: Vec<u8> = offs.iter().map(|(o, _)| *o).collect();
                assert_eq!(got, want, "token ({},{})", t.x, t.y);
                // Features must match the map.
                for (o, f) in offs {
                    let dy = (*o as usize / 3) as isize - 1;
                    let dx = (*o as usize % 3) as isize - 1;
                    let idx =
                        m.find((t.x as isize + dx) as u16, (t.y as isize + dy) as u16).unwrap();
                    assert_eq!(f.as_slice(), m.feat(idx));
                }
            }
        });
    }

    #[test]
    fn s2_tokens_match_downsample_rule_in_order() {
        check("SLB s2 token merge = grid rule", 48, |g| {
            let w = g.usize(4, 16);
            let h = g.usize(4, 16);
            let m = random_i8_map(g, w, h, 2, 0.3);
            let out = run_slb(&m, 3, 2);
            let toks: Vec<Token> = out.iter().map(|(t, _)| *t).collect();
            let want: Vec<Token> = crate::sparse::conv::downsample_tokens(&m.bitmap());
            assert_eq!(toks, want);
            // Windows gather the k×k neighbourhood around (2gx, 2gy).
            let bm = m.bitmap();
            for (t, offs) in &out {
                let mut want_offs = Vec::new();
                for dy in 0..3isize {
                    for dx in 0..3isize {
                        let x = 2 * t.x as isize + dx - 1;
                        let y = 2 * t.y as isize + dy - 1;
                        if x >= 0
                            && y >= 0
                            && (x as usize) < w
                            && (y as usize) < h
                            && bm.get(x as usize, y as usize)
                        {
                            want_offs.push((dy * 3 + dx) as u8);
                        }
                    }
                }
                let got: Vec<u8> = offs.iter().map(|(o, _)| *o).collect();
                assert_eq!(got, want_offs, "grid token ({},{})", t.x, t.y);
            }
        });
    }

    #[test]
    fn s1_handles_k5() {
        check("SLB s1 k=5 windows", 24, |g| {
            let w = g.usize(5, 14);
            let h = g.usize(5, 14);
            let m = random_i8_map(g, w, h, 1, 0.3);
            let out = run_slb(&m, 5, 1);
            assert_eq!(out.len(), m.nnz());
            let bm = m.bitmap();
            for (t, offs) in &out {
                let n_want = (0..25)
                    .filter(|&o| {
                        let dy = o as isize / 5 - 2;
                        let dx = o as isize % 5 - 2;
                        let x = t.x as isize + dx;
                        let y = t.y as isize + dy;
                        x >= 0
                            && y >= 0
                            && (x as usize) < w
                            && (y as usize) < h
                            && bm.get(x as usize, y as usize)
                    })
                    .count();
                assert_eq!(offs.len(), n_want);
            }
        });
    }

    #[test]
    fn empty_input_just_ends() {
        let m: SparseMap<i8> = SparseMap::empty(8, 8, 1);
        assert_eq!(run_slb(&m, 3, 1).len(), 0);
        assert_eq!(run_slb(&m, 3, 2).len(), 0);
    }

    #[test]
    fn dense_input_no_deadlock() {
        let mut m: SparseMap<i8> = SparseMap::empty(9, 7, 1);
        for y in 0..7 {
            for x in 0..9 {
                m.push(Token::new(x, y), &[1]);
            }
        }
        let out = run_slb(&m, 3, 1);
        assert_eq!(out.len(), 63);
        // Interior windows must have all 9 offsets.
        let center = out.iter().find(|(t, _)| t.x == 4 && t.y == 3).unwrap();
        assert_eq!(center.1.len(), 9);
    }
}
