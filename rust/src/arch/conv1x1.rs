//! 1×1 (pointwise) convolution module — paper §3.3.1, Fig. 4.
//!
//! Tokens are relayed unchanged (submanifold by construction); the feature
//! vector is multiplied by the weight matrix held in on-chip ROM. The PE
//! array processes `pf` MACs per cycle, so one token occupies the module
//! for `ceil(cin·cout / pf)` cycles — the initiation interval the Eqn. 5
//! cost model assigns this layer.

use super::module::{pe_cycles, Countdown, Module};
use super::stream::{ChanId, Fabric, Item, ModStats};
use crate::sparse::quant::Requant;
use crate::sparse::Token;

pub struct Conv1x1Mod {
    name: String,
    in_ch: ChanId,
    out_ch: ChanId,
    cin: usize,
    cout: usize,
    pf: usize,
    w: Vec<i8>,
    b: Vec<i32>,
    rq: Requant,
    cd: Countdown,
    cur: Option<(Token, Vec<i8>)>,
    pending: Option<Item>,
    stats: ModStats,
    done: bool,
}

impl Conv1x1Mod {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        in_ch: ChanId,
        out_ch: ChanId,
        cin: usize,
        cout: usize,
        pf: usize,
        w: Vec<i8>,
        b: Vec<i32>,
        rq: Requant,
    ) -> Self {
        assert_eq!(w.len(), cin * cout);
        assert_eq!(b.len(), cout);
        Conv1x1Mod {
            name: name.into(),
            in_ch,
            out_ch,
            cin,
            cout,
            pf: pf.max(1),
            w,
            b,
            rq,
            cd: Countdown::default(),
            cur: None,
            pending: None,
            stats: ModStats::default(),
            done: false,
        }
    }

    fn compute(&self, f: &[i8]) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.cout);
        for co in 0..self.cout {
            let mut acc = self.b[co];
            for ci in 0..self.cin {
                acc += f[ci] as i32 * self.w[ci * self.cout + co] as i32;
            }
            out.push(self.rq.apply(acc));
        }
        out
    }
}

impl Module for Conv1x1Mod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        // 1. Drain pending output.
        if let Some(item) = self.pending.take() {
            if fab.can_push(self.out_ch) {
                if item.is_end() {
                    self.done = true;
                }
                fab.chan(self.out_ch).push(item);
                self.stats.produced += 1;
            } else {
                self.pending = Some(item);
                self.stats.stall_out += 1;
                return;
            }
        }
        // 2. Advance compute.
        if self.cd.busy() {
            self.stats.busy += 1;
            if self.cd.tick() {
                let (t, f) = self.cur.take().unwrap();
                self.pending = Some(Item::Feat { t, f: self.compute(&f) });
            }
            return;
        }
        // 3. Intake.
        if self.pending.is_none() {
            match fab.chan(self.in_ch).pop() {
                Some(Item::Feat { t, f }) => {
                    self.stats.consumed += 1;
                    self.cur = Some((t, f));
                    self.cd.start(pe_cycles(self.cin * self.cout, self.pf).max(1));
                }
                Some(Item::End) => {
                    self.stats.consumed += 1;
                    self.pending = Some(Item::End);
                }
                Some(other) => panic!("{}: unexpected item {other:?}", self.name),
                None => self.stats.stall_in += 1,
            }
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn next_event(&self) -> Option<u64> {
        if self.pending.is_some() {
            // Will attempt the push on the very next step — blocks skipping.
            Some(1)
        } else if self.cd.busy() {
            Some(self.cd.0)
        } else {
            None
        }
    }

    fn fast_forward(&mut self, k: u64) {
        debug_assert!(self.cd.0 > k);
        self.cd.0 -= k;
        self.stats.busy += k;
    }

    fn dsp(&self) -> usize {
        self.pf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::conv::conv1x1_i8;
    use crate::sparse::SparseMap;

    /// Drive a single module manually: feed a sparse map, collect output,
    /// compare against the functional reference bit-for-bit.
    #[test]
    fn matches_functional_reference() {
        let mut rng = crate::util::Rng::new(3);
        let (w, h, cin, cout) = (10, 8, 4, 6);
        let mut input: SparseMap<i8> = SparseMap::empty(w, h, cin);
        for y in 0..h {
            for x in 0..w {
                if rng.chance(0.3) {
                    let f: Vec<i8> = (0..cin).map(|_| rng.range_i64(-100, 100) as i8).collect();
                    input.push(Token::new(x as u16, y as u16), &f);
                }
            }
        }
        let wt: Vec<i8> = (0..cin * cout).map(|_| rng.range_i64(-30, 30) as i8).collect();
        let b: Vec<i32> = (0..cout).map(|_| rng.range_i64(-500, 500) as i32).collect();
        let rq = Requant::from_scale(0.01, 0, 127);

        let mut fab = Fabric::default();
        let cin_ch = fab.add_chan(4);
        let cout_ch = fab.add_chan(4);
        let mut m = Conv1x1Mod::new("c1", cin_ch, cout_ch, cin, cout, 4, wt.clone(), b.clone(), rq);

        let mut out: SparseMap<i8> = SparseMap::empty(w, h, cout);
        let mut feed = input.tokens.iter().enumerate();
        let mut next = feed.next();
        let mut sent_end = false;
        let mut cycles = 0u64;
        while !m.done() && cycles < 1_000_000 {
            // Feed input.
            if fab.can_push(cin_ch) {
                if let Some((i, t)) = next {
                    fab.chan(cin_ch).push(Item::Feat { t: *t, f: input.feat(i).to_vec() });
                    next = feed.next();
                } else if !sent_end {
                    fab.chan(cin_ch).push(Item::End);
                    sent_end = true;
                }
            }
            m.step(&mut fab);
            // Drain output.
            while let Some(item) = fab.chan(cout_ch).pop() {
                if let Item::Feat { t, f } = item {
                    out.push(t, &f);
                }
            }
            cycles += 1;
        }
        assert!(m.done(), "module did not finish");
        let expect = conv1x1_i8(&input, &wt, &b, cout, &rq);
        assert_eq!(out, expect);
        // II model: each token occupies ceil(cin*cout/pf) = 6 cycles.
        assert!(cycles as usize >= input.nnz() * 6, "cycles {cycles}");
    }
}
