"""Training pipeline (build-time only): train the submanifold networks on
the rust-generated synthetic datasets, evaluate accuracy (including the
standard-vs-submanifold comparison of Fig. 12), and export weights +
golden vectors for the rust side.

Usage (driven by `make artifacts`):
    python -m compile.train --data ../artifacts/data --out ../artifacts \
        --datasets n_mnist,roshambo17 --model compact --epochs 30
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import tensorio


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def accuracy(spec, params, xs, ys, batch=16):
    correct = 0
    for i in range(0, len(xs), batch):
        logits = M.forward_batch(spec, params, jnp.asarray(xs[i : i + batch]))
        correct += int((jnp.argmax(logits, axis=1) == jnp.asarray(ys[i : i + batch])).sum())
    return correct / len(xs)


def train_model(spec, xs, ys, epochs=30, lr=0.05, batch=16, seed=0, momentum=0.9, log=print):
    """Plain SGD + momentum on the masked-dense (≡ submanifold) network."""
    params = M.init_params(spec, jax.random.PRNGKey(seed))
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}

    @jax.jit
    def step(params, vel, xb, yb):
        def loss_fn(p):
            logits = M.forward_batch(spec, p, xb)
            return cross_entropy(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_vel = {k: momentum * vel[k] - lr * grads[k] for k in params}
        new_params = {k: params[k] + new_vel[k] for k in params}
        return new_params, new_vel, loss

    n = len(xs)
    rng = np.random.RandomState(seed)
    for epoch in range(epochs):
        order = rng.permutation(n)
        losses = []
        for i in range(0, n, batch):
            idx = order[i : i + batch]
            params, vel, loss = step(params, vel, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
            losses.append(float(loss))
        if epoch % 5 == 0 or epoch == epochs - 1:
            log(f"  epoch {epoch:3d}: loss {np.mean(losses):.4f}")
    return params


def export(spec, params, xs, out_dir, stem, n_golden=4, extra_meta=None):
    """Write weights (.esdw), metadata (.meta.json), and golden
    input/logit pairs for the rust cross-check."""
    os.makedirs(out_dir, exist_ok=True)
    tensors = {k: np.asarray(v, dtype=np.float32) for k, v in params.items()}
    # Golden vectors: exact f32 logits on real samples.
    golden_inputs = np.asarray(xs[:n_golden], dtype=np.float32)
    golden_logits = np.asarray(
        M.forward_batch(spec, params, jnp.asarray(golden_inputs)), dtype=np.float32
    )
    tensors["golden.inputs"] = golden_inputs
    tensors["golden.logits"] = golden_logits
    tensorio.write_tensors(os.path.join(out_dir, f"{stem}_weights.esdw"), tensors)
    meta = {
        "h": spec["h"],
        "w": spec["w"],
        "c": spec["cin"],
        "n_classes": spec["n_classes"],
        "model": spec["name"],
        "n_golden": int(n_golden),
    }
    meta.update(extra_meta or {})
    with open(os.path.join(out_dir, f"{stem}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", default="n_mnist,roshambo17")
    ap.add_argument("--model", default="compact")
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    summary = {}
    for ds in args.datasets.split(","):
        ds = ds.strip()
        train_path = os.path.join(args.data, f"{ds}_train.esda")
        test_path = os.path.join(args.data, f"{ds}_test.esda")
        if not os.path.exists(train_path):
            print(f"!! {train_path} missing — run `esda gen-data` first")
            continue
        xs, ys = D.load_split(train_path)
        xt, yt = D.load_split(test_path)
        n_classes = int(ys.max()) + 1
        h, w = xs.shape[1], xs.shape[2]
        spec = M.BUILDERS[args.model](w, h, n_classes)
        print(f"== {ds}: {len(xs)} train / {len(xt)} test, {w}x{h}, {n_classes} classes ==")
        params = train_model(spec, xs, ys, epochs=args.epochs, lr=args.lr, seed=args.seed)
        train_acc = accuracy(spec, params, xs, ys)
        test_acc = accuracy(spec, params, xt, yt)
        print(f"  accuracy: train {train_acc:.3f} test {test_acc:.3f}")
        stem = f"{args.model}_{ds}"
        export(spec, params, xs, args.out, stem,
               extra_meta={"train_acc": train_acc, "test_acc": test_acc})
        summary[ds] = {"train_acc": train_acc, "test_acc": test_acc, "stem": stem}
    with open(os.path.join(args.out, "train_summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
