"""L2 model tests: expansion mirrors graph.rs, pallas/ref forward equality,
shapes, and training smoke."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import tensorio

jax.config.update("jax_platform_name", "cpu")


def test_tiny_expansion_matches_rust():
    spec = M.tiny(16, 16, 3)
    ops = M.expand_ops(spec)
    kinds = [o["op"] for o in ops]
    # Mirror of graph.rs test `mbconv_expansion_shapes`.
    assert kinds == [
        "conv_kxk", "res_fork", "conv1x1", "dwconv", "conv1x1", "res_add",
        "conv1x1", "dwconv", "conv1x1", "global_pool", "fc",
    ]
    assert ops[0]["cout"] == 4 and ops[0]["cin"] == 2
    assert ops[2] == {"op": "conv1x1", "cin": 4, "cout": 8, "act": "relu6"}
    assert ops[4]["act"] == "none"
    assert ops[10] == {"op": "fc", "cin": 8, "cout": 3}


def test_mbv2_block_count_matches_rust():
    spec = M.mobilenet_v2_05(128, 128, 10)
    ops = M.expand_ops(spec)
    assert sum(1 for o in ops if o["op"] == "dwconv") == 17
    assert sum(1 for o in ops if o["op"] == "res_add") == 10


def test_param_shapes_align_with_ops():
    spec = M.compact(34, 34, 10)
    params = M.init_params(spec, jax.random.PRNGKey(0))
    for i, op in enumerate(M.expand_ops(spec)):
        ws, bs = M.op_param_shapes(op)
        if ws is None:
            assert f"op{i}.w" not in params
        else:
            assert params[f"op{i}.w"].shape == ws
            assert params[f"op{i}.b"].shape == bs


def _sample_input(seed, h, w, p=0.2):
    rng = np.random.default_rng(seed)
    mask = rng.random((h, w)) < p
    x = rng.standard_normal((h, w, 2)).astype(np.float32) * mask[..., None]
    return jnp.asarray(x)


def test_forward_pallas_equals_ref():
    spec = M.tiny(20, 20, 4)
    params = M.init_params(spec, jax.random.PRNGKey(1))
    for seed in range(3):
        x = _sample_input(seed, 20, 20)
        ref_logits = M.forward(spec, params, x, use_pallas=False)
        pk_logits = M.forward(spec, params, x, use_pallas=True)
        np.testing.assert_allclose(ref_logits, pk_logits, rtol=1e-4, atol=1e-4)


def test_forward_batch_shape():
    spec = M.tiny(16, 16, 5)
    params = M.init_params(spec, jax.random.PRNGKey(2))
    xs = jnp.stack([_sample_input(s, 16, 16) for s in range(4)])
    logits = M.forward_batch(spec, params, xs)
    assert logits.shape == (4, 5)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_empty_input_is_finite():
    spec = M.tiny(16, 16, 3)
    params = M.init_params(spec, jax.random.PRNGKey(3))
    x = jnp.zeros((16, 16, 2), jnp.float32)
    logits = M.forward(spec, params, x)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_training_reduces_loss_and_learns(tmp_path):
    """End-to-end micro-training on a separable toy problem."""
    from compile.train import train_model, accuracy

    spec = M.tiny(12, 12, 2)
    rng = np.random.default_rng(0)
    # Class 0: tokens in top half. Class 1: bottom half.
    xs, ys = [], []
    for i in range(40):
        cls = i % 2
        mask = np.zeros((12, 12), bool)
        rows = slice(0, 5) if cls == 0 else slice(7, 12)
        mask[rows] = rng.random((5, 12)) < 0.4
        x = rng.standard_normal((12, 12, 2)).astype(np.float32) * mask[..., None]
        xs.append(x)
        ys.append(cls)
    xs = np.stack(xs)
    ys = np.array(ys, np.int32)
    params = train_model(spec, xs, ys, epochs=18, lr=0.1, batch=8, log=lambda *_: None)
    acc = accuracy(spec, params, xs, ys)
    assert acc > 0.8, f"train accuracy {acc}"


def test_tensorio_roundtrip(tmp_path):
    path = tmp_path / "t.esdw"
    tensors = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([-128, 127], np.int8),
        "c": np.array([2**31 - 1], np.int32),
    }
    tensorio.write_tensors(path, tensors)
    back = tensorio.read_tensors(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
