// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Exec-engine throughput: the allocating per-op oracle (`classify_i8`)
//! vs. the compiled arena plan (`ExecPlan` + `ExecCtx`), and micro-batched
//! serving throughput at batch caps {1, 4, 16} — plus allocs-per-inference
//! for both paths (the arena must be at zero in steady state).
//!
//! Emits `BENCH_exec.json` at the repository root (override the path with
//! `ESDA_BENCH_OUT`) so the perf trajectory is tracked from PR 2 on:
//!
//! ```sh
//! cargo bench --bench exec_plan
//! ```
//!
//! `ESDA_BENCH_SMOKE=1` runs a fast low-iteration pass — numbers too
//! noisy to compare, but every field is measured and non-null. CI runs
//! smoke mode and rejects a `null` in the output, so the checked-in
//! file can never silently regress to placeholders again.

use esda::coordinator::{Backend, Functional};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::model::exec::classify_i8;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::{ExecCtx, ExecPlan, NetworkSpec};
use esda::sparse::SparseMap;
use esda::util::alloc::CountingAllocator;
use esda::util::json::Json;
use esda::util::stats::bench;
use esda::util::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Measured iterations: the real run amortizes noise over 20; smoke mode
/// (CI) only proves the harness measures and emits real numbers.
fn iters() -> (usize, usize) {
    if std::env::var_os("ESDA_BENCH_SMOKE").is_some() {
        (1, 2)
    } else {
        (2, 20)
    }
}

fn req_per_s(n_inputs: usize, mean_s: f64) -> f64 {
    if mean_s <= 0.0 {
        return f64::NAN;
    }
    n_inputs as f64 / mean_s
}

fn main() {
    let (warmup, iters) = iters();
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 7);
    let mut rng = Rng::new(42);
    let inputs: Vec<SparseMap<f32>> = (0..8)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &inputs[..3]);
    let n = inputs.len();

    println!("# exec engine — oracle vs compiled arena plan ({} on n_mnist)\n", spec.name);

    // --- Allocating per-op oracle -----------------------------------------
    let mut sink = 0usize;
    let a0 = CountingAllocator::thread_allocs();
    for m in &inputs {
        sink += classify_i8(&qnet, m);
    }
    let oracle_allocs = (CountingAllocator::thread_allocs() - a0) as f64 / n as f64;
    let s = bench(warmup, iters, || {
        for m in &inputs {
            sink += classify_i8(&qnet, m);
        }
    });
    let oracle_rps = req_per_s(n, s.mean());
    println!("oracle  : {oracle_rps:9.0} req/s | {oracle_allocs:7.1} allocs/inference");

    // --- Compiled plan + arena context ------------------------------------
    let plan = ExecPlan::compile(&qnet);
    let mut ctx = ExecCtx::new();
    for m in &inputs {
        sink += plan.classify(&mut ctx, m); // warm the arena
    }
    let a0 = CountingAllocator::thread_allocs();
    for m in &inputs {
        sink += plan.classify(&mut ctx, m);
    }
    let plan_allocs = (CountingAllocator::thread_allocs() - a0) as f64 / n as f64;
    let s = bench(warmup, iters, || {
        for m in &inputs {
            sink += plan.classify(&mut ctx, m);
        }
    });
    let plan_rps = req_per_s(n, s.mean());
    println!(
        "plan    : {plan_rps:9.0} req/s | {plan_allocs:7.1} allocs/inference | {:.2}x oracle",
        plan_rps / oracle_rps
    );

    // --- Micro-batched serving path (Functional backend) ------------------
    let backend = Functional::new(qnet);
    let mut batches = Vec::new();
    for cap in [1usize, 4, 16] {
        // Warm the backend's context pool at this batch shape.
        for chunk in inputs.chunks(cap) {
            sink += backend.classify_batch(chunk).len();
        }
        let s = bench(warmup, iters, || {
            for chunk in inputs.chunks(cap) {
                for r in backend.classify_batch(chunk) {
                    if r.is_err() {
                        panic!("functional backend cannot fail");
                    }
                }
            }
        });
        let rps = req_per_s(n, s.mean());
        println!("batch {cap:2}: {rps:9.0} req/s");
        batches.push(Json::obj(vec![
            ("batch", Json::Num(cap as f64)),
            ("req_per_s", Json::Num(rps)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("exec_plan".into())),
        ("model", Json::Str(spec.name.clone())),
        ("dataset", Json::Str(profile.name.into())),
        ("n_inputs", Json::Num(n as f64)),
        ("iters", Json::Num(iters as f64)),
        (
            "oracle",
            Json::obj(vec![
                ("req_per_s", Json::Num(oracle_rps)),
                ("allocs_per_inference", Json::Num(oracle_allocs)),
            ]),
        ),
        (
            "plan",
            Json::obj(vec![
                ("req_per_s", Json::Num(plan_rps)),
                ("allocs_per_inference", Json::Num(plan_allocs)),
            ]),
        ),
        ("batched", Json::Arr(batches)),
    ]);
    let path = std::env::var("ESDA_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_exec.json").into());
    std::fs::write(&path, format!("{out}\n")).expect("write bench json");
    println!("\nwrote {path} (sink {sink})");
}
