//! Classification backends behind a shared object-safe trait.
//!
//! The serving runtime replicates accelerators across worker threads, so a
//! backend must be usable from many threads at once: `Backend: Send + Sync`
//! and `classify` takes `&self`. The three implementations mirror the
//! paper's platforms:
//!
//! - [`Simulator`] — the cycle-level ESDA dataflow simulator (batch-1, the
//!   paper's FPGA deployment; also reports hardware cycles),
//! - [`Functional`] — the int8 functional reference (fast, no cycle model),
//! - [`Dense`] — the PJRT dense engine (the GPU-platform stand-in; real
//!   only with the `pjrt` feature).

use crate::arch::{simulate_inference, HwConfig};
use crate::model::exec::argmax;
use crate::model::plan::{DeltaCache, DeltaOutcome, ExecCtx, ExecPlan, FullReason};
use crate::model::quant::QuantizedNet;
use crate::sparse::SparseMap;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default simulator cycle budget per inference (generous: deadlock and
/// runaway detection live inside the simulator itself).
pub const DEFAULT_CYCLE_BUDGET: u64 = 10_000_000_000;

/// One classification outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Classification {
    /// Predicted class index.
    pub pred: usize,
    /// Simulated hardware cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Per-request outcome of a delta-capable classification (what the
/// incremental path did, for the serving metrics/report).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeltaStatus {
    /// Backend has no delta path, or the request carried no stream id.
    NotApplicable,
    /// The incremental path ran; fractions per [`DeltaOutcome`].
    Hit { dirty_frac: f64, recomputed_frac: f64 },
    /// Full recompute, with the reason (cache refreshed along the way).
    Full(FullReason),
}

impl DeltaStatus {
    fn from_outcome(o: DeltaOutcome) -> DeltaStatus {
        match o {
            DeltaOutcome::Delta { .. } => DeltaStatus::Hit {
                dirty_frac: o.dirty_frac(),
                recomputed_frac: o.recomputed_frac(),
            },
            DeltaOutcome::Full(r) => DeltaStatus::Full(r),
        }
    }
}

/// Backend failure (simulator deadlock/timeout, PJRT error, …).
#[derive(Debug, Clone)]
pub struct BackendError(pub String);

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for BackendError {}

/// A classification backend that worker replicas can share.
///
/// Implementations must be stateless across calls (or internally
/// synchronized): the pool calls `classify` concurrently from N threads.
pub trait Backend: Send + Sync {
    /// Short display name for reports.
    fn name(&self) -> &str;

    /// Classify one sparse input map.
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError>;

    /// Classify a micro-batch of input maps, returning one result per map
    /// in order. The default runs one-by-one; backends override it to
    /// amortize per-inference setup (the functional backend reuses one
    /// execution arena across the whole batch, the dense engine takes its
    /// lock once).
    fn classify_batch(&self, maps: &[SparseMap<f32>]) -> Vec<Result<Classification, BackendError>> {
        maps.iter().map(|m| self.classify(m)).collect()
    }

    /// True when [`Backend::classify_batch_delta`] can reuse per-stream
    /// cached state (the router then applies sticky routing so a stream
    /// keeps landing on the worker that holds its cache warm).
    fn supports_delta(&self) -> bool {
        false
    }

    /// Classify a micro-batch with per-request stream identities
    /// (`streams[i]` labels `maps[i]`; `None` = no stream identity). The
    /// default delegates to [`Backend::classify_batch`] and reports
    /// [`DeltaStatus::NotApplicable`]; delta-capable backends override it
    /// to run incremental execution against each stream's cached window.
    /// Results must be **bit-identical** to the non-delta path.
    fn classify_batch_delta(
        &self,
        streams: &[Option<u64>],
        maps: &[SparseMap<f32>],
    ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
        debug_assert_eq!(streams.len(), maps.len());
        self.classify_batch(maps)
            .into_iter()
            .map(|r| r.map(|c| (c, DeltaStatus::NotApplicable)))
            .collect()
    }

    /// Drop any cached per-stream state (no-op without a delta path).
    fn evict_stream(&self, _stream: u64) {}
}

/// Functional int8 backend (fast; no cycle model). The network is compiled
/// **once** into an [`ExecPlan`] at construction (the `QuantizedNet` is
/// consumed — the plan holds the only weight copy); requests execute
/// through pooled [`ExecCtx`] buffer arenas, so steady-state inference
/// performs no per-request program walking, weight resolution, or heap
/// allocation.
pub struct Functional {
    plan: ExecPlan,
    /// Warm execution contexts, one per concurrently-classifying thread
    /// (grown on demand; the lock is held only to pop/push).
    // lint: lock-rank(75): backend-ctxs
    ctxs: Mutex<Vec<ExecCtx>>,
    /// Incremental-execution engine ([`Functional::with_delta`]).
    delta: Option<DeltaEngine>,
}

/// Per-stream cache store for incremental execution. The store may be
/// shared across every replica of a pool class
/// ([`ReplicaSpec::functional_delta`]): a [`DeltaCache`] is self-consistent
/// (its cached input and layer activations always come from one coherent
/// previous window), so any replica can serve any stream correctly — at
/// worst a non-sticky hop diffs against an older window and recomputes
/// more. Stickiness is purely a performance property, never a correctness
/// one, which is what makes replica retirement trivially safe.
// lint: lock-rank(76): delta-store
pub type DeltaStore = Arc<Mutex<HashMap<u64, DeltaCache>>>;

struct DeltaEngine {
    max_frac: f64,
    // lint: lock-rank(76): delta-store
    caches: DeltaStore,
}

/// Cap on concurrently-cached streams per store: beyond this, an arbitrary
/// entry is evicted (the evicted stream simply cold-starts on its next
/// window). Bounds memory for long-tail stream populations.
const MAX_CACHED_STREAMS: usize = 1024;

impl Functional {
    pub fn new(qnet: QuantizedNet) -> Functional {
        let plan = ExecPlan::compile(&qnet);
        Functional { plan, ctxs: Mutex::new(Vec::new()), delta: None }
    }

    /// Enable incremental (delta) execution across overlapping windows:
    /// requests carrying a stream id diff against that stream's cached
    /// previous window and recompute only changed sites, falling back to a
    /// full pass when the changed fraction exceeds `max_frac`.
    pub fn with_delta(self, max_frac: f64) -> Functional {
        self.with_delta_store(max_frac, Arc::new(Mutex::new(HashMap::new())))
    }

    /// [`Functional::with_delta`] against a caller-provided (possibly
    /// shared) stream-cache store.
    pub fn with_delta_store(mut self, max_frac: f64, caches: DeltaStore) -> Functional {
        self.delta = Some(DeltaEngine { max_frac, caches });
        self
    }

    /// Run `f` with a pooled execution context; the context returns to the
    /// pool afterwards so its arena stays warm for the next request.
    fn with_ctx<R>(&self, f: impl FnOnce(&ExecPlan, &mut ExecCtx) -> R) -> R {
        let mut ctx = self.ctxs.lock().unwrap().pop().unwrap_or_default();
        let r = f(&self.plan, &mut ctx);
        self.ctxs.lock().unwrap().push(ctx);
        r
    }
}

impl Backend for Functional {
    fn name(&self) -> &str {
        "functional-int8"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        let pred = self.with_ctx(|plan, ctx| plan.classify(ctx, map));
        Ok(Classification { pred, sim_cycles: None })
    }

    fn classify_batch(&self, maps: &[SparseMap<f32>]) -> Vec<Result<Classification, BackendError>> {
        // One context for the whole batch: the arena stays hot and the
        // pool lock is taken once per batch instead of once per request.
        self.with_ctx(|plan, ctx| {
            maps.iter()
                .map(|m| Ok(Classification { pred: plan.classify(ctx, m), sim_cycles: None }))
                .collect()
        })
    }

    fn supports_delta(&self) -> bool {
        self.delta.is_some()
    }

    fn classify_batch_delta(
        &self,
        streams: &[Option<u64>],
        maps: &[SparseMap<f32>],
    ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
        debug_assert_eq!(streams.len(), maps.len());
        let Some(engine) = &self.delta else {
            return streams
                .iter()
                .zip(maps)
                .map(|(_, m)| {
                    self.with_ctx(|plan, ctx| {
                        let pred = plan.classify(ctx, m);
                        Ok((Classification { pred, sim_cycles: None }, DeltaStatus::NotApplicable))
                    })
                })
                .collect();
        };
        self.with_ctx(|plan, ctx| {
            streams
                .iter()
                .zip(maps)
                .map(|(stream, m)| {
                    let (pred, status) = match stream {
                        None => (plan.classify(ctx, m), DeltaStatus::NotApplicable),
                        Some(id) => {
                            // Take the stream's cache *out* of the store so
                            // the lock is not held during execution; other
                            // replicas hitting the same stream concurrently
                            // just cold-start (correct, merely slower).
                            let cached = engine.caches.lock().unwrap().remove(id);
                            let mut cache = cached.unwrap_or_default();
                            let (pred, outcome) =
                                plan.classify_delta(ctx, &mut cache, m, engine.max_frac);
                            let mut store = engine.caches.lock().unwrap();
                            if store.len() >= MAX_CACHED_STREAMS {
                                if let Some(&victim) = store.keys().next() {
                                    store.remove(&victim);
                                }
                            }
                            store.insert(*id, cache);
                            (pred, DeltaStatus::from_outcome(outcome))
                        }
                    };
                    Ok((Classification { pred, sim_cycles: None }, status))
                })
                .collect()
        })
    }

    fn evict_stream(&self, stream: u64) {
        if let Some(engine) = &self.delta {
            engine.caches.lock().unwrap().remove(&stream);
        }
    }
}

/// A delegating handle to one shared backend instance: lets every
/// replica of a pool class serve through the same underlying backend
/// (the arrangement a [`Swappable`] fleet model uses, so one atomic
/// flip retargets every replica at once).
pub struct Shared(pub Arc<dyn Backend>);

impl Backend for Shared {
    fn name(&self) -> &str {
        self.0.name()
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        self.0.classify(map)
    }

    fn classify_batch(&self, maps: &[SparseMap<f32>]) -> Vec<Result<Classification, BackendError>> {
        self.0.classify_batch(maps)
    }

    fn supports_delta(&self) -> bool {
        self.0.supports_delta()
    }

    fn classify_batch_delta(
        &self,
        streams: &[Option<u64>],
        maps: &[SparseMap<f32>],
    ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
        self.0.classify_batch_delta(streams, maps)
    }

    fn evict_stream(&self, stream: u64) {
        self.0.evict_stream(stream)
    }
}

/// A backend whose implementation can be **atomically replaced** while
/// workers keep classifying — the serving runtime's hot model swap.
///
/// Every call clones the current `Arc` under a short lock, so an
/// in-flight batch finishes on the version it started with and the next
/// batch sees the new one: no request is lost, none is torn across
/// versions. The swap itself is wait-free for readers in the steady
/// state (the lock is held only to clone or replace the pointer).
pub struct Swappable {
    name: String,
    // lint: lock-rank(70): swap-inner
    inner: Mutex<Arc<dyn Backend>>,
    // lint: atomic(seqcst): readers must agree on which swap generation is live
    generation: AtomicUsize,
}

impl Swappable {
    pub fn new(name: impl Into<String>, inner: Arc<dyn Backend>) -> Swappable {
        Swappable { name: name.into(), inner: Mutex::new(inner), generation: AtomicUsize::new(0) }
    }

    /// Atomically flip to `next`, returning the retired version (callers
    /// may keep it warm for rollback).
    pub fn swap(&self, next: Arc<dyn Backend>) -> Arc<dyn Backend> {
        let mut slot = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let old = std::mem::replace(&mut *slot, next);
        self.generation.fetch_add(1, Ordering::SeqCst);
        old
    }

    /// Number of completed swaps (0 on the version the server started
    /// with) — lets callers confirm a scheduled swap actually landed.
    pub fn generation(&self) -> usize {
        self.generation.load(Ordering::SeqCst)
    }

    fn current(&self) -> Arc<dyn Backend> {
        Arc::clone(&self.inner.lock().unwrap_or_else(|p| p.into_inner()))
    }
}

impl Backend for Swappable {
    fn name(&self) -> &str {
        &self.name
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        self.current().classify(map)
    }

    fn classify_batch(&self, maps: &[SparseMap<f32>]) -> Vec<Result<Classification, BackendError>> {
        self.current().classify_batch(maps)
    }

    fn supports_delta(&self) -> bool {
        self.current().supports_delta()
    }

    fn classify_batch_delta(
        &self,
        streams: &[Option<u64>],
        maps: &[SparseMap<f32>],
    ) -> Vec<Result<(Classification, DeltaStatus), BackendError>> {
        self.current().classify_batch_delta(streams, maps)
    }

    fn evict_stream(&self, stream: u64) {
        self.current().evict_stream(stream)
    }
}

/// Cycle-level ESDA simulator (reports hardware cycles too).
pub struct Simulator {
    pub qnet: QuantizedNet,
    pub cfg: HwConfig,
    pub cycle_budget: u64,
}

impl Simulator {
    pub fn new(qnet: QuantizedNet, cfg: HwConfig) -> Simulator {
        Simulator { qnet, cfg, cycle_budget: DEFAULT_CYCLE_BUDGET }
    }
}

impl Backend for Simulator {
    fn name(&self) -> &str {
        "cycle-simulator"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        let (logits, report) = simulate_inference(&self.qnet, &self.cfg, map, self.cycle_budget)
            .map_err(|e| BackendError(format!("simulation: {e}")))?;
        Ok(Classification { pred: argmax(&logits), sim_cycles: Some(report.cycles) })
    }
}

/// PJRT dense engine (AOT artifact). The engine handle is `Send` but not
/// `Sync`, so one shared instance serializes inferences behind a mutex —
/// worker replicas queue on it. A truly parallel dense pool needs one
/// engine per replica (future work: per-worker backend factories).
pub struct Dense {
    // lint: lock-rank(77): dense-engine
    pub engine: std::sync::Mutex<crate::runtime::Engine>,
}

impl Dense {
    pub fn new(engine: crate::runtime::Engine) -> Dense {
        Dense { engine: std::sync::Mutex::new(engine) }
    }
}

impl Backend for Dense {
    fn name(&self) -> &str {
        "pjrt-dense"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        // A previous panic while holding the lock cannot corrupt the
        // engine (inference takes `&self`), so poisoning is ignorable.
        let engine = self.engine.lock().unwrap_or_else(|p| p.into_inner());
        let logits = engine
            .infer_sparse(map)
            .map_err(|e| BackendError(format!("dense inference: {e}")))?;
        Ok(Classification { pred: argmax(&logits), sim_cycles: None })
    }

    fn classify_batch(&self, maps: &[SparseMap<f32>]) -> Vec<Result<Classification, BackendError>> {
        // Native batching for the serialized engine: take the lock once
        // per batch so replicas queue per accelerator visit, not per map.
        let engine = self.engine.lock().unwrap_or_else(|p| p.into_inner());
        maps.iter()
            .map(|m| {
                engine
                    .infer_sparse(m)
                    .map(|logits| Classification { pred: argmax(&logits), sim_cycles: None })
                    .map_err(|e| BackendError(format!("dense inference: {e}")))
            })
            .collect()
    }
}

/// How one replica class of a heterogeneous pool is instantiated and
/// scheduled: a display name, a replica count (optionally a `min..max`
/// range the autoscaler moves within), a batch affinity (the micro-batch
/// cap its workers drain — dense engines want large batches, the cycle
/// simulator wants batch 1), and a **factory** that builds one
/// independent backend instance per replica.
///
/// Per-replica instances are what make heterogeneous pools truly parallel:
/// the homogeneous [`run_server`](super::serve::run_server) path shares a
/// single backend across workers, which serializes the [`Dense`] engine
/// behind its mutex — a pool built from `ReplicaSpec::dense` loads one
/// engine per replica instead. The same factory is what lets the
/// autoscaler grow a class **on demand**: only the `count` (= min)
/// replicas are instantiated eagerly at pool build; replicas up to `max`
/// are built by [`PoolClass::build_replica`] the first time the
/// controller scales into them (and kept warm for re-activation).
pub struct ReplicaSpec {
    class: String,
    /// The served model this class belongs to (fleet serving routes a
    /// request only to classes tagged with its model).
    model: String,
    count: usize,
    max: usize,
    batch: usize,
    #[allow(clippy::type_complexity)]
    factory: Box<dyn Fn(usize) -> Result<Box<dyn Backend>, BackendError> + Send + Sync>,
}

/// Model tag every class carries when the caller never names one: the
/// single-model paths all agree on it, so legacy pools keep routing and
/// reporting exactly as before fleets existed.
pub const DEFAULT_MODEL: &str = "default";

impl ReplicaSpec {
    /// A class built from an arbitrary factory; `factory(i)` constructs
    /// replica `i`'s backend instance.
    pub fn new(
        class: impl Into<String>,
        count: usize,
        batch: usize,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>, BackendError> + Send + Sync + 'static,
    ) -> ReplicaSpec {
        ReplicaSpec {
            class: class.into(),
            model: DEFAULT_MODEL.to_string(),
            count,
            max: count,
            batch: batch.max(1),
            factory: Box::new(factory),
        }
    }

    /// Tag this class as serving `model` (fleet pools; the router only
    /// sends a request to classes tagged with its model).
    pub fn for_model(mut self, model: impl Into<String>) -> ReplicaSpec {
        self.model = model.into();
        self
    }

    /// Functional int8 replicas (each compiles its own [`ExecPlan`]).
    /// Default batch affinity 4: the arena amortizes per-visit setup.
    pub fn functional(count: usize, qnet: QuantizedNet) -> ReplicaSpec {
        ReplicaSpec::new("func", count, 4, move |_| Ok(Box::new(Functional::new(qnet.clone()))))
    }

    /// Functional replicas with incremental (delta) execution enabled.
    /// All replicas of the class — including ones the autoscaler builds
    /// later — share **one** stream-cache store, so scaling a replica down
    /// loses no cached windows: its streams rehome to a sibling and keep
    /// hitting (the move shows up as a sticky-routing miss, not a delta
    /// cold-start).
    pub fn functional_delta(count: usize, qnet: QuantizedNet, max_frac: f64) -> ReplicaSpec {
        let store: DeltaStore = Arc::new(Mutex::new(HashMap::new()));
        ReplicaSpec::new("func", count, 4, move |_| {
            let f = Functional::new(qnet.clone()).with_delta_store(max_frac, Arc::clone(&store));
            Ok(Box::new(f))
        })
    }

    /// Cycle-level simulator replicas. Batch affinity 1: the simulator
    /// models the paper's batch-1 FPGA deployment and amortizes nothing
    /// across a visit.
    pub fn simulator(count: usize, qnet: QuantizedNet, cfg: HwConfig) -> ReplicaSpec {
        ReplicaSpec::new("sim", count, 1, move |_| {
            Ok(Box::new(Simulator::new(qnet.clone(), cfg.clone())))
        })
    }

    /// PJRT dense replicas — one engine loaded **per replica**, so dense
    /// inference finally runs in parallel instead of queueing on a single
    /// shared mutex. Batch affinity 16: the dense engine is happiest
    /// amortizing its dispatch over large batches.
    pub fn dense(count: usize, hlo_path: std::path::PathBuf) -> ReplicaSpec {
        ReplicaSpec::new("dense", count, 16, move |i| {
            let engine = crate::runtime::Engine::load(&hlo_path)
                .map_err(|e| BackendError(format!("dense replica {i}: {e}")))?;
            Ok(Box::new(Dense::new(engine)))
        })
    }

    /// Override the batch affinity (e.g. from a `class=count@batch` CLI
    /// spec entry).
    pub fn with_batch(mut self, batch: usize) -> ReplicaSpec {
        self.batch = batch.max(1);
        self
    }

    /// Allow the autoscaler to grow this class up to `max` replicas (the
    /// `class=min..max` CLI range syntax; floored at the base count).
    /// Replicas beyond the base count are built lazily via the factory
    /// when the controller first scales into them.
    pub fn with_max_replicas(mut self, max: usize) -> ReplicaSpec {
        self.max = max.max(self.count);
        self
    }
}

/// One instantiated replica class of a [`ReplicaPool`].
pub struct PoolClass {
    /// Display name (metrics/report key).
    pub name: String,
    /// The served model this class belongs to ([`DEFAULT_MODEL`] unless
    /// the spec was tagged via [`ReplicaSpec::for_model`]).
    pub model: String,
    /// Micro-batch cap this class's workers drain per accelerator visit.
    pub batch: usize,
    /// Independent backend instances for the base (minimum) replica
    /// count; shared (`Arc`) so the serving runtime can hand clones to
    /// dynamically spawned worker threads.
    pub replicas: Vec<Arc<dyn Backend>>,
    /// Minimum active replicas (== `replicas.len()`).
    pub min: usize,
    /// Maximum replicas the autoscaler may grow to (== `min` when the
    /// class is not scalable).
    pub max: usize,
    /// Retained factory for on-demand growth past `min`.
    #[allow(clippy::type_complexity)]
    factory: Box<dyn Fn(usize) -> Result<Box<dyn Backend>, BackendError> + Send + Sync>,
}

impl PoolClass {
    /// Build replica `i`'s backend on demand (the autoscaler's scale-up
    /// path; `i ∈ [min, max)` — the base replicas already exist).
    pub fn build_replica(&self, i: usize) -> Result<Arc<dyn Backend>, BackendError> {
        debug_assert!(i < self.max, "replica {i} beyond class '{}' max {}", self.name, self.max);
        Ok(Arc::from((self.factory)(i)?))
    }
}

/// A heterogeneous accelerator pool: differently-shaped replica classes
/// that coexist behind one serving runtime, with the router picking a
/// class per request (see [`run_pool`](super::serve::run_pool)).
pub struct ReplicaPool {
    pub classes: Vec<PoolClass>,
}

impl ReplicaPool {
    /// Instantiate every replica of every class via its factory.
    pub fn build(specs: Vec<ReplicaSpec>) -> Result<ReplicaPool, BackendError> {
        if specs.is_empty() {
            return Err(BackendError("pool needs at least one replica class".into()));
        }
        let mut classes = Vec::with_capacity(specs.len());
        for spec in specs {
            if spec.count == 0 {
                return Err(BackendError(format!(
                    "replica class '{}' needs a count >= 1",
                    spec.class
                )));
            }
            // Class names key the metrics/report rows; duplicates would
            // render as indistinguishable rows and break name lookups.
            if classes.iter().any(|c: &PoolClass| c.name == spec.class) {
                return Err(BackendError(format!(
                    "duplicate replica class '{}' in pool spec",
                    spec.class
                )));
            }
            let mut replicas: Vec<Arc<dyn Backend>> = Vec::with_capacity(spec.count);
            for i in 0..spec.count {
                replicas.push(Arc::from((spec.factory)(i)?));
            }
            classes.push(PoolClass {
                name: spec.class,
                model: spec.model,
                batch: spec.batch,
                replicas,
                min: spec.count,
                max: spec.max.max(spec.count),
                factory: spec.factory,
            });
        }
        Ok(ReplicaPool { classes })
    }

    /// Total worker replicas instantiated eagerly across all classes (the
    /// per-class minimums; autoscaled classes may grow past this at
    /// serve time).
    pub fn n_replicas(&self) -> usize {
        self.classes.iter().map(|c| c.replicas.len()).sum()
    }

    /// Total replica capacity if every class scaled to its max.
    pub fn max_replicas(&self) -> usize {
        self.classes.iter().map(|c| c.max).sum()
    }

    /// True when some class can grow past its base count (an autoscaler
    /// would have something to do).
    pub fn is_scalable(&self) -> bool {
        self.classes.iter().any(|c| c.max > c.min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::qnet_for;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::util::Rng;

    /// Simulator and functional backends must classify identically.
    #[test]
    fn backends_agree_on_predictions() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let func = Functional::new(qnet.clone());
        let sim = Simulator::new(qnet, HwConfig::uniform(n_ops, 8));
        let mut rng = Rng::new(77);
        for i in 0..3 {
            let es = profile.sample(i, &mut rng);
            let map = histogram2_norm(&es, profile.w, profile.h, 8.0);
            let f = func.classify(&map).unwrap();
            let s = sim.classify(&map).unwrap();
            assert_eq!(f.pred, s.pred);
            assert!(f.sim_cycles.is_none());
            assert!(s.sim_cycles.unwrap() > 0);
        }
    }

    /// The compiled-plan path behind `Functional` must agree with the
    /// allocating oracle on every request.
    #[test]
    fn functional_plan_matches_oracle_classify() {
        use crate::model::exec::classify_i8;
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let func = Functional::new(qnet.clone());
        let mut rng = Rng::new(123);
        for i in 0..6 {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            let map = histogram2_norm(&es, profile.w, profile.h, 8.0);
            assert_eq!(func.classify(&map).unwrap().pred, classify_i8(&qnet, &map));
        }
    }

    /// `classify_batch` returns one in-order result per map and matches
    /// the sequential path (both for the functional override and for a
    /// default-implementation backend).
    #[test]
    fn classify_batch_matches_sequential() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let func = Functional::new(qnet.clone());
        let sim = Simulator::new(qnet, HwConfig::uniform(n_ops, 8));
        let mut rng = Rng::new(5);
        let maps: Vec<SparseMap<f32>> = (0..5)
            .map(|i| {
                let es = profile.sample(i % profile.n_classes, &mut rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            })
            .collect();
        for backend in [&func as &dyn Backend, &sim as &dyn Backend] {
            let seq: Vec<usize> =
                maps.iter().map(|m| backend.classify(m).unwrap().pred).collect();
            let batched: Vec<usize> = backend
                .classify_batch(&maps)
                .into_iter()
                .map(|r| r.unwrap().pred)
                .collect();
            assert_eq!(batched, seq, "{}", backend.name());
        }
        assert!(func.classify_batch(&[]).is_empty());
    }

    /// The pool builder instantiates one independent backend per replica,
    /// applies class batch affinities, and rejects degenerate specs.
    #[test]
    fn replica_pool_builds_per_replica_instances() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let pool = ReplicaPool::build(vec![
            ReplicaSpec::functional(2, qnet.clone()),
            ReplicaSpec::simulator(1, qnet.clone(), HwConfig::uniform(n_ops, 8)),
        ])
        .unwrap();
        assert_eq!(pool.classes.len(), 2);
        assert_eq!(pool.n_replicas(), 3);
        assert_eq!(pool.classes[0].name, "func");
        assert_eq!(pool.classes[0].batch, 4, "functional batch affinity");
        assert_eq!(pool.classes[0].replicas.len(), 2);
        assert_eq!(pool.classes[1].name, "sim");
        assert_eq!(pool.classes[1].batch, 1, "the simulator is a batch-1 device");

        // `with_batch` overrides the affinity (floored at 1).
        let spec = ReplicaSpec::functional(1, qnet.clone()).with_batch(0);
        let pool = ReplicaPool::build(vec![spec]).unwrap();
        assert_eq!(pool.classes[0].batch, 1);

        assert!(ReplicaPool::build(vec![]).is_err(), "empty pool must be rejected");
        let zero = ReplicaSpec::functional(0, qnet.clone());
        assert!(ReplicaPool::build(vec![zero]).is_err(), "zero-count class must be rejected");

        // Duplicate class names would render indistinguishable report rows
        // and break per-class lookups.
        let dup =
            vec![ReplicaSpec::functional(1, qnet.clone()), ReplicaSpec::functional(1, qnet)];
        let err = ReplicaPool::build(dup).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    /// A ranged spec instantiates only its base replicas eagerly and
    /// grows the rest on demand through the retained factory.
    #[test]
    fn scalable_class_grows_replicas_on_demand() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let built = std::sync::Arc::new(AtomicUsize::new(0));
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let b2 = std::sync::Arc::clone(&built);
        let spec = ReplicaSpec::new("func", 1, 4, move |_| {
            b2.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(Functional::new(qnet.clone())))
        })
        .with_max_replicas(3);
        let pool = ReplicaPool::build(vec![spec]).unwrap();
        let class = &pool.classes[0];
        assert_eq!((class.min, class.max), (1, 3));
        assert_eq!(class.replicas.len(), 1, "only the base replica is built eagerly");
        assert_eq!(built.load(Ordering::SeqCst), 1);
        assert_eq!(pool.n_replicas(), 1);
        assert_eq!(pool.max_replicas(), 3);
        assert!(pool.is_scalable());
        // Scale-up path: replicas 1 and 2 are built on demand.
        let r1 = class.build_replica(1).unwrap();
        let _r2 = class.build_replica(2).unwrap();
        assert_eq!(built.load(Ordering::SeqCst), 3);
        let map = {
            let mut rng = Rng::new(4);
            let es = profile.sample(0, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        };
        // A grown replica classifies like any other.
        assert_eq!(
            r1.classify(&map).unwrap().pred,
            class.replicas[0].classify(&map).unwrap().pred
        );
        // `with_max_replicas` floors at the base count.
        let profile = DatasetProfile::n_mnist();
        let spec = ReplicaSpec::functional(2, qnet_for(&profile)).with_max_replicas(1);
        let pool = ReplicaPool::build(vec![spec]).unwrap();
        assert_eq!((pool.classes[0].min, pool.classes[0].max), (2, 2));
        assert!(!pool.is_scalable());
    }

    /// Factory errors propagate out of the builder with the replica index.
    #[test]
    fn replica_pool_surfaces_factory_errors() {
        let spec = ReplicaSpec::new("broken", 1, 1, |i| {
            Err(BackendError(format!("replica {i} failed to init")))
        });
        let err = ReplicaPool::build(vec![spec]).unwrap_err();
        assert!(err.to_string().contains("replica 0"), "{err}");
    }

    /// Backends are shareable across threads (the pool's core contract).
    #[test]
    fn backend_trait_objects_are_sync() {
        fn assert_sync<T: Sync + ?Sized>() {}
        assert_sync::<dyn Backend>();
        assert_sync::<Functional>();
        assert_sync::<Simulator>();
        assert_sync::<Dense>();
    }

    /// Delta-enabled classification is bit-equal to the plain path while
    /// reporting cache status: cold start on the first window of a stream,
    /// hits on subsequent overlapping windows, `NotApplicable` without a
    /// stream identity.
    #[test]
    fn functional_delta_matches_plain_and_reports_status() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let plain = Functional::new(qnet.clone());
        let delta = Functional::new(qnet).with_delta(0.35);
        assert!(!plain.supports_delta());
        assert!(delta.supports_delta());
        let mut rng = Rng::new(9);
        let es = profile.sample(3, &mut rng);
        // Overlapping windows: each step drops one more trailing event.
        let maps: Vec<_> = (0..5)
            .map(|t| histogram2_norm(&es[..es.len() - t], profile.w, profile.h, 8.0))
            .collect();
        let mut statuses = Vec::new();
        for (t, m) in maps.iter().enumerate() {
            let stream = if t == 4 { None } else { Some(7u64) };
            let got = delta.classify_batch_delta(&[stream], std::slice::from_ref(m));
            let (c, status) = got.into_iter().next().unwrap().unwrap();
            assert_eq!(c.pred, plain.classify(m).unwrap().pred, "step {t} diverged");
            statuses.push(status);
        }
        assert_eq!(statuses[0], DeltaStatus::Full(FullReason::ColdCache));
        assert!(
            statuses[1..4].iter().all(|s| matches!(s, DeltaStatus::Hit { .. })),
            "{statuses:?}"
        );
        assert_eq!(statuses[4], DeltaStatus::NotApplicable);
        // Evicting the stream forces the next window back to a cold start.
        delta.evict_stream(7);
        let got = delta.classify_batch_delta(&[Some(7)], std::slice::from_ref(&maps[0]));
        let (_, status) = got.into_iter().next().unwrap().unwrap();
        assert_eq!(status, DeltaStatus::Full(FullReason::ColdCache));
    }

    /// Two Functional instances sharing one store (the
    /// `functional_delta` replica arrangement): a stream warmed on one
    /// replica hits on the other, so replica retirement loses no state.
    #[test]
    fn functional_delta_store_is_shared_across_replicas() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let store: DeltaStore = Arc::new(Mutex::new(HashMap::new()));
        let a = Functional::new(qnet.clone()).with_delta_store(0.35, Arc::clone(&store));
        let b = Functional::new(qnet).with_delta_store(0.35, Arc::clone(&store));
        let mut rng = Rng::new(10);
        let es = profile.sample(1, &mut rng);
        let m0 = histogram2_norm(&es, profile.w, profile.h, 8.0);
        let m1 = histogram2_norm(&es[..es.len() - 1], profile.w, profile.h, 8.0);
        let (_, s0) = a.classify_batch_delta(&[Some(42)], std::slice::from_ref(&m0))
            .into_iter().next().unwrap().unwrap();
        assert_eq!(s0, DeltaStatus::Full(FullReason::ColdCache));
        let (c1, s1) = b.classify_batch_delta(&[Some(42)], std::slice::from_ref(&m1))
            .into_iter().next().unwrap().unwrap();
        assert!(matches!(s1, DeltaStatus::Hit { .. }), "{s1:?}");
        assert_eq!(c1.pred, b.classify(&m1).unwrap().pred);
        assert_eq!(store.lock().unwrap().len(), 1);
    }

    /// A swap retargets every `Shared` handle at once, bumps the
    /// generation, and returns the retired version.
    #[test]
    fn swappable_flips_every_shared_handle_at_once() {
        struct Fixed(usize);
        impl Backend for Fixed {
            fn name(&self) -> &str {
                "fixed"
            }
            fn classify(&self, _: &SparseMap<f32>) -> Result<Classification, BackendError> {
                Ok(Classification { pred: self.0, sim_cycles: None })
            }
        }
        let swap = Arc::new(Swappable::new("candidate", Arc::new(Fixed(1))));
        let a = Shared(Arc::clone(&swap) as Arc<dyn Backend>);
        let b = Shared(Arc::clone(&swap) as Arc<dyn Backend>);
        let map = SparseMap::empty(4, 4, 2);
        assert_eq!(a.classify(&map).unwrap().pred, 1);
        assert_eq!(swap.generation(), 0);
        let old = swap.swap(Arc::new(Fixed(2)));
        assert_eq!(old.classify(&map).unwrap().pred, 1, "retired version still usable");
        assert_eq!(a.classify(&map).unwrap().pred, 2);
        assert_eq!(b.classify(&map).unwrap().pred, 2, "both handles see the flip");
        assert_eq!(swap.generation(), 1);
        assert_eq!(a.name(), "candidate", "the swappable keeps its own display name");
    }

    /// Model tags ride `ReplicaSpec::for_model` into the built pool;
    /// untagged specs land on the shared default.
    #[test]
    fn pool_classes_carry_model_tags() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let pool = ReplicaPool::build(vec![
            ReplicaSpec::functional(1, qnet.clone()).for_model("mnist-a"),
            ReplicaSpec::simulator(1, qnet, HwConfig::uniform(n_ops, 8)),
        ])
        .unwrap();
        assert_eq!(pool.classes[0].model, "mnist-a");
        assert_eq!(pool.classes[1].model, DEFAULT_MODEL);
    }

    /// A stub Dense backend surfaces engine errors instead of panicking.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn dense_stub_errors_cleanly() {
        let eng = crate::runtime::Engine { h: 4, w: 4, c: 2, n_classes: 3 };
        let dense = Dense::new(eng);
        let map = SparseMap::empty(4, 4, 2);
        let e = dense.classify(&map).unwrap_err();
        assert!(e.to_string().contains("pjrt"));
    }
}
