//! Latency/throughput metrics for the serving pipeline.

use crate::util::stats::Summary;
use std::time::Instant;

/// Per-request timing record.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// End-to-end latency (enqueue → classified), seconds.
    pub e2e_s: f64,
    /// Accelerator-stage service time, seconds.
    pub service_s: f64,
    /// Simulated hardware cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Aggregated pipeline metrics.
#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub timings: Vec<RequestTiming>,
    pub correct: usize,
    pub total: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics { started: Instant::now(), timings: Vec::new(), correct: 0, total: 0 }
    }
}

impl Metrics {
    pub fn record(&mut self, t: RequestTiming, correct: bool) {
        self.timings.push(t);
        self.total += 1;
        if correct {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.correct as f64 / self.total as f64
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.e2e_s).collect::<Vec<_>>())
    }

    pub fn service_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.service_s).collect::<Vec<_>>())
    }

    /// Wall-clock throughput (requests/s).
    pub fn throughput(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt <= 0.0 {
            return f64::NAN;
        }
        self.total as f64 / dt
    }

    /// Mean simulated hardware latency in ms at `clock_hz`, when available.
    pub fn mean_sim_latency_ms(&self, clock_hz: f64) -> Option<f64> {
        let cycles: Vec<f64> = self
            .timings
            .iter()
            .filter_map(|t| t.sim_cycles.map(|c| c as f64))
            .collect();
        if cycles.is_empty() {
            return None;
        }
        Some(cycles.iter().sum::<f64>() / cycles.len() as f64 / clock_hz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.010, service_s: 0.002, sim_cycles: Some(1000) }, true);
        m.record(RequestTiming { e2e_s: 0.020, service_s: 0.004, sim_cycles: Some(3000) }, false);
        assert_eq!(m.total, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert!((m.e2e_summary().mean() - 0.015).abs() < 1e-9);
        let lat = m.mean_sim_latency_ms(1e6).unwrap();
        assert!((lat - 2.0).abs() < 1e-9); // 2000 cycles avg @1MHz = 2ms
    }
}
