//! Stage 4: the accelerator worker loop — micro-batch draining with
//! pop-time deadline expiry, retire-token autoscaler handoff, sticky
//! side-queue affinity work, and (for shadowed models) the shadow
//! conformance mirror evaluated after each primary result is recorded.

use super::state::{
    take_retire_token, ClassCtx, Meta, Routed, ServedRecord, ShadowCtx, SharedCtx, WorkerOutput,
};
use crate::coordinator::backend::{Backend, DeltaStatus};
use crate::coordinator::metrics::{DeltaMetrics, RequestTiming};
use crate::coordinator::queue::AdmissionQueue;
use crate::events::Event;
use crate::model::FullReason;
use crate::sparse::SparseMap;
use crate::util::panic_message;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Mirror one served request to its model's shadow candidate when the
/// deterministic fraction schedule selects it, comparing predictions
/// bit-exactly. A candidate error counts as a disagreement — a backend
/// that cannot classify certainly does not conform. Disagreeing samples
/// are appended to the capture when one is armed; past the cap (or on a
/// write error, or when the raw events were not retained) the drop is
/// counted instead of silently lost.
fn shadow_compare(
    sh: &ShadowCtx,
    label: usize,
    primary_pred: usize,
    map: &SparseMap<f32>,
    events: Option<Vec<Event>>,
) {
    // floor((k+1)·f) > floor(k·f) fires on exactly a `fraction` share of
    // the counter sequence — deterministic, RNG-free, burst-insensitive.
    let k = sh.counter.fetch_add(1, Ordering::Relaxed);
    let f = sh.fraction;
    let take = ((k + 1) as f64 * f).floor() > (k as f64 * f).floor();
    if !take {
        return;
    }
    sh.mirrored.fetch_add(1, Ordering::Relaxed);
    let agree = match sh.candidate.classify(map) {
        Ok(c) => c.pred == primary_pred,
        Err(_) => false,
    };
    if agree {
        return;
    }
    sh.disagreements.fetch_add(1, Ordering::Relaxed);
    if let Some(capture) = &sh.capture {
        let written = match (events, capture.lock().unwrap().as_mut()) {
            (Some(evs), Some(w)) => w.append(u32::try_from(label).unwrap_or(u32::MAX), evs),
            _ => false,
        };
        if !written {
            sh.capture_drops.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// The accelerator worker body: drain `queue` in micro-batches — expiring
/// deadline-passed requests at the pop, without spending a batch slot on
/// them — and classify through this replica's backend. `routed` is true
/// when a router feeds this class (several classes): the worker then
/// maintains the class backlog and folds observed service times back into
/// the class cost model; in the single-class fast path (`queue` *is* the
/// ingress) both are skipped — there is no routing decision to inform.
///
/// Autoscaler retirement: a scale-down step deposits a retire token at
/// the class; the first worker to claim it finishes the batch it holds
/// (in-flight work is always drained), stops taking new work, and exits —
/// a parked worker is unblocked via the queue's cancellable pop and
/// re-parks if a sibling claimed the token first.
///
/// Sticky routing: a delta-capable worker under a router additionally
/// owns a bounded `side` queue of requests pinned to it because it holds
/// their stream's delta cache. Side work is drained first (non-blocking)
/// each lap; after a served batch the worker re-advertises the streams it
/// refreshed via the sticky context. A retiring sticky worker first
/// withdraws from the target list and closes its side queue (in-flight
/// pushes bounce to the router for cost routing), then serves the
/// remainder itself — no pinned request is ever stranded or double-served.
///
/// Shadow mirroring happens here, after each primary result lands in the
/// worker's records: the serving thread pays for the candidate visit so
/// the mirror can never reorder or delay another worker's traffic.
#[allow(clippy::too_many_arguments)]
pub(super) fn worker_loop(
    wid: usize,
    ci: usize,
    class: &ClassCtx<'_>,
    queue: &AdmissionQueue<Routed>,
    routed: bool,
    backend: &dyn Backend,
    side: Option<Arc<AdmissionQueue<Routed>>>,
    sx: &SharedCtx<'_, '_>,
) -> WorkerOutput {
    let multi_tenant = sx.tenants.len() > 1;
    // Record the first failure and hard-stop every stage: producers fail
    // fast, the router and all class workers wake and exit.
    let fail = |msg: String| {
        sx.first_error.lock().unwrap().get_or_insert_with(|| msg);
        sx.ingress.abort();
        for c in sx.classes {
            c.queue.abort();
        }
    };
    let mut records: Vec<ServedRecord> = Vec::new();
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut busy_s = 0.0f64;
    let mut delta = DeltaMetrics::default();
    let use_delta = backend.supports_delta();
    let batch_cap = class.batch.max(1);
    let mut batch: Vec<Routed> = Vec::with_capacity(batch_cap);
    let mut metas: Vec<Meta> = Vec::with_capacity(batch_cap);
    let mut maps: Vec<SparseMap<f32>> = Vec::with_capacity(batch_cap);
    let mut streams: Vec<Option<u64>> = Vec::with_capacity(batch_cap);
    let mut events_buf: Vec<Option<Vec<Event>>> = Vec::with_capacity(batch_cap);
    let side_pending = || side.as_ref().is_some_and(|q| q.stats().2 > 0);
    let mut retiring = false;
    loop {
        // Retired by the autoscaler: claim the pending token (the
        // previous iteration's batch was fully served — in-flight work is
        // never abandoned), stop being a sticky target, then serve out
        // the side-queue remainder before exiting.
        if !retiring && take_retire_token(&class.retire) {
            retiring = true;
            if let Some(sq) = &side {
                if let Some(sc) = sx.sticky {
                    sc.deregister(wid);
                }
                // Closed *after* deregistration: an in-flight sticky push
                // bounces back to the router, which cost-routes it.
                sq.close();
            }
        }
        if retiring && side.is_none() {
            break;
        }
        // Affinity work first: requests the router pinned to this worker
        // because it holds their stream's delta cache. The always-true
        // cancellation predicate makes this a non-blocking drain.
        let mut side_expired = 0usize;
        if let Some(sq) = &side {
            side_expired = sq.pop_batch_where_cancellable(
                batch_cap,
                &mut batch,
                |r| {
                    let ex = r.expired(Instant::now());
                    if ex {
                        sx.tenants[r.tenant].deadline_router.fetch_add(1, Ordering::Relaxed);
                        sx.models[r.model].deadline_router.fetch_add(1, Ordering::Relaxed);
                    }
                    ex
                },
                || true,
            );
            if side_expired > 0 {
                // Side queues exist only under a router: the class books
                // always apply.
                class.deadline_drops.fetch_add(side_expired, Ordering::Relaxed);
                class.backlog.fetch_sub(side_expired, Ordering::SeqCst);
            }
        }
        if batch.is_empty() && retiring {
            if side_expired > 0 {
                continue; // expiries accounted; re-check for a remainder
            }
            break; // side queue drained — retirement complete
        }
        if batch.is_empty() {
            // No pinned work: drain the class queue (or, routerless, the
            // ingress) like any sibling. Deadline-passed requests are
            // discarded inside the queue lock: they must not waste a
            // batch slot, let alone a backend visit. The pop returns
            // promptly on an all-reject drain so the class backlog and
            // drop books update *before* the next routing decision — the
            // router must not see phantom backlog. The cancellation
            // predicate unparks workers (empty-handed) when the
            // autoscaler deposits a retire token — or the router lands
            // sticky work — while the queue is idle.
            let expired = queue.pop_batch_where_cancellable(
                batch_cap,
                &mut batch,
                |r| {
                    let ex = r.expired(Instant::now());
                    if ex {
                        // Attribute the expiry to its tenant and model
                        // here, where the item is still visible; in the
                        // routerless path the queue *is* the ingress, so
                        // the expiry also frees the tenant's quota slot.
                        sx.tenants[r.tenant].deadline_router.fetch_add(1, Ordering::Relaxed);
                        sx.models[r.model].deadline_router.fetch_add(1, Ordering::Relaxed);
                        if !routed && multi_tenant {
                            sx.tenants[r.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    ex
                },
                || class.retire.load(Ordering::SeqCst) > 0 || side_pending(),
            );
            if expired > 0 {
                class.deadline_drops.fetch_add(expired, Ordering::Relaxed);
                if routed {
                    class.backlog.fetch_sub(expired, Ordering::SeqCst);
                }
            }
            if batch.is_empty() {
                if expired > 0 {
                    continue; // expiries accounted; look for real work again
                }
                if side_pending() {
                    continue; // woken for pinned work — the top of the loop drains it
                }
                // Empty-handed: the stream ended, or a retire token woke
                // the class (claimed at the top of the loop — exactly one
                // worker gets it; the rest find it gone and park again).
                if class.retire.load(Ordering::SeqCst) > 0 {
                    continue;
                }
                if queue.is_closed() {
                    // Closed and drained, or aborted. Anything still on
                    // the side queue was pushed before the router exited —
                    // serve it before leaving (re-checked after observing
                    // the close, so no later push can be missed).
                    if side_pending() {
                        continue;
                    }
                    if let Some(sq) = &side {
                        if let Some(sc) = sx.sticky {
                            sc.deregister(wid);
                        }
                        sq.close();
                    }
                    break;
                }
                continue; // the token went to a sibling — look for work again
            }
        }
        let n = batch.len();
        metas.clear();
        maps.clear();
        streams.clear();
        events_buf.clear();
        for req in batch.drain(..) {
            // In the routerless path this pop took the request out of the
            // ingress queue, freeing its tenant's quota slot (the routed
            // path freed it when the router popped the ingress).
            if !routed && multi_tenant {
                sx.tenants[req.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
            }
            metas.push(Meta {
                label: req.label,
                tenant: req.tenant,
                model: req.model,
                arrival: req.arrival,
                bucket: req.bucket,
                predicted_s: req.predicted_s,
                deadline: req.deadline,
                sticky: req.sticky,
            });
            streams.push(req.stream);
            maps.push(req.map);
            events_buf.push(req.events);
        }
        let t0 = Instant::now();
        // Delta-capable backends take the stream-labelled entry point;
        // the plain path is adapted so both arms yield one result shape.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if use_delta {
                backend.classify_batch_delta(&streams, &maps)
            } else {
                backend
                    .classify_batch(&maps)
                    .into_iter()
                    .map(|r| r.map(|c| (c, DeltaStatus::NotApplicable)))
                    .collect()
            }
        }));
        let visit_s = t0.elapsed().as_secs_f64();
        let done = Instant::now();
        if routed {
            // The visit is over: these requests leave the class's routing
            // backlog whatever the outcome.
            class.backlog.fetch_sub(n, Ordering::SeqCst);
        }
        let results = match outcome {
            Ok(rs) => rs,
            Err(p) => {
                fail(format!("worker panic: {}", panic_message(p.as_ref())));
                break;
            }
        };
        if results.len() != n {
            // A broken Backend impl must fail loudly, not silently lose
            // requests to zip truncation.
            fail(format!(
                "backend '{}' returned {} result(s) for a batch of {n}",
                backend.name(),
                results.len(),
            ));
            break;
        }
        busy_s += visit_s;
        // Class-level busy books feed the autoscaler's windowed
        // utilization (cheap: one atomic add per accelerator visit).
        class.busy_us.fetch_add((visit_s * 1e6) as u64, Ordering::Relaxed);
        batch_sizes.push(n);
        // The visit is one accelerator pass; attribute its cost evenly
        // across the requests it served, and — when a router is making
        // decisions — teach it what this class actually costs at each
        // request's event-count bucket.
        let service_s = visit_s / n as f64;
        if routed {
            for m in &metas {
                class.cost.observe(m.bucket, service_s);
            }
        }
        let mut failed = false;
        for (i, (m, res)) in metas.iter().zip(results).enumerate() {
            match res {
                Ok((c, st)) => {
                    match st {
                        DeltaStatus::NotApplicable => delta.not_applicable += 1,
                        DeltaStatus::Hit { dirty_frac, recomputed_frac } => {
                            delta.hits += 1;
                            delta.dirty_frac_sum += dirty_frac;
                            delta.recomputed_frac_sum += recomputed_frac;
                        }
                        DeltaStatus::Full(FullReason::ColdCache) => delta.full_cold += 1,
                        DeltaStatus::Full(FullReason::Geometry) => delta.full_geometry += 1,
                        DeltaStatus::Full(FullReason::OverThreshold) => {
                            delta.full_over_threshold += 1;
                        }
                    }
                    let timing = RequestTiming {
                        e2e_s: done.duration_since(m.arrival).as_secs_f64(),
                        service_s,
                        sim_cycles: c.sim_cycles,
                    };
                    records.push(ServedRecord {
                        label: m.label,
                        tenant: m.tenant,
                        model: m.model,
                        pred: c.pred,
                        timing,
                        predicted_s: m.predicted_s,
                        met_deadline: m.deadline.map(|dl| done <= dl),
                        sticky: m.sticky,
                    });
                    // Shadow conformance: evaluated after the primary
                    // result is in the books — a mirrored visit is never
                    // served traffic and never delays a sibling's batch.
                    if let Some(sh) =
                        sx.models.get(m.model).and_then(|mc| mc.shadow.as_ref())
                    {
                        shadow_compare(sh, m.label, c.pred, &maps[i], events_buf[i].take());
                    }
                }
                Err(e) => {
                    fail(e.to_string());
                    failed = true;
                    break;
                }
            }
        }
        if failed {
            break;
        }
        // The batch is served: future windows of these streams should come
        // back here, where their freshly written caches live. A retiring
        // worker must not re-advertise itself.
        if use_delta && !retiring {
            if let (Some(sc), Some(_)) = (sx.sticky, &side) {
                for &s in streams.iter().flatten() {
                    sc.remember(s, wid);
                }
            }
        }
    }
    WorkerOutput { wid, class: ci, busy_s, records, batch_sizes, delta }
}
