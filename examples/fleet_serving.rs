// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Multi-model fleet serving demo: two models ("alpha", "beta") with
//! their own weights share one front door behind a weighted traffic mix.
//! Requests carry a model tag, the router treats it as a hard filter,
//! and each model keeps its own conservation books. Two fleet operations
//! run live: a **shadow** mirrors half of beta's served traffic to a
//! differently-trained candidate and counts bit-exact disagreements, and
//! a **hot swap** flips alpha's backend to a fresh build mid-run — gated
//! on observed progress, losing zero requests.
//!
//! With `--report-out path` a machine-readable JSON summary is written —
//! CI greps it for `null` to catch NaN/inf leaking into reports.
//!
//! Run: `cargo run --release --example fleet_serving`
//! (add `--smoke` for the quick CI-sized run)

use esda::coordinator::{
    run_pool_source, synthetic_source, Backend, BackendError, Classification, DropPolicy,
    Functional, MixSource, ReplicaPool, ReplicaSpec, ServerConfig, Shared, ShadowConfig,
    Swappable,
};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::model::quant::{quantize_network, QuantizedNet};
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::json::Json;
use esda::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Paces requests (so the mid-run swap actually lands mid-run) and
/// counts every classification across both models.
struct Paced {
    inner: Arc<dyn Backend>,
    calls: Arc<AtomicUsize>,
    delay: Duration,
}

impl Backend for Paced {
    fn name(&self) -> &str {
        "paced"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

/// A tiny quantized network for `profile` with its own weight seed —
/// distinct seeds give the fleet genuinely different models, so shadow
/// disagreements are real prediction divergence, not bookkeeping noise.
fn qnet_seeded(profile: &DatasetProfile, weight_seed: u64) -> QuantizedNet {
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, weight_seed);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    quantize_network(&spec, &weights, &calib)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["smoke"]).unwrap();
    let smoke = args.has("smoke");
    let profile = DatasetProfile::n_mnist();
    let n_offered = if smoke { 48 } else { 192 };

    // Alpha serves behind a Swappable handle (every replica delegates to
    // the same flip point); beta is a plain build with a shadow watching.
    let alpha = Arc::new(Swappable::new(
        "alpha",
        Arc::new(Functional::new(qnet_seeded(&profile, 5))) as Arc<dyn Backend>,
    ));
    let calls = Arc::new(AtomicUsize::new(0));
    let (ah, ac) = (Arc::clone(&alpha), Arc::clone(&calls));
    let beta_qnet = qnet_seeded(&profile, 6);
    let (bq, bc) = (beta_qnet.clone(), Arc::clone(&calls));
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::new("alpha-c", 2, 2, move |_| {
            Ok(Box::new(Paced {
                inner: Arc::new(Shared(Arc::clone(&ah) as Arc<dyn Backend>)),
                calls: Arc::clone(&ac),
                delay: Duration::from_millis(1),
            }))
        })
        .for_model("alpha"),
        ReplicaSpec::new("beta-c", 1, 2, move |_| {
            Ok(Box::new(Paced {
                inner: Arc::new(Functional::new(bq.clone())),
                calls: Arc::clone(&bc),
                delay: Duration::from_millis(1),
            }))
        })
        .for_model("beta"),
    ])
    .expect("pool build");

    // Shadow: a differently-seeded candidate mirrors half of beta's
    // served traffic; disagreements are real divergence between builds.
    let cfg = ServerConfig {
        n_requests: n_offered,
        seed: 42,
        queue_depth: 8,
        drop_policy: DropPolicy::Block,
        batch: 2,
        shadows: vec![ShadowConfig {
            model: "beta".into(),
            candidate: Arc::new(Functional::new(qnet_seeded(&profile, 7))),
            fraction: 0.5,
        }],
        ..Default::default()
    };

    // Hot swap: once a third of the stream has been classified, flip
    // alpha to a fresh build. Progress-gated (not wall-clock), so the
    // flip always lands with most of the stream still in flight.
    let next_build = Functional::new(qnet_seeded(&profile, 9));
    let swapper = {
        let (h, c) = (Arc::clone(&alpha), Arc::clone(&calls));
        std::thread::spawn(move || {
            while c.load(Ordering::SeqCst) < n_offered / 3 {
                std::thread::sleep(Duration::from_micros(200));
            }
            h.swap(Arc::new(next_build));
        })
    };

    // Traffic mix 2:1 — alpha gets two of every three requests.
    let src = MixSource::new(Box::new(synthetic_source(&profile, &cfg)), &[2, 1]);
    let r = run_pool_source(Box::new(src), &pool, &cfg).expect("fleet run");
    swapper.join().expect("swap thread");
    let m = &r.metrics;

    println!("== two-model fleet, shadowed beta, mid-run alpha swap ==");
    println!(
        "  {} served / {n_offered} offered | {} queue drop(s) | {} deadline shed(s)",
        m.total,
        m.dropped,
        m.deadline_drops(),
    );
    println!("{}", esda::report::model_table(m).render());
    if let Some(line) = esda::report::shadow_line(m) {
        println!("  {line}");
    }

    // The demo is also an acceptance check: the swap landed, nothing was
    // lost, and every model's books balance on their own.
    assert_eq!(alpha.generation(), 1, "the scheduled hot swap must have landed");
    let conservation_ok = m.total + m.dropped + m.deadline_drops() == n_offered;
    assert!(conservation_ok, "global books must cover the mixed stream");
    assert_eq!(m.total, n_offered, "blocking admission is lossless across the swap");
    assert_eq!(m.per_model.len(), 2, "one book per fleet model");
    let (a, b) = (&m.per_model[0], &m.per_model[1]);
    assert_eq!((a.model.as_str(), b.model.as_str()), ("alpha", "beta"));
    // The 2:1 mix splits the offered stream exactly.
    assert_eq!(a.offered(), 2 * n_offered / 3, "alpha books: {a:?}");
    assert_eq!(b.offered(), n_offered / 3, "beta books: {b:?}");
    assert!(b.shadow_mirrored >= 1, "the shadow must mirror some of beta's traffic");
    assert!(
        b.shadow_mirrored <= b.served,
        "mirrors are observations of served requests, never extra service"
    );
    assert_eq!(a.shadow_mirrored, 0, "no shadow was configured for alpha");
    let disagreement_rate = b.disagreement_rate().expect("mirrored > 0");
    println!(
        "alpha swapped after {} request(s); beta disagreement rate {:.1}% over {} mirror(s)",
        n_offered / 3,
        disagreement_rate * 100.0,
        b.shadow_mirrored
    );

    // Machine-readable summary (CI greps this for `null`).
    if let Some(out) = args.get("report-out") {
        let per_model: Vec<Json> = m
            .per_model
            .iter()
            .map(|ms| {
                Json::obj(vec![
                    ("model", Json::Str(ms.model.clone())),
                    ("classes", Json::Num(ms.classes as f64)),
                    ("served", Json::Num(ms.served as f64)),
                    ("dropped", Json::Num(ms.dropped as f64)),
                    ("deadline_drops", Json::Num(ms.deadline_drops() as f64)),
                    ("offered", Json::Num(ms.offered() as f64)),
                    ("shadow_mirrored", Json::Num(ms.shadow_mirrored as f64)),
                    ("shadow_disagreements", Json::Num(ms.shadow_disagreements as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("offered", Json::Num(n_offered as f64)),
            ("served", Json::Num(m.total as f64)),
            ("queue_drops", Json::Num(m.dropped as f64)),
            ("deadline_drops", Json::Num(m.deadline_drops() as f64)),
            ("conservation_ok", Json::Bool(conservation_ok)),
            ("swap_generation", Json::Num(alpha.generation() as f64)),
            ("swap_lost_requests", Json::Num((n_offered - m.total) as f64)),
            ("shadow_disagreement_rate", Json::Num(disagreement_rate)),
            ("per_model", Json::Arr(per_model)),
        ]);
        std::fs::write(out, doc.to_string()).expect("write report");
        println!("report written -> {out}");
    }
}
