//! Tiny CLI flag parser (clap is not vendored).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (usually `std::env::args().skip(1)`).
    /// `bool_flags` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if bool_flags.contains(&stripped) {
                    out.bools.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{stripped} expects a value"))?;
                    out.flags.insert(stripped.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// One entry of a `--pool` spec: a replica class name, its base replica
/// count, an optional autoscaling upper bound, and an optional
/// batch-affinity override.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolItem {
    pub class: String,
    /// Base (minimum) replica count.
    pub count: usize,
    /// `Some(m)` when spelled `class=min..max`: the autoscaler may grow
    /// the class up to `m` replicas. `None` pins the class at `count`.
    pub max: Option<usize>,
    /// `Some(b)` when spelled `class=count@b`; `None` leaves the class's
    /// default batch affinity in place.
    pub batch: Option<usize>,
}

/// Parse a `--pool` spec: a comma-separated list of
/// `class=count[@batch]` or `class=min..max[@batch]` entries, e.g.
/// `func=4,sim=1,dense=1`, `func=4@8,sim=1`, or `func=1..4,sim=1..2@1`.
pub fn parse_pool_spec(s: &str) -> Result<Vec<PoolItem>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let (class, rest) = part.split_once('=').ok_or_else(|| {
            format!("pool entry '{part}': expected class=count[@batch] or class=min..max[@batch]")
        })?;
        let (count_s, batch) = match rest.split_once('@') {
            Some((c, b)) => {
                let b: usize = b
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad batch '{b}'"))?;
                if b == 0 {
                    return Err(format!("pool entry '{part}': batch must be >= 1"));
                }
                (c, Some(b))
            }
            None => (rest, None),
        };
        let (count, max) = match count_s.split_once("..") {
            Some((lo, hi)) => {
                let lo: usize = lo
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad min count '{lo}'"))?;
                let hi: usize = hi
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad max count '{hi}'"))?;
                if hi < lo {
                    return Err(format!(
                        "pool entry '{part}': replica range must satisfy min <= max"
                    ));
                }
                (lo, Some(hi))
            }
            None => {
                let count: usize = count_s
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad count '{count_s}'"))?;
                (count, None)
            }
        };
        if count == 0 {
            return Err(format!("pool entry '{part}': count must be >= 1"));
        }
        if class.is_empty() {
            return Err(format!("pool entry '{part}': empty class name"));
        }
        out.push(PoolItem { class: class.to_string(), count, max, batch });
    }
    Ok(out)
}

/// A parsed `--source` spec: where the serving runtime's requests come
/// from.
#[derive(Debug, Clone, PartialEq)]
pub enum SourceSpec {
    /// The synthetic event camera (default).
    Synth,
    /// Replay a recorded `.esda` dataset at `speed`× wall-clock rate.
    Replay { path: String, speed: f64 },
    /// Follow a growing `.esda` file (camera-dump pipeline).
    Tail { path: String },
}

/// Parse a `--source` spec: `synth`, `replay:path[@speed]`, or
/// `tail:path`. The substring after the *last* `@` is the replay speed
/// when it parses as a number (which must then be finite and > 0);
/// a non-numeric suffix is simply part of the path, so
/// `replay:runs@v2/cap.esda` opens that file at 1× while
/// `replay:cap.esda@2.5` replays at 2.5×. A path whose final component
/// genuinely ends in `@<number>` needs an explicit speed suffix.
pub fn parse_source_spec(s: &str) -> Result<SourceSpec, String> {
    if s == "synth" {
        return Ok(SourceSpec::Synth);
    }
    if let Some(rest) = s.strip_prefix("replay:") {
        let (path, speed) = match rest.rsplit_once('@') {
            Some((p, sp)) => match sp.parse::<f64>() {
                Ok(v) if v.is_finite() && v > 0.0 => (p, v),
                Ok(v) => {
                    return Err(format!(
                        "--source replay: speed must be finite and > 0, got {v}"
                    ))
                }
                // Non-numeric suffix: the '@' belongs to the path.
                Err(_) => (rest, 1.0),
            },
            None => (rest, 1.0),
        };
        if path.is_empty() {
            return Err("--source replay: empty path".into());
        }
        return Ok(SourceSpec::Replay { path: path.to_string(), speed });
    }
    if let Some(path) = s.strip_prefix("tail:") {
        if path.is_empty() {
            return Err("--source tail: empty path".into());
        }
        return Ok(SourceSpec::Tail { path: path.to_string() });
    }
    Err(format!(
        "--source: expected synth | replay:path[@speed] | tail:path, got '{s}'"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], bools: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), bools).unwrap()
    }

    #[test]
    fn parses_flag_styles() {
        let a = parse(
            &["simulate", "--model=mbv2", "--steps", "100", "--verbose", "extra"],
            &["verbose"],
        );
        assert_eq!(a.positional(), &["simulate".to_string(), "extra".to_string()]);
        assert_eq!(a.get("model"), Some("mbv2"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("name", "d"), "d");
    }

    #[test]
    fn bad_int_reports_flag() {
        let a = parse(&["--steps", "abc"], &[]);
        let e = a.get_usize("steps", 0).unwrap_err();
        assert!(e.contains("steps"));
    }

    #[test]
    fn pool_spec_parses_counts_and_batch_overrides() {
        let items = parse_pool_spec("func=4,sim=1,dense=2").unwrap();
        assert_eq!(
            items,
            vec![
                PoolItem { class: "func".into(), count: 4, max: None, batch: None },
                PoolItem { class: "sim".into(), count: 1, max: None, batch: None },
                PoolItem { class: "dense".into(), count: 2, max: None, batch: None },
            ]
        );
        let items = parse_pool_spec("func=4@8, sim=1").unwrap();
        assert_eq!(items[0].batch, Some(8));
        assert_eq!(
            items[1],
            PoolItem { class: "sim".into(), count: 1, max: None, batch: None }
        );
    }

    /// The autoscaling range syntax: `class=min..max[@batch]`.
    #[test]
    fn pool_spec_parses_replica_ranges() {
        let items = parse_pool_spec("func=1..4,sim=2..2@1,dense=3").unwrap();
        assert_eq!(
            items,
            vec![
                PoolItem { class: "func".into(), count: 1, max: Some(4), batch: None },
                PoolItem { class: "sim".into(), count: 2, max: Some(2), batch: Some(1) },
                PoolItem { class: "dense".into(), count: 3, max: None, batch: None },
            ]
        );
    }

    #[test]
    fn pool_spec_rejects_malformed_entries() {
        for bad in [
            "", "func", "func=", "func=0", "=3", "func=2@0", "func=2@x", "func=4,,sim=1",
            "func=4..2", "func=0..2", "func=..2", "func=1..", "func=1..x", "func=x..2",
        ] {
            assert!(parse_pool_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn source_spec_parses_every_variant() {
        assert_eq!(parse_source_spec("synth").unwrap(), SourceSpec::Synth);
        assert_eq!(
            parse_source_spec("replay:data/n_mnist_test.esda").unwrap(),
            SourceSpec::Replay { path: "data/n_mnist_test.esda".into(), speed: 1.0 }
        );
        assert_eq!(
            parse_source_spec("replay:d.esda@2.5").unwrap(),
            SourceSpec::Replay { path: "d.esda".into(), speed: 2.5 }
        );
        assert_eq!(
            parse_source_spec("tail:/var/cam/dump.esda").unwrap(),
            SourceSpec::Tail { path: "/var/cam/dump.esda".into() }
        );
        // A non-numeric suffix after '@' is part of the path, not a
        // malformed speed.
        assert_eq!(
            parse_source_spec("replay:runs@v2/cap.esda").unwrap(),
            SourceSpec::Replay { path: "runs@v2/cap.esda".into(), speed: 1.0 }
        );
    }

    #[test]
    fn source_spec_rejects_malformed_entries() {
        for bad in [
            "", "nope", "replay:", "replay:@2", "tail:", "replay:d.esda@0",
            "replay:d.esda@-1", "replay:d.esda@inf", "replay:d.esda@nan",
        ] {
            assert!(parse_source_spec(bad).is_err(), "accepted '{bad}'");
        }
    }
}
