//! Functional network execution — the numerics oracle.
//!
//! Runs the flat op program over a sparse input map, in f32 (matches the
//! JAX model) or int8 (matches the hardware, bit-for-bit with `arch::sim`).
//! Residual blocks use a small value stack (ResFork pushes a copy, ResAdd
//! pops and adds), mirroring the paper's fork/FIFO/merge chaining (Fig. 10).
//!
//! An observer hook exposes every intermediate activation — used by the
//! quantization calibrator and by `hwopt::stats` to collect the per-layer
//! spatial/kernel sparsity statistics that drive Eqn. 5.

use super::graph::{NetworkSpec, Op};
use super::quant::QuantizedNet;
use super::weights::FloatWeights;
use crate::sparse::conv::{self};
use crate::sparse::SparseMap;

/// Intermediate value during execution.
#[derive(Clone, Debug)]
pub enum Value<T> {
    Map(SparseMap<T>),
    /// Post-pooling vector (f32 path: f32; i8 path: i32 accumulators).
    Vec(Vec<T>),
}

/// Observation passed to the per-op hook: op index and its output.
pub enum Observed<'a> {
    MapF32(&'a SparseMap<f32>),
    MapI8(&'a SparseMap<i8>),
    VecF32(&'a [f32]),
    VecI32(&'a [i32]),
}

/// f32 forward pass; returns logits.
pub fn forward_f32(spec: &NetworkSpec, w: &FloatWeights, input: &SparseMap<f32>) -> Vec<f32> {
    forward_f32_observed(spec, w, input, &mut |_i, _o| {})
}

/// f32 forward with a per-op observer.
pub fn forward_f32_observed(
    spec: &NetworkSpec,
    weights: &FloatWeights,
    input: &SparseMap<f32>,
    observe: &mut dyn FnMut(usize, Observed),
) -> Vec<f32> {
    assert_eq!(input.c, spec.cin, "input channels mismatch");
    assert_eq!((input.w, input.h), (spec.w, spec.h), "input geometry mismatch");
    let ops = spec.ops();
    let mut cur = SparseMap::clone(input);
    let mut stack: Vec<SparseMap<f32>> = Vec::new();
    let mut pooled: Vec<f32> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        let ow = &weights.per_op[i];
        match *op {
            Op::Conv1x1 { cout, act, .. } => {
                cur = conv::conv1x1_f32(&cur, &ow.w, &ow.b, cout, act);
                observe(i, Observed::MapF32(&cur));
            }
            Op::ConvKxK { k, cout, stride, act, .. } => {
                cur = if stride == 1 {
                    conv::conv_kxk_s1_f32(&cur, k, &ow.w, &ow.b, cout, act)
                } else {
                    conv::conv_kxk_s2_f32(&cur, k, &ow.w, &ow.b, cout, act)
                };
                observe(i, Observed::MapF32(&cur));
            }
            Op::DwConv { k, stride, act, .. } => {
                cur = if stride == 1 {
                    conv::dwconv_kxk_s1_f32(&cur, k, &ow.w, &ow.b, act)
                } else {
                    conv::dwconv_kxk_s2_f32(&cur, k, &ow.w, &ow.b, act)
                };
                observe(i, Observed::MapF32(&cur));
            }
            Op::ResFork => {
                stack.push(cur.clone());
                observe(i, Observed::MapF32(&cur));
            }
            Op::ResAdd => {
                let shortcut = stack.pop().expect("ResAdd without matching ResFork");
                cur = conv::residual_add_f32(&cur, &shortcut);
                observe(i, Observed::MapF32(&cur));
            }
            Op::GlobalPool { .. } => {
                pooled = conv::global_avg_pool_f32(&cur);
                observe(i, Observed::VecF32(&pooled));
            }
            Op::Fc { cout, .. } => {
                pooled = conv::fc_f32(&pooled, &ow.w, &ow.b, cout);
                observe(i, Observed::VecF32(&pooled));
            }
        }
    }
    assert!(stack.is_empty(), "unbalanced ResFork/ResAdd");
    pooled
}

/// int8 forward pass (hardware-exact); quantizes the f32 input with the
/// calibrated input scale, returns int32 logits.
pub fn forward_i8(qnet: &QuantizedNet, input: &SparseMap<f32>) -> Vec<i32> {
    forward_i8_observed(qnet, input, &mut |_i, _o| {})
}

/// Quantize a float input map with the network's input scale.
pub fn quantize_input(qnet: &QuantizedNet, input: &SparseMap<f32>) -> SparseMap<i8> {
    let mut q: SparseMap<i8> = SparseMap::empty(input.w, input.h, input.c);
    q.tokens = input.tokens.clone();
    q.feats = input
        .feats
        .iter()
        .map(|&v| ((v / qnet.input_scale).round() as i32).clamp(-128, 127) as i8)
        .collect();
    q
}

/// int8 forward with observer.
pub fn forward_i8_observed(
    qnet: &QuantizedNet,
    input: &SparseMap<f32>,
    observe: &mut dyn FnMut(usize, Observed),
) -> Vec<i32> {
    let spec = &qnet.spec;
    assert_eq!(input.c, spec.cin);
    let ops = spec.ops();
    let mut cur = quantize_input(qnet, input);
    let mut stack: Vec<SparseMap<i8>> = Vec::new();
    let mut pooled: Vec<i32> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Conv1x1 { cout, .. } => {
                let q = qnet.per_op[i].as_ref().unwrap();
                cur = conv::conv1x1_i8(&cur, &q.w, &q.b, cout, &q.rq);
                observe(i, Observed::MapI8(&cur));
            }
            Op::ConvKxK { k, cout, stride, .. } => {
                let q = qnet.per_op[i].as_ref().unwrap();
                cur = if stride == 1 {
                    conv::conv_kxk_s1_i8(&cur, k, &q.w, &q.b, cout, &q.rq)
                } else {
                    conv::conv_kxk_s2_i8(&cur, k, &q.w, &q.b, cout, &q.rq)
                };
                observe(i, Observed::MapI8(&cur));
            }
            Op::DwConv { k, stride, .. } => {
                let q = qnet.per_op[i].as_ref().unwrap();
                cur = if stride == 1 {
                    conv::dwconv_kxk_s1_i8(&cur, k, &q.w, &q.b, &q.rq)
                } else {
                    conv::dwconv_kxk_s2_i8(&cur, k, &q.w, &q.b, &q.rq)
                };
                observe(i, Observed::MapI8(&cur));
            }
            Op::ResFork => {
                stack.push(cur.clone());
                observe(i, Observed::MapI8(&cur));
            }
            Op::ResAdd => {
                let shortcut = stack.pop().expect("ResAdd without ResFork");
                cur = conv::residual_add_i8(&cur, &shortcut);
                observe(i, Observed::MapI8(&cur));
            }
            Op::GlobalPool { .. } => {
                pooled = conv::global_avg_pool_i8(&cur);
                observe(i, Observed::VecI32(&pooled));
            }
            Op::Fc { cout, .. } => {
                let q = qnet.per_op[i].as_ref().unwrap();
                pooled = conv::fc_i8(&pooled, &q.w, &q.b, cout);
                observe(i, Observed::VecI32(&pooled));
            }
        }
    }
    pooled
}

/// Full k×k submanifold conv, stride 1, int8 (the stem layer). Kept as a
/// compatibility alias — the kernel now lives in
/// [`conv::conv_kxk_s1_i8`] next to its `_into` arena variant.
pub fn conv_full_s1_i8(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &crate::sparse::quant::Requant,
) -> SparseMap<i8> {
    conv::conv_kxk_s1_i8(input, k, w, bias, cout, rq)
}

/// Classify a float input through the hardware-exact int8 path
/// (quantize → forward → argmax) — the functional serving backend.
pub fn classify_i8(qnet: &QuantizedNet, input: &SparseMap<f32>) -> usize {
    argmax(&forward_i8(qnet, input))
}

/// Argmax helper for classification outputs.
pub fn argmax<T: PartialOrd + Copy>(xs: &[T]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::quant::quantize_network;
    use crate::util::Rng;

    fn small_input(seed: u64) -> SparseMap<f32> {
        let p = DatasetProfile::n_mnist();
        let mut rng = Rng::new(seed);
        let es = p.sample(seed as usize % p.n_classes, &mut rng);
        histogram2_norm(&es, p.w, p.h, 8.0)
    }

    #[test]
    fn f32_forward_produces_logits() {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, 1);
        let input = small_input(3);
        let logits = forward_f32(&spec, &w, &input);
        assert_eq!(logits.len(), 5);
        assert!(logits.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn observer_sees_every_op() {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, 1);
        let input = small_input(4);
        let mut seen = Vec::new();
        forward_f32_observed(&spec, &w, &input, &mut |i, _| seen.push(i));
        assert_eq!(seen, (0..spec.ops().len()).collect::<Vec<_>>());
    }

    #[test]
    fn submanifold_keeps_tokens_through_stride1_ops() {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, 2);
        let input = small_input(5);
        let in_tokens = input.tokens.clone();
        let ops = spec.ops();
        forward_f32_observed(&spec, &w, &input, &mut |i, o| {
            if let Observed::MapF32(m) = o {
                // Until the first stride-2 op, tokens must equal the input's.
                let first_s2 = ops.iter().position(|o| o.stride() == 2).unwrap();
                if i < first_s2 {
                    assert_eq!(m.tokens, in_tokens, "op {i} changed tokens");
                }
            }
        });
    }

    /// With untrained random weights the logits are nearly tied, so argmax
    /// agreement is not a meaningful metric; instead require a strong
    /// correlation between f32 logits and dequantized int8 logits.
    #[test]
    fn i8_logits_correlate_with_f32() {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, 7);
        let calib: Vec<SparseMap<f32>> = (0..4).map(|s| small_input(s)).collect();
        let qnet = quantize_network(&spec, &w, &calib);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for s in 10..18u64 {
            let input = small_input(s);
            let lf = forward_f32(&spec, &w, &input);
            let li = forward_i8(&qnet, &input);
            assert_eq!(li.len(), 5);
            // Center per sample to remove the shared offset.
            let mf = lf.iter().sum::<f32>() / 5.0;
            let mi = li.iter().sum::<i32>() as f32 / 5.0;
            xs.extend(lf.iter().map(|&v| v - mf));
            ys.extend(li.iter().map(|&v| v as f32 - mi));
        }
        let dot: f32 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        let nx: f32 = xs.iter().map(|a| a * a).sum::<f32>().sqrt();
        let ny: f32 = ys.iter().map(|b| b * b).sum::<f32>().sqrt();
        let corr = dot / (nx * ny).max(1e-9);
        assert!(corr > 0.9, "f32/int8 logit correlation too low: {corr}");
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5, -2, 5]), 0); // first max wins
    }

    #[test]
    fn classify_i8_matches_manual_path() {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, 11);
        let calib: Vec<SparseMap<f32>> = (0..2u64).map(small_input).collect();
        let qnet = quantize_network(&spec, &w, &calib);
        let input = small_input(6);
        assert_eq!(classify_i8(&qnet, &input), argmax(&forward_i8(&qnet, &input)));
    }
}
