"""L2: the JAX network, mirroring ``rust/src/model/graph.rs`` exactly.

The block → primitive-op expansion must match the rust side op-for-op:
weight tensors are exchanged as ``op{i}.w`` / ``op{i}.b`` keyed by the op
index, and the rust loader validates shapes against its own expansion —
any drift fails loudly at load time.

``forward`` runs the op program with either the Pallas kernels
(``use_pallas=True`` — the configuration that gets AOT-lowered, so the L1
kernels end up inside the HLO artifact) or the pure-jnp reference
(training, where speed matters and equality is covered by pytest).
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels import submanifold as pk

# ---------------------------------------------------------------------------
# Block / op expansion (mirror of graph.rs)
# ---------------------------------------------------------------------------


def stem(k, cout, stride):
    return {"kind": "stem", "k": k, "cout": cout, "stride": stride}


def mbconv(cout, expand, k, stride):
    return {"kind": "mbconv", "cout": cout, "expand": expand, "k": k, "stride": stride}


def conv1x1_block(cout, act="relu6"):
    return {"kind": "conv1x1", "cout": cout, "act": act}


def pool_fc_block():
    return {"kind": "pool_fc"}


def expand_ops(spec):
    """Blocks → primitive op list (mirror of NetworkSpec::ops)."""
    ops = []
    c = spec["cin"]
    for b in spec["blocks"]:
        kind = b["kind"]
        if kind == "stem":
            ops.append({"op": "conv_kxk", "k": b["k"], "cin": c, "cout": b["cout"],
                        "stride": b["stride"], "act": "relu6"})
            c = b["cout"]
        elif kind == "mbconv":
            residual = b["stride"] == 1 and c == b["cout"]
            ce = c * b["expand"]
            if residual:
                ops.append({"op": "res_fork"})
            if b["expand"] != 1:
                ops.append({"op": "conv1x1", "cin": c, "cout": ce, "act": "relu6"})
            ops.append({"op": "dwconv", "k": b["k"], "c": ce, "stride": b["stride"],
                        "act": "relu6"})
            ops.append({"op": "conv1x1", "cin": ce, "cout": b["cout"], "act": "none"})
            if residual:
                ops.append({"op": "res_add"})
            c = b["cout"]
        elif kind == "conv1x1":
            ops.append({"op": "conv1x1", "cin": c, "cout": b["cout"], "act": b["act"]})
            c = b["cout"]
        elif kind == "pool_fc":
            ops.append({"op": "global_pool", "c": c})
            ops.append({"op": "fc", "cin": c, "cout": spec["n_classes"]})
        else:
            raise ValueError(kind)
    return ops


def tiny(w, h, n_classes):
    return {
        "name": "tiny", "w": w, "h": h, "cin": 2, "n_classes": n_classes,
        "blocks": [
            stem(3, 4, 1),
            mbconv(4, 2, 3, 1),
            mbconv(8, 2, 3, 2),
            pool_fc_block(),
        ],
    }


def compact(w, h, n_classes):
    return {
        "name": "compact", "w": w, "h": h, "cin": 2, "n_classes": n_classes,
        "blocks": [
            stem(3, 8, 1),
            mbconv(12, 2, 3, 2),
            mbconv(12, 2, 3, 1),
            mbconv(24, 2, 3, 2),
            mbconv(24, 2, 3, 1),
            mbconv(48, 2, 3, 2),
            conv1x1_block(96, "relu6"),
            pool_fc_block(),
        ],
    }


def mobilenet_v2_05(w, h, n_classes):
    stages = [(8, 1, 1, 1), (12, 6, 2, 2), (16, 6, 2, 3), (32, 6, 2, 4),
              (48, 6, 1, 3), (80, 6, 2, 3), (160, 6, 1, 1)]
    blocks = [stem(3, 16, 2)]
    for cout, expand, stride, repeats in stages:
        for r in range(repeats):
            blocks.append(mbconv(cout, expand, 3, stride if r == 0 else 1))
    blocks.append(conv1x1_block(640, "relu6"))
    blocks.append(pool_fc_block())
    return {"name": "mbv2", "w": w, "h": h, "cin": 2, "n_classes": n_classes,
            "blocks": blocks}


BUILDERS = {"tiny": tiny, "compact": compact, "mbv2": mobilenet_v2_05}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def op_param_shapes(op):
    """Weight/bias shapes for one op (None for weightless ops)."""
    o = op["op"]
    if o == "conv1x1":
        return (op["cin"], op["cout"]), (op["cout"],)
    if o == "conv_kxk":
        return (op["k"], op["k"], op["cin"], op["cout"]), (op["cout"],)
    if o == "dwconv":
        return (op["k"], op["k"], op["c"]), (op["c"],)
    if o == "fc":
        return (op["cin"], op["cout"]), (op["cout"],)
    return None, None


def init_params(spec, key):
    """He-init parameters as {op{i}.w / op{i}.b: array}."""
    import jax

    params = {}
    for i, op in enumerate(expand_ops(spec)):
        wshape, bshape = op_param_shapes(op)
        if wshape is None:
            continue
        key, sub = jax.random.split(key)
        fan_in = {
            "conv1x1": lambda: op["cin"],
            "conv_kxk": lambda: op["k"] * op["k"] * op["cin"],
            "dwconv": lambda: op["k"] * op["k"],
            "fc": lambda: op["cin"],
        }[op["op"]]()
        std = (2.0 / fan_in) ** 0.5
        params[f"op{i}.w"] = jax.random.normal(sub, wshape, jnp.float32) * std
        params[f"op{i}.b"] = jnp.zeros(bshape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(spec, params, x, use_pallas=False):
    """Run the network on one dense sample x: (H, W, cin) f32.

    The mask (token set) is derived from the input — a pixel is a token iff
    any channel is nonzero, exactly as the rust `SparseMap::from_dense`.
    Returns logits (n_classes,).
    """
    mask = jnp.any(jnp.abs(x) > 0, axis=-1)
    cur, m = x, mask
    stack = []
    pooled = None
    for i, op in enumerate(expand_ops(spec)):
        o = op["op"]
        w = params.get(f"op{i}.w")
        b = params.get(f"op{i}.b")
        if o == "conv1x1":
            fn = pk.pointwise if use_pallas else ref.conv1x1
            cur, m = fn(cur, m, w, b, act=op["act"])
        elif o == "conv_kxk":
            fn = pk.conv3x3 if use_pallas else ref.submanifold_conv
            cur, m = fn(cur, m, w, b, stride=op["stride"], act=op["act"])
        elif o == "dwconv":
            fn = pk.dwconv3x3 if use_pallas else ref.submanifold_dwconv
            cur, m = fn(cur, m, w, b, stride=op["stride"], act=op["act"])
        elif o == "res_fork":
            stack.append((cur, m))
        elif o == "res_add":
            sc, _ = stack.pop()
            cur = ref.residual_add(cur, sc, m)
        elif o == "global_pool":
            if use_pallas:
                pooled_input = (cur, m)
            else:
                pooled_input = (cur, m)
            # pool happens inside fc below for the pallas head
            pooled = pooled_input
        elif o == "fc":
            cur_x, cur_m = pooled
            if use_pallas:
                return pk.pool_fc(cur_x, cur_m, w, b)
            return ref.global_pool_fc(cur_x, cur_m, w, b)
        else:
            raise ValueError(o)
    raise RuntimeError("network must end in pool_fc")


def forward_batch(spec, params, xs, use_pallas=False):
    """vmapped batched forward (training path)."""
    import jax

    return jax.vmap(lambda x: forward(spec, params, x, use_pallas))(xs)
