//! Residual chaining modules — paper §3.3.7, Fig. 10.
//!
//! [`ForkMod`] duplicates the token-feature stream (identity shortcut);
//! the shortcut side is a plain deep FIFO channel; [`AddMod`] merges the
//! two branches with a saturating int8 add. Submanifold convolution
//! guarantees both branches carry identical token sequences, which AddMod
//! asserts.

use super::module::Module;
use super::stream::{ChanId, Fabric, Item, ModStats};

/// Stream fork: one input, two outputs, both must be ready.
pub struct ForkMod {
    name: String,
    in_ch: ChanId,
    out_a: ChanId,
    out_b: ChanId,
    stats: ModStats,
    done: bool,
}

impl ForkMod {
    pub fn new(name: impl Into<String>, in_ch: ChanId, out_a: ChanId, out_b: ChanId) -> Self {
        ForkMod { name: name.into(), in_ch, out_a, out_b, stats: ModStats::default(), done: false }
    }
}

impl Module for ForkMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        if fab.peek(self.in_ch).is_none() {
            self.stats.stall_in += 1;
            return;
        }
        if !(fab.can_push(self.out_a) && fab.can_push(self.out_b)) {
            self.stats.stall_out += 1;
            return;
        }
        let item = fab.chan(self.in_ch).pop().unwrap();
        self.stats.consumed += 1;
        if item.is_end() {
            self.done = true;
        }
        fab.chan(self.out_a).push(item.clone());
        fab.chan(self.out_b).push(item);
        self.stats.produced += 2;
        self.stats.busy += 1;
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Residual merge: element-wise saturating add of two synchronized streams.
pub struct AddMod {
    name: String,
    in_a: ChanId,
    in_b: ChanId,
    out_ch: ChanId,
    stats: ModStats,
    done: bool,
}

impl AddMod {
    pub fn new(name: impl Into<String>, in_a: ChanId, in_b: ChanId, out_ch: ChanId) -> Self {
        AddMod { name: name.into(), in_a, in_b, out_ch, stats: ModStats::default(), done: false }
    }
}

impl Module for AddMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        if fab.peek(self.in_a).is_none() || fab.peek(self.in_b).is_none() {
            self.stats.stall_in += 1;
            return;
        }
        if !fab.can_push(self.out_ch) {
            self.stats.stall_out += 1;
            return;
        }
        let a = fab.chan(self.in_a).pop().unwrap();
        let b = fab.chan(self.in_b).pop().unwrap();
        self.stats.consumed += 2;
        let out = match (a, b) {
            (Item::End, Item::End) => {
                self.done = true;
                Item::End
            }
            (Item::Feat { t: ta, f: fa }, Item::Feat { t: tb, f: fb }) => {
                assert_eq!(ta, tb, "{}: residual branches desynchronized", self.name);
                let f = fa
                    .iter()
                    .zip(&fb)
                    .map(|(&x, &y)| (x as i32 + y as i32).clamp(-128, 127) as i8)
                    .collect();
                Item::Feat { t: ta, f }
            }
            (a, b) => panic!("{}: mismatched branch items {a:?} / {b:?}", self.name),
        };
        fab.chan(self.out_ch).push(out);
        self.stats.produced += 1;
        self.stats.busy += 1;
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Token;

    #[test]
    fn fork_then_add_is_doubling() {
        let mut fab = Fabric::default();
        let ch_in = fab.add_chan(4);
        let ch_a = fab.add_chan(4);
        let ch_b = fab.add_chan(16);
        let ch_out = fab.add_chan(4);
        let mut fork = ForkMod::new("fork", ch_in, ch_a, ch_b);
        let mut add = AddMod::new("add", ch_a, ch_b, ch_out);

        fab.chan(ch_in).push(Item::Feat { t: Token::new(1, 0), f: vec![5, -3, 100] });
        fab.chan(ch_in).push(Item::Feat { t: Token::new(2, 0), f: vec![-100, 0, 1] });
        fab.chan(ch_in).push(Item::End);

        let mut outs = Vec::new();
        for _ in 0..32 {
            add.step(&mut fab);
            fork.step(&mut fab);
            while let Some(i) = fab.chan(ch_out).pop() {
                outs.push(i);
            }
        }
        assert!(add.done() && fork.done());
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0], Item::Feat { t: Token::new(1, 0), f: vec![10, -6, 127] }); // saturates
        assert_eq!(outs[1], Item::Feat { t: Token::new(2, 0), f: vec![-128, 0, 2] });
        assert!(outs[2].is_end());
    }

    #[test]
    fn fork_blocks_until_both_ready() {
        let mut fab = Fabric::default();
        let ch_in = fab.add_chan(4);
        let ch_a = fab.add_chan(1);
        let ch_b = fab.add_chan(1);
        let mut fork = ForkMod::new("fork", ch_in, ch_a, ch_b);
        fab.chan(ch_in).push(Item::Feat { t: Token::new(0, 0), f: vec![1] });
        fab.chan(ch_in).push(Item::End);
        fork.step(&mut fab); // moves first item
        fork.step(&mut fab); // blocked: ch_a/ch_b full
        assert_eq!(fork.stats().stall_out, 1);
        assert_eq!(fab.chan(ch_a).len(), 1);
        // Drain one side only — still blocked.
        fab.chan(ch_a).pop();
        fork.step(&mut fab);
        assert_eq!(fork.stats().stall_out, 2);
        fab.chan(ch_b).pop();
        fork.step(&mut fab);
        assert!(fork.done());
    }

    #[test]
    #[should_panic(expected = "desynchronized")]
    fn add_panics_on_token_mismatch() {
        let mut fab = Fabric::default();
        let a = fab.add_chan(2);
        let b = fab.add_chan(2);
        let o = fab.add_chan(2);
        let mut add = AddMod::new("add", a, b, o);
        fab.chan(a).push(Item::Feat { t: Token::new(0, 0), f: vec![1] });
        fab.chan(b).push(Item::Feat { t: Token::new(1, 0), f: vec![1] });
        add.step(&mut fab);
    }
}
