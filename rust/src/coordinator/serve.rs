//! The sharded multi-worker serving runtime.
//!
//! ```text
//!                                     ┌─ accel worker 0 ─┐
//! event source → repr builder → ingress├─ accel worker 1 ─┤→ merged metrics
//!  (synthetic     (histogram2)   queue │       …          │  + predictions
//!   camera)                    (admission└─ accel worker N ┘
//!                               control)
//! ```
//!
//! The source and representation stages run on their own threads (the
//! "processing system" of Fig. 2); classified requests fan out over a pool
//! of N accelerator replicas sharing one [`Backend`] via `&self`. The
//! ingress queue applies admission control: `Block` exerts backpressure
//! (lossless, the paper's batch-1 deployment), `DropOldest` sheds stale
//! load under saturation and counts every drop.
//!
//! Worker panics and backend errors are caught and surfaced as
//! [`PipelineError`] — they never poison a join — and requests that were
//! admitted but not classified when the run aborts are counted as
//! `in_flight`.

use super::backend::Backend;
use super::metrics::{Metrics, PercentileReport, RequestTiming, WorkerStats};
use super::queue::{AdmissionQueue, DropPolicy};
use crate::events::{repr::histogram2_norm, DatasetProfile};
use crate::sparse::SparseMap;
use crate::util::{panic_message, Rng};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::Instant;

/// Serving-runtime configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of requests the synthetic source generates.
    pub n_requests: usize,
    /// Source seed (fixes the request stream).
    pub seed: u64,
    /// Histogram clip value.
    pub clip: f32,
    /// Accelerator worker replicas.
    pub workers: usize,
    /// Ingress/stage queue depth.
    pub queue_depth: usize,
    /// Admission control policy when the ingress queue saturates.
    pub drop_policy: DropPolicy,
    /// Max requests a worker drains from the ingress queue per wakeup
    /// (micro-batch cap; 1 = classic one-at-a-time). Workers never wait to
    /// fill a batch — they take what is already queued — so batching adds
    /// no latency when the system is unloaded and amortizes per-visit
    /// backend overhead when it is saturated.
    pub batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_requests: 32,
            seed: 1,
            clip: 8.0,
            workers: 1,
            queue_depth: 4,
            drop_policy: DropPolicy::Block,
            batch: 1,
        }
    }
}

/// One served request's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prediction {
    /// Ground-truth class of the synthetic recording.
    pub label: usize,
    /// Backend's predicted class.
    pub pred: usize,
    /// Worker replica that served it.
    pub worker: usize,
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServerResult {
    pub metrics: Metrics,
    /// Per-request outcomes, grouped by worker (use as a multiset: the
    /// worker interleaving is scheduling-dependent).
    pub predictions: Vec<Prediction>,
}

/// A serving run that aborted: the first backend error or worker panic,
/// plus how much work completed and how much was stranded.
#[derive(Debug, Clone)]
pub struct PipelineError {
    pub msg: String,
    /// Requests classified before the abort.
    pub completed: usize,
    /// Requests admitted but never classified.
    pub in_flight: usize,
}

impl fmt::Display for PipelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serving aborted after {} request(s) ({} in flight): {}",
            self.completed, self.in_flight, self.msg
        )
    }
}

impl std::error::Error for PipelineError {}

struct Request {
    label: usize,
    map: SparseMap<f32>,
    enqueued: Instant,
}

/// Per-worker raw output collected at join time:
/// `(worker id, busy seconds, served records, per-visit batch sizes)`.
type WorkerOutput = (usize, f64, Vec<(usize, usize, RequestTiming)>, Vec<usize>);

/// Run the serving pipeline to completion over `cfg.n_requests` synthetic
/// requests, fanning the accelerator stage out over `cfg.workers` replicas.
pub fn run_server(
    profile: &DatasetProfile,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(cfg.workers >= 1, "need at least one worker replica");
    let t_start = Instant::now();
    let queue: AdmissionQueue<Request> = AdmissionQueue::new(cfg.queue_depth, cfg.drop_policy);
    let first_error: Mutex<Option<String>> = Mutex::new(None);
    let (tx_ev, rx_ev) =
        sync_channel::<(usize, Vec<crate::events::Event>)>(cfg.queue_depth.max(1));

    let mut outputs: Vec<WorkerOutput> = Vec::new();
    std::thread::scope(|s| {
        // Stage 1: synthetic event camera.
        let p1 = profile.clone();
        let (n, seed) = (cfg.n_requests, cfg.seed);
        let source = s.spawn(move || {
            let mut rng = Rng::new(seed);
            for i in 0..n {
                let class = i % p1.n_classes;
                let events = p1.sample(class, &mut rng);
                if tx_ev.send((class, events)).is_err() {
                    return; // downstream hung up early
                }
            }
        });

        // Stage 2: representation builder + admission control.
        let (w, h, clip) = (profile.w, profile.h, cfg.clip);
        let queue_ref = &queue;
        let repr = s.spawn(move || {
            for (label, events) in rx_ev.iter() {
                let map = histogram2_norm(&events, w, h, clip);
                let req = Request { label, map, enqueued: Instant::now() };
                if queue_ref.push(req).is_err() {
                    break; // queue closed by an aborting worker
                }
            }
            queue_ref.close();
        });

        // Stage 3: the accelerator worker pool. Each wakeup drains up to
        // `cfg.batch` already-queued requests and classifies them in one
        // backend visit (`classify_batch`), so backends that amortize
        // per-visit setup — the functional plan arena, the dense engine's
        // lock — see the whole micro-batch.
        let error_ref = &first_error;
        let batch_cap = cfg.batch.max(1);
        let handles: Vec<_> = (0..cfg.workers)
            .map(|wid| {
                s.spawn(move || {
                    let mut records: Vec<(usize, usize, RequestTiming)> = Vec::new();
                    let mut batch_sizes: Vec<usize> = Vec::new();
                    let mut busy_s = 0.0f64;
                    let mut batch: Vec<Request> = Vec::with_capacity(batch_cap);
                    let mut metas: Vec<(usize, Instant)> = Vec::with_capacity(batch_cap);
                    let mut maps: Vec<SparseMap<f32>> = Vec::with_capacity(batch_cap);
                    loop {
                        queue_ref.pop_batch(batch_cap, &mut batch);
                        if batch.is_empty() {
                            break; // closed and drained, or aborted
                        }
                        let n = batch.len();
                        metas.clear();
                        maps.clear();
                        for req in batch.drain(..) {
                            metas.push((req.label, req.enqueued));
                            maps.push(req.map);
                        }
                        let t0 = Instant::now();
                        let outcome =
                            catch_unwind(AssertUnwindSafe(|| backend.classify_batch(&maps)));
                        let visit_s = t0.elapsed().as_secs_f64();
                        let results = match outcome {
                            Ok(rs) => rs,
                            Err(p) => {
                                let mut slot = error_ref.lock().unwrap();
                                slot.get_or_insert_with(|| {
                                    format!("worker panic: {}", panic_message(p.as_ref()))
                                });
                                queue_ref.abort();
                                break;
                            }
                        };
                        if results.len() != n {
                            // A broken Backend impl must fail loudly, not
                            // silently lose requests to zip truncation.
                            let mut slot = error_ref.lock().unwrap();
                            slot.get_or_insert_with(|| {
                                format!(
                                    "backend '{}' returned {} result(s) for a batch of {n}",
                                    backend.name(),
                                    results.len(),
                                )
                            });
                            queue_ref.abort();
                            break;
                        }
                        busy_s += visit_s;
                        batch_sizes.push(n);
                        // The visit is one accelerator pass; attribute its
                        // cost evenly across the requests it served.
                        let service_s = visit_s / n as f64;
                        let mut failed = false;
                        for (&(label, enqueued), res) in metas.iter().zip(results) {
                            match res {
                                Ok(c) => {
                                    let timing = RequestTiming {
                                        e2e_s: enqueued.elapsed().as_secs_f64(),
                                        service_s,
                                        sim_cycles: c.sim_cycles,
                                    };
                                    records.push((label, c.pred, timing));
                                }
                                Err(e) => {
                                    let mut slot = error_ref.lock().unwrap();
                                    slot.get_or_insert_with(|| e.to_string());
                                    queue_ref.abort();
                                    failed = true;
                                    break;
                                }
                            }
                        }
                        if failed {
                            break;
                        }
                    }
                    (wid, busy_s, records, batch_sizes)
                })
            })
            .collect();

        outputs = handles.into_iter().map(|h| h.join().expect("worker thread")).collect();
        repr.join().expect("repr thread");
        source.join().expect("source thread");
    });

    outputs.sort_by_key(|(wid, _, _, _)| *wid);
    let (submitted, dropped, _still_queued) = queue.stats();
    let processed: usize = outputs.iter().map(|(_, _, r, _)| r.len()).sum();
    let in_flight = submitted.saturating_sub(dropped + processed);

    if let Some(msg) = first_error.into_inner().unwrap() {
        return Err(PipelineError { msg, completed: processed, in_flight });
    }
    // Clean completion conserves requests: everything admitted was either
    // served or dropped (stranded requests only exist on the Err path).
    debug_assert_eq!(in_flight, 0, "completed run stranded {in_flight} request(s)");

    let wall_s = t_start.elapsed().as_secs_f64();
    let mut metrics = Metrics { started: t_start, dropped, wall_s, ..Metrics::default() };
    let mut predictions = Vec::with_capacity(processed);
    for (wid, busy_s, records, batch_sizes) in &outputs {
        let service: Vec<f64> = records.iter().map(|(_, _, t)| t.service_s).collect();
        let e2e: Vec<f64> = records.iter().map(|(_, _, t)| t.e2e_s).collect();
        let batches: Vec<f64> = batch_sizes.iter().map(|&b| b as f64).collect();
        metrics.per_worker.push(WorkerStats {
            worker: *wid,
            served: records.len(),
            batches: batch_sizes.len(),
            busy_s: *busy_s,
            service: PercentileReport::from_samples(&service),
            e2e: PercentileReport::from_samples(&e2e),
            batch: PercentileReport::from_samples(&batches),
        });
        metrics.batch_sizes.extend_from_slice(batch_sizes);
        for &(label, pred, timing) in records {
            metrics.record(timing, pred == label);
            predictions.push(Prediction { label, pred, worker: *wid });
        }
    }
    Ok(ServerResult { metrics, predictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;
    use crate::coordinator::backend::{BackendError, Classification, Functional, Simulator};
    use crate::coordinator::testutil::qnet_for;

    #[test]
    fn pool_processes_all_requests() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig { n_requests: 12, seed: 4, workers: 3, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 12);
        assert_eq!(r.predictions.len(), 12);
        assert_eq!(r.metrics.dropped, 0);
        assert_eq!(r.metrics.per_worker.len(), 3);
        assert_eq!(r.metrics.per_worker.iter().map(|w| w.served).sum::<usize>(), 12);
        assert!(r.metrics.throughput() > 0.0);
    }

    /// Micro-batching is a scheduling detail: every request is still served
    /// exactly once, and the batch-size books stay consistent.
    #[test]
    fn batched_pool_serves_every_request_once() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig {
            n_requests: 20,
            seed: 6,
            workers: 2,
            queue_depth: 8,
            batch: 4,
            ..Default::default()
        };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 20);
        assert_eq!(r.predictions.len(), 20);
        let visits: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(visits, 20, "batch sizes must partition the request stream");
        assert!(r.metrics.batch_sizes.iter().all(|&b| (1..=4).contains(&b)));
        assert!(r.metrics.mean_batch() >= 1.0);
        let per_worker: usize = r.metrics.per_worker.iter().map(|w| w.batches).sum();
        assert_eq!(per_worker, r.metrics.batch_sizes.len());
    }

    #[test]
    fn simulator_replicas_report_cycles() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
        let cfg = ServerConfig { n_requests: 4, seed: 5, workers: 2, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 4);
        let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
        assert!(lat > 0.0);
    }

    /// A backend that errors mid-stream aborts cleanly with in-flight
    /// accounting instead of deadlocking or poisoning joins.
    #[test]
    fn backend_error_aborts_cleanly() {
        struct FailAfter {
            inner: Functional,
            calls: std::sync::atomic::AtomicUsize,
        }
        impl Backend for FailAfter {
            fn name(&self) -> &str {
                "fail-after"
            }
            fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
                let n = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if n >= 5 {
                    return Err(BackendError("injected fault".into()));
                }
                self.inner.classify(map)
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = FailAfter {
            inner: Functional::new(qnet_for(&profile)),
            calls: std::sync::atomic::AtomicUsize::new(0),
        };
        let cfg = ServerConfig { n_requests: 16, seed: 2, workers: 2, ..Default::default() };
        let err = run_server(&profile, &backend, &cfg).unwrap_err();
        assert!(err.msg.contains("injected fault"), "msg: {}", err.msg);
        assert!(err.completed < 16);
    }
}
