//! Clocked pipeline simulator: steps all modules until the sink completes,
//! with a deadlock watchdog and per-module/per-FIFO reporting.

use super::module::Module;
use super::stream::Fabric;
use std::fmt;

/// A built pipeline ready to simulate.
///
/// Modules are `Send` so a whole pipeline can move to (or be built on) an
/// accelerator worker-replica thread in the serving runtime.
pub struct Pipeline {
    pub fabric: Fabric,
    /// Modules in pipeline (topological) order, source first, sink last.
    pub modules: Vec<Box<dyn Module + Send>>,
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total cycles until the sink finished.
    pub cycles: u64,
    /// Per-module (name, stats, dsp).
    pub modules: Vec<(String, super::stream::ModStats, usize)>,
    /// Per-FIFO (pushes, max occupancy, capacity).
    pub fifos: Vec<(u64, usize, usize)>,
}

impl SimReport {
    /// The module with the most busy cycles — the pipeline bottleneck.
    pub fn bottleneck(&self) -> Option<&(String, super::stream::ModStats, usize)> {
        self.modules.iter().max_by_key(|(_, s, _)| s.busy)
    }

    /// Latency in seconds at a given clock (paper: 187 MHz on ZCU102).
    pub fn latency_s(&self, clock_hz: f64) -> f64 {
        self.cycles as f64 / clock_hz
    }
}

impl fmt::Display for SimReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "cycles: {}", self.cycles)?;
        writeln!(
            f,
            "{:<22} {:>10} {:>10} {:>10} {:>9} {:>9}",
            "module", "busy", "stall_in", "stall_out", "consumed", "produced"
        )?;
        for (name, s, _) in &self.modules {
            writeln!(
                f,
                "{:<22} {:>10} {:>10} {:>10} {:>9} {:>9}",
                name, s.busy, s.stall_in, s.stall_out, s.consumed, s.produced
            )?;
        }
        Ok(())
    }
}

/// Simulation failure.
#[derive(Debug)]
pub enum SimError {
    /// No module made progress for the watchdog window.
    Deadlock { cycle: u64, state: String },
    /// Exceeded the cycle budget.
    Timeout { budget: u64 },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::Deadlock { cycle, state } => {
                write!(f, "pipeline deadlock at cycle {cycle}:\n{state}")
            }
            SimError::Timeout { budget } => write!(f, "simulation exceeded {budget} cycles"),
        }
    }
}

impl std::error::Error for SimError {}

impl Pipeline {
    /// Run until the last module (sink) reports done, or error out.
    pub fn run(&mut self, max_cycles: u64) -> Result<SimReport, SimError> {
        let n = self.modules.len();
        assert!(n >= 2, "pipeline needs at least source and sink");
        let mut cycle: u64 = 0;
        let no_skip = std::env::var_os("ESDA_NO_SKIP").is_some();
        let watchdog_window: u64 = 65_536;
        let mut last_progress_cycle: u64 = 0;
        let mut last_activity: u64 = 0;
        while !self.modules[n - 1].done() {
            if cycle >= max_cycles {
                return Err(SimError::Timeout { budget: max_cycles });
            }
            // Step consumers before producers (reverse pipeline order): an
            // item pushed this cycle is visible to its consumer next cycle,
            // matching registered RTL handshakes.
            let transfers_before = self.fabric.total_transfers();
            for m in self.modules.iter_mut().rev() {
                m.step(&mut self.fabric);
            }
            cycle += 1;
            // Event-skip fast path (§Perf): when a cycle moved nothing on
            // any channel, the pipeline state can only change when some
            // compute countdown expires — jump straight to the earliest one.
            // Exact: stalled modules stay stalled until a channel changes,
            // and channels only change when a countdown completes.
            if self.fabric.total_transfers() == transfers_before && !no_skip {
                if let Some(k) = self
                    .modules
                    .iter()
                    .filter_map(|m| m.next_event())
                    .min()
                {
                    if k > 1 {
                        for m in self.modules.iter_mut() {
                            if m.next_event().is_some() {
                                m.fast_forward(k - 1);
                            }
                        }
                        cycle += k - 1;
                    }
                }
            }
            // Watchdog: total consumed+produced must advance.
            if cycle - last_progress_cycle >= watchdog_window {
                let activity: u64 = self
                    .modules
                    .iter()
                    .map(|m| m.stats().consumed + m.stats().produced)
                    .sum();
                if activity == last_activity {
                    return Err(SimError::Deadlock { cycle, state: self.dump_state() });
                }
                last_activity = activity;
                last_progress_cycle = cycle;
            }
        }
        Ok(SimReport {
            cycles: cycle,
            modules: self
                .modules
                .iter()
                .map(|m| (m.name().to_string(), m.stats().clone(), m.dsp()))
                .collect(),
            fifos: self
                .fabric
                .chans
                .iter()
                .map(|c| (c.pushes, c.max_occupancy, c.cap))
                .collect(),
        })
    }

    fn dump_state(&self) -> String {
        let mut s = String::new();
        for m in &self.modules {
            let st = m.stats();
            s.push_str(&format!(
                "  {}: done={} consumed={} produced={} stall_in={} stall_out={}\n",
                m.name(),
                m.done(),
                st.consumed,
                st.produced,
                st.stall_in,
                st.stall_out
            ));
        }
        for (i, c) in self.fabric.chans.iter().enumerate() {
            s.push_str(&format!("  chan{}: len={}/{}\n", i, c.len(), c.cap));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pool_fc::{SinkMod, SourceMod};
    use crate::sparse::{SparseMap, Token};

    #[test]
    fn source_to_sink_passthrough() {
        let mut input: SparseMap<i8> = SparseMap::empty(4, 4, 2);
        input.push(Token::new(1, 0), &[3, 4]);
        input.push(Token::new(2, 3), &[5, 6]);
        let mut fab = Fabric::default();
        let ch = fab.add_chan(2);
        let src = SourceMod::new("src", ch, &input);
        let sink = SinkMod::new("sink", ch, 4, 4, 2);
        let mut p = Pipeline { fabric: fab, modules: vec![Box::new(src), Box::new(sink)] };
        let report = p.run(1000).unwrap();
        assert!(report.cycles >= 3); // 2 beats + end
        // Sink holds the map (downcast via report is not possible; re-check
        // through counters).
        assert_eq!(report.modules[1].1.consumed, 3);
    }

    #[test]
    fn deadlock_detected() {
        // A sink that never consumes against a source with data ⇒ watchdog.
        struct StuckSink {
            stats: crate::arch::stream::ModStats,
        }
        impl crate::arch::module::Module for StuckSink {
            fn name(&self) -> &str {
                "stuck"
            }
            fn step(&mut self, _f: &mut Fabric) {}
            fn stats(&self) -> &crate::arch::stream::ModStats {
                &self.stats
            }
            fn done(&self) -> bool {
                false
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        let mut input: SparseMap<i8> = SparseMap::empty(4, 4, 1);
        for x in 0..4u16 {
            input.push(Token::new(x, 0), &[1]);
        }
        let mut fab = Fabric::default();
        let ch = fab.add_chan(1);
        let src = SourceMod::new("src", ch, &input);
        let sink = StuckSink { stats: Default::default() };
        let mut p = Pipeline { fabric: fab, modules: vec![Box::new(src), Box::new(sink)] };
        match p.run(10_000_000) {
            Err(SimError::Deadlock { state, .. }) => {
                assert!(state.contains("stuck"));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    /// The event-skip fast path must be cycle-exact: simulating with and
    /// without it yields identical cycle counts and logits.
    #[test]
    fn event_skip_is_cycle_exact() {
        use crate::arch::{simulate_inference, HwConfig};
        use crate::events::{repr::histogram2_norm, DatasetProfile};
        use crate::model::quant::quantize_network;
        use crate::model::weights::FloatWeights;
        use crate::model::NetworkSpec;
        let p = DatasetProfile::n_mnist();
        let spec = NetworkSpec::tiny(p.w, p.h, p.n_classes);
        let w = FloatWeights::random(&spec, 21);
        let mut rng = crate::util::Rng::new(4);
        let mk = |rng: &mut crate::util::Rng, i: usize| {
            let es = p.sample(i % p.n_classes, rng);
            histogram2_norm(&es, p.w, p.h, 8.0)
        };
        let calib = vec![mk(&mut rng, 0), mk(&mut rng, 1)];
        let qnet = quantize_network(&spec, &w, &calib);
        // Mixed PFs exercise long countdowns (where skipping matters).
        let mut cfg = HwConfig::uniform(spec.ops().len(), 1);
        cfg.pf[0] = 16;
        for s in 0..3u64 {
            let input = mk(&mut rng, 5 + s as usize);
            std::env::remove_var("ESDA_NO_SKIP");
            let (l1, r1) = simulate_inference(&qnet, &cfg, &input, 1_000_000_000).unwrap();
            std::env::set_var("ESDA_NO_SKIP", "1");
            let (l2, r2) = simulate_inference(&qnet, &cfg, &input, 1_000_000_000).unwrap();
            std::env::remove_var("ESDA_NO_SKIP");
            assert_eq!(l1, l2);
            assert_eq!(r1.cycles, r2.cycles, "skip changed cycle count");
        }
    }

    /// Worker replicas in the serving runtime may own pipelines, so the
    /// whole simulator state must be `Send`.
    #[test]
    fn pipeline_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Pipeline>();
        assert_send::<SimReport>();
    }

    #[test]
    fn timeout_respected() {
        let input: SparseMap<i8> = SparseMap::empty(4, 4, 1);
        let mut fab = Fabric::default();
        let ch = fab.add_chan(1);
        let src = SourceMod::new("src", ch, &input);
        struct NeverDone {
            stats: crate::arch::stream::ModStats,
        }
        impl crate::arch::module::Module for NeverDone {
            fn name(&self) -> &str {
                "nd"
            }
            fn step(&mut self, f: &mut Fabric) {
                f.chan(0).pop(); // consumes, so no deadlock — just never done
            }
            fn stats(&self) -> &crate::arch::stream::ModStats {
                &self.stats
            }
            fn done(&self) -> bool {
                false
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        let sink = NeverDone { stats: Default::default() };
        let mut p = Pipeline { fabric: fab, modules: vec![Box::new(src), Box::new(sink)] };
        assert!(matches!(p.run(500), Err(SimError::Timeout { .. })));
    }
}
