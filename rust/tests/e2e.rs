//! Cross-module integration tests that don't need `make artifacts`:
//! the serving pipeline, the NAS→optimizer→simulator chain, and the
//! cost-model-vs-simulator consistency contract.

use esda::arch::{simulate_inference, HwConfig};
use esda::coordinator::{run_pipeline, Backend, Functional, PipelineConfig, Simulator};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::{allocate, stats::collect_stats_for_profile, Budget};
use esda::model::exec::forward_i8;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::Rng;

fn inputs_for(p: &DatasetProfile, n: usize, seed: u64) -> Vec<SparseMap<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let es = p.sample(i % p.n_classes, &mut rng);
            histogram2_norm(&es, p.w, p.h, 8.0)
        })
        .collect()
}

/// Optimizer → simulator contract: the Eqn. 5 bottleneck prediction and
/// the measured cycle count must agree to a small factor across datasets
/// and models (the model is an average; samples vary).
#[test]
fn cost_model_tracks_simulator() {
    for (profile, spec) in [
        (DatasetProfile::n_mnist(), NetworkSpec::tiny(34, 34, 10)),
        (DatasetProfile::roshambo17(), NetworkSpec::compact("c", 64, 64, 3)),
    ] {
        let w = FloatWeights::random(&spec, 2);
        let calib = inputs_for(&profile, 3, 1);
        let qnet = quantize_network(&spec, &w, &calib);
        let stats = collect_stats_for_profile(&spec, &profile, 6, 3);
        let alloc = allocate(&spec, &stats, &Budget::zcu102()).unwrap();
        let cfg = HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
        let mut total_ratio = 0.0;
        let samples = inputs_for(&profile, 4, 9);
        for input in &samples {
            let (_, report) = simulate_inference(&qnet, &cfg, input, 5_000_000_000).unwrap();
            total_ratio += report.cycles as f64 / alloc.latency;
        }
        let mean_ratio = total_ratio / samples.len() as f64;
        assert!(
            (0.3..3.0).contains(&mean_ratio),
            "{}: sim/model ratio {mean_ratio}",
            profile.name
        );
    }
}

/// The full serving pipeline with the simulator backend classifies exactly
/// like the functional reference, under concurrent staged execution.
#[test]
fn pipeline_backends_consistent_end_to_end() {
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let w = FloatWeights::random(&spec, 4);
    let calib = inputs_for(&profile, 3, 2);
    let qnet = quantize_network(&spec, &w, &calib);
    let n_ops = spec.ops().len();
    let run = |backend: &dyn Backend| {
        let cfg = PipelineConfig { n_requests: 10, seed: 77, queue_depth: 3, clip: 8.0 };
        run_pipeline(&profile, backend, &cfg).expect("pipeline run")
    };
    let f = run(&Functional::new(qnet.clone()));
    let s = run(&Simulator::new(qnet.clone(), HwConfig::uniform(n_ops, 8)));
    assert_eq!(f.metrics.total, 10);
    assert_eq!(s.metrics.total, 10);
    // Deterministic sources (same seed) ⇒ identical correctness counts.
    assert_eq!(f.metrics.correct, s.metrics.correct);
}

/// NAS output is executable: the best candidate quantizes, allocates, and
/// simulates to the same logits as the functional int8 path.
#[test]
fn nas_winner_is_simulatable() {
    let profile = DatasetProfile::n_mnist();
    let space = esda::nas::SearchSpace::for_dataset(profile.w, profile.h, profile.n_classes);
    let cfg = esda::nas::SearchConfig {
        n_samples: 5,
        top_k: 1,
        n_stat_samples: 2,
        probe_per_class: 3,
        seed: 3,
        budget: Budget::zcu102(),
    };
    let out = esda::nas::search(&profile, &space, &cfg);
    let best = out.first().expect("search found a feasible model");
    let w = FloatWeights::random(&best.spec, 5);
    let calib = inputs_for(&profile, 2, 6);
    let qnet = quantize_network(&best.spec, &w, &calib);
    let hw = HwConfig { pf: best.alloc.pf.clone(), fifo_depth: 8 };
    let input = &calib[0];
    let want = forward_i8(&qnet, input);
    let (got, _) = simulate_inference(&qnet, &hw, input, 10_000_000_000).unwrap();
    assert_eq!(got, want);
}

/// Representation choice is orthogonal to the architecture: a time-surface
/// input flows through the same pipeline (the paper's claim that ESDA
/// "can seamlessly integrate with different 2D representation algorithms").
#[test]
fn time_surface_representation_works_too() {
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let w = FloatWeights::random(&spec, 6);
    let mut rng = Rng::new(8);
    let es = profile.sample(0, &mut rng);
    let ts = esda::events::repr::time_surface(&es, profile.w, profile.h, 10_000.0);
    let calib = vec![ts.clone()];
    let qnet = quantize_network(&spec, &w, &calib);
    let cfg = HwConfig::uniform(spec.ops().len(), 8);
    let want = forward_i8(&qnet, &ts);
    let (got, _) = simulate_inference(&qnet, &cfg, &ts, 5_000_000_000).unwrap();
    assert_eq!(got, want);
}
