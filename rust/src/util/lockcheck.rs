//! Debug-build lock-rank witness: [`RankedMutex`] / [`RankedCondvar`].
//!
//! The static `lock-order` lint proves a partial order over declared lock
//! ranks lexically; this module enforces the same order dynamically. Every
//! ranked lock carries a `u32` rank (see `coordinator::lock_ranks`) and a
//! stable name. Under `cfg(debug_assertions)` a thread-local witness stack
//! records the ranks a thread currently holds and asserts strict
//! monotonicity at every acquisition — so the randomized serving property
//! tests double as a lock-order fuzzer. In release builds the wrappers
//! compile down to plain `std::sync` calls with zero extra cost.
//!
//! Poisoning behaves exactly like `std`: `lock()` returns a `LockResult`
//! whose `Err` carries a usable guard via `PoisonError::into_inner`, so the
//! repo's poison-tolerant `unwrap_or_else(|e| e.into_inner())` idiom works
//! unchanged.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

#[cfg(debug_assertions)]
use std::cell::RefCell;

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks (and names) of the ranked locks this thread currently holds,
    /// in acquisition order. Only exists in debug builds.
    static HELD: RefCell<Vec<(u32, &'static str)>> = const { RefCell::new(Vec::new()) };
}

/// Assert that acquiring `rank` would keep this thread's held ranks
/// strictly increasing. Called *before* blocking on the inner mutex so an
/// inversion panics instead of deadlocking.
#[cfg(debug_assertions)]
fn witness_check(rank: u32, name: &'static str) {
    HELD.with(|held| {
        if let Some(&(top, top_name)) = held.borrow().last() {
            assert!(
                rank > top,
                "lock-rank inversion: acquiring `{name}` (rank {rank}) while holding \
                 `{top_name}` (rank {top})"
            );
        }
    });
}

#[cfg(debug_assertions)]
fn witness_push(rank: u32, name: &'static str) {
    HELD.with(|held| held.borrow_mut().push((rank, name)));
}

/// Remove the most recent entry for (`rank`, `name`). Guards may be dropped
/// out of acquisition order, so this is positional, not a strict pop.
#[cfg(debug_assertions)]
fn witness_release(rank: u32, name: &'static str) {
    HELD.with(|held| {
        let mut held = held.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&(r, n)| r == rank && n == name) {
            held.remove(pos);
        }
    });
}

/// Assert this thread holds no ranked locks. Call at blocking points that
/// must never run under a lock (e.g. the top of a net accept loop). Free in
/// release builds.
pub fn debug_assert_no_locks_held(context: &str) {
    #[cfg(debug_assertions)]
    HELD.with(|held| {
        let held = held.borrow();
        assert!(
            held.is_empty(),
            "{context}: thread still holds {} ranked lock(s); most recent is `{}`",
            held.len(),
            held.last().map(|&(_, n)| n).unwrap_or("?"),
        );
    });
    #[cfg(not(debug_assertions))]
    let _ = context;
}

/// A `Mutex<T>` that declares its place in the global lock order.
pub struct RankedMutex<T> {
    rank: u32,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> RankedMutex<T> {
    /// Wrap `value` in a mutex ranked `rank` in the global order. `name` is
    /// used in witness panic messages; use the same name for every instance
    /// sharing a rank (e.g. all admission-queue states).
    pub fn new(rank: u32, name: &'static str, value: T) -> Self {
        RankedMutex { rank, name, inner: Mutex::new(value) }
    }

    /// Acquire the lock, asserting (debug builds only) that this thread's
    /// held ranks stay strictly increasing.
    pub fn lock(&self) -> LockResult<RankedGuard<'_, T>> {
        #[cfg(debug_assertions)]
        witness_check(self.rank, self.name);
        let res = self.inner.lock();
        #[cfg(debug_assertions)]
        witness_push(self.rank, self.name);
        match res {
            Ok(g) => Ok(self.wrap(g)),
            Err(p) => Err(PoisonError::new(self.wrap(p.into_inner()))),
        }
    }

    fn wrap<'a>(&self, g: MutexGuard<'a, T>) -> RankedGuard<'a, T> {
        RankedGuard { guard: Some(g), rank: self.rank, name: self.name }
    }

    /// Consume the mutex, returning the inner value (mirrors
    /// `Mutex::into_inner`, including poison reporting).
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    /// This lock's declared rank.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// This lock's witness name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl<T: fmt::Debug> fmt::Debug for RankedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RankedMutex")
            .field("rank", &self.rank)
            .field("name", &self.name)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard returned by [`RankedMutex::lock`]. Dropping it releases the inner
/// mutex and retires the witness entry.
pub struct RankedGuard<'a, T> {
    /// `None` only transiently while a condvar wait owns the inner guard.
    guard: Option<MutexGuard<'a, T>>,
    rank: u32,
    name: &'static str,
}

impl<T> Deref for RankedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.guard.as_deref().expect("ranked guard used during condvar handoff")
    }
}

impl<T> DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_deref_mut().expect("ranked guard used during condvar handoff")
    }
}

impl<T> Drop for RankedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if self.guard.is_some() {
            witness_release(self.rank, self.name);
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for RankedGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RankedGuard")
            .field("rank", &self.rank)
            .field("name", &self.name)
            .field("guard", &self.guard)
            .finish()
    }
}

/// A `Condvar` that waits on [`RankedGuard`]s.
///
/// While a thread is parked in `wait`/`wait_timeout` the witness entry for
/// the handed-off guard is deliberately retained: the parked thread cannot
/// acquire anything else, and it holds the lock again the instant the wait
/// returns, so the entry stays accurate at every observable point.
#[derive(Debug, Default)]
pub struct RankedCondvar {
    inner: Condvar,
}

impl RankedCondvar {
    pub fn new() -> Self {
        RankedCondvar { inner: Condvar::new() }
    }

    /// Block until notified, releasing and re-acquiring the guard's mutex
    /// exactly like `Condvar::wait`.
    pub fn wait<'a, T>(&self, mut guard: RankedGuard<'a, T>) -> LockResult<RankedGuard<'a, T>> {
        let (rank, name) = (guard.rank, guard.name);
        let inner = guard.guard.take().expect("condvar wait on a handed-off guard");
        drop(guard); // guard slot is empty: shell drop skips the witness pop
        match self.inner.wait(inner) {
            Ok(g) => Ok(RankedGuard { guard: Some(g), rank, name }),
            Err(p) => {
                Err(PoisonError::new(RankedGuard { guard: Some(p.into_inner()), rank, name }))
            }
        }
    }

    /// Block until notified or `dur` elapses; mirrors
    /// `Condvar::wait_timeout`.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: RankedGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(RankedGuard<'a, T>, WaitTimeoutResult)> {
        let (rank, name) = (guard.rank, guard.name);
        let inner = guard.guard.take().expect("condvar wait on a handed-off guard");
        drop(guard); // guard slot is empty: shell drop skips the witness pop
        match self.inner.wait_timeout(inner, dur) {
            Ok((g, timed)) => Ok((RankedGuard { guard: Some(g), rank, name }, timed)),
            Err(p) => {
                let (g, timed) = p.into_inner();
                Err(PoisonError::new((RankedGuard { guard: Some(g), rank, name }, timed)))
            }
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}
