//! Weight containers and the binary tensor file exchanged with python.
//!
//! Float weights come either from the python training path
//! (`artifacts/<net>_weights.bin`, written by `python/compile/train.py`) or
//! from the seeded random initializer (tests, benches that don't need
//! trained accuracy).
//!
//! Tensor container layout (little-endian):
//! ```text
//! magic "ESDW" (u32 0x45534457), version u32 = 1, n_tensors u32
//! per tensor: name_len u32, name bytes, dtype u8 (0=f32,1=i8,2=i32),
//!             ndim u32, dims u32×ndim, raw data
//! ```

use super::graph::{NetworkSpec, Op};
use crate::sparse::quant::Requant;
use crate::util::Rng;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// Float weights for one primitive op (empty vecs for no-weight ops).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpWeights {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Quantized weights + requantization for one op.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantOpWeights {
    pub w: Vec<i8>,
    /// Bias in the accumulator domain (s_in · s_w).
    pub b: Vec<i32>,
    pub rq: Requant,
    /// Input/output activation scales (for staging & debugging).
    pub s_in: f32,
    pub s_out: f32,
}

/// All float weights of a network, aligned to `spec.ops()` indices.
#[derive(Clone, Debug, PartialEq)]
pub struct FloatWeights {
    pub per_op: Vec<OpWeights>,
}

impl FloatWeights {
    /// He-style random init, deterministic in `seed`.
    pub fn random(spec: &NetworkSpec, seed: u64) -> FloatWeights {
        let mut rng = Rng::new(seed);
        let per_op = spec
            .ops()
            .iter()
            .map(|op| {
                if !op.has_weights() {
                    return OpWeights::default();
                }
                let n = op.weight_count();
                let fan_in = match op {
                    Op::Conv1x1 { cin, .. } => *cin,
                    Op::ConvKxK { k, cin, .. } => k * k * cin,
                    Op::DwConv { k, .. } => k * k,
                    Op::Fc { cin, .. } => *cin,
                    _ => 1,
                };
                let std = (2.0 / fan_in as f64).sqrt();
                let w = (0..n).map(|_| (rng.normal() * std) as f32).collect();
                let b = vec![0.0f32; op.cout().unwrap()];
                OpWeights { w, b }
            })
            .collect();
        FloatWeights { per_op }
    }
}

// ---------------------------------------------------------------------------
// Tensor container I/O
// ---------------------------------------------------------------------------

pub const MAGIC: u32 = 0x4553_4457; // "ESDW"
pub const VERSION: u32 = 1;

/// A named tensor from the container.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I8 { dims: Vec<usize>, data: Vec<i8> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I8 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }
    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

/// Named tensor store.
pub type TensorMap = BTreeMap<String, Tensor>;

/// Write a tensor container.
pub fn write_tensors(path: &Path, tensors: &TensorMap) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let (dtype, dims): (u8, &[usize]) = match t {
            Tensor::F32 { dims, .. } => (0, dims),
            Tensor::I8 { dims, .. } => (1, dims),
            Tensor::I32 { dims, .. } => (2, dims),
        };
        f.write_all(&[dtype])?;
        f.write_all(&(dims.len() as u32).to_le_bytes())?;
        for &d in dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            Tensor::F32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::I8 { data, .. } => {
                let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
                f.write_all(&bytes)?;
            }
            Tensor::I32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    f.flush()
}

fn rd_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read a tensor container.
pub fn read_tensors(path: &Path) -> std::io::Result<TensorMap> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let err = |m: String| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    if rd_u32(&mut f)? != MAGIC {
        return Err(err("bad magic".into()));
    }
    let v = rd_u32(&mut f)?;
    if v != VERSION {
        return Err(err(format!("unsupported version {v}")));
    }
    let n = rd_u32(&mut f)? as usize;
    let mut out = TensorMap::new();
    for _ in 0..n {
        let name_len = rd_u32(&mut f)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).map_err(|e| err(e.to_string()))?;
        let mut dtype = [0u8; 1];
        f.read_exact(&mut dtype)?;
        let ndim = rd_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(rd_u32(&mut f)? as usize);
        }
        let count: usize = dims.iter().product();
        let t = match dtype[0] {
            0 => {
                let mut data = Vec::with_capacity(count);
                let mut b = [0u8; 4];
                for _ in 0..count {
                    f.read_exact(&mut b)?;
                    data.push(f32::from_le_bytes(b));
                }
                Tensor::F32 { dims, data }
            }
            1 => {
                let mut bytes = vec![0u8; count];
                f.read_exact(&mut bytes)?;
                Tensor::I8 { dims, data: bytes.iter().map(|&b| b as i8).collect() }
            }
            2 => {
                let mut data = Vec::with_capacity(count);
                let mut b = [0u8; 4];
                for _ in 0..count {
                    f.read_exact(&mut b)?;
                    data.push(i32::from_le_bytes(b));
                }
                Tensor::I32 { dims, data }
            }
            d => return Err(err(format!("unknown dtype {d}"))),
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Load [`FloatWeights`] for `spec` from a tensor container: weighted op
/// `i` reads tensors `op{i}.w` and `op{i}.b` (the naming contract with
/// `python/compile/train.py`).
pub fn load_float_weights(path: &Path, spec: &NetworkSpec) -> std::io::Result<FloatWeights> {
    let tensors = read_tensors(path)?;
    let err = |m: String| std::io::Error::new(std::io::ErrorKind::InvalidData, m);
    let ops = spec.ops();
    let mut per_op = Vec::with_capacity(ops.len());
    for (i, op) in ops.iter().enumerate() {
        if !op.has_weights() {
            per_op.push(OpWeights::default());
            continue;
        }
        let wt = tensors
            .get(&format!("op{i}.w"))
            .and_then(|t| t.as_f32())
            .ok_or_else(|| err(format!("missing f32 tensor op{i}.w")))?;
        let bt = tensors
            .get(&format!("op{i}.b"))
            .and_then(|t| t.as_f32())
            .ok_or_else(|| err(format!("missing f32 tensor op{i}.b")))?;
        if wt.len() != op.weight_count() || bt.len() != op.cout().unwrap() {
            return Err(err(format!(
                "op{i} shape mismatch: got w={} b={}, want w={} b={}",
                wt.len(),
                bt.len(),
                op.weight_count(),
                op.cout().unwrap()
            )));
        }
        per_op.push(OpWeights { w: wt.to_vec(), b: bt.to_vec() });
    }
    Ok(FloatWeights { per_op })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_align_with_ops() {
        let spec = NetworkSpec::tiny(16, 16, 3);
        let w = FloatWeights::random(&spec, 1);
        let ops = spec.ops();
        assert_eq!(w.per_op.len(), ops.len());
        for (ow, op) in w.per_op.iter().zip(&ops) {
            assert_eq!(ow.w.len(), op.weight_count());
            if op.has_weights() {
                assert_eq!(ow.b.len(), op.cout().unwrap());
            }
        }
        // Deterministic.
        assert_eq!(FloatWeights::random(&spec, 1), w);
        assert_ne!(FloatWeights::random(&spec, 2), w);
    }

    #[test]
    fn tensor_container_roundtrip() {
        let dir = std::env::temp_dir().join(format!("esda_w_{}", std::process::id()));
        let path = dir.join("t.esdw");
        let mut m = TensorMap::new();
        m.insert(
            "a".into(),
            Tensor::F32 { dims: vec![2, 3], data: vec![1.0, 2.0, 3.0, -4.0, 0.5, 6.0] },
        );
        m.insert("b".into(), Tensor::I8 { dims: vec![4], data: vec![-128, 0, 1, 127] });
        m.insert("c".into(), Tensor::I32 { dims: vec![2], data: vec![i32::MIN, i32::MAX] });
        write_tensors(&path, &m).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_float_weights_checks_shapes() {
        let dir = std::env::temp_dir().join(format!("esda_w2_{}", std::process::id()));
        let path = dir.join("net.esdw");
        let spec = NetworkSpec::tiny(8, 8, 2);
        let fw = FloatWeights::random(&spec, 3);
        let mut m = TensorMap::new();
        for (i, ow) in fw.per_op.iter().enumerate() {
            if ow.w.is_empty() {
                continue;
            }
            m.insert(
                format!("op{i}.w"),
                Tensor::F32 { dims: vec![ow.w.len()], data: ow.w.clone() },
            );
            m.insert(
                format!("op{i}.b"),
                Tensor::F32 { dims: vec![ow.b.len()], data: ow.b.clone() },
            );
        }
        write_tensors(&path, &m).unwrap();
        let loaded = load_float_weights(&path, &spec).unwrap();
        assert_eq!(loaded, fw);
        // Corrupt: drop one tensor.
        m.remove("op0.w");
        write_tensors(&path, &m).unwrap();
        assert!(load_float_weights(&path, &spec).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
