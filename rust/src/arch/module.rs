//! The dataflow-module abstraction (paper Eqn. 1): every hardware module
//! consumes token-feature items from input channels and produces them on
//! output channels, maintaining strict ravel order, one step per clock.

use super::stream::{Fabric, ModStats};

/// A cycle-steppable hardware module.
pub trait Module {
    /// Display name (for reports and deadlock dumps).
    fn name(&self) -> &str;

    /// Advance one clock edge. A module may pop at most one item per input
    /// channel and push at most one item per output channel per call
    /// (multi-cycle work is modelled with internal busy countdowns).
    fn step(&mut self, fab: &mut Fabric);

    /// Activity counters.
    fn stats(&self) -> &ModStats;

    /// True once the module has propagated end-of-stream (used by the
    /// simulator to detect completion).
    fn done(&self) -> bool;

    /// DSP cost of this module under its configuration (Eqn. 5 family) —
    /// used by reports; the authoritative cost model lives in `hwopt`.
    fn dsp(&self) -> usize {
        0
    }

    /// Downcast support (the builder recovers the sink's collected output).
    fn as_any(&self) -> &dyn std::any::Any;

    /// Event-skip support (§Perf): `Some(k)` when the module is in a pure
    /// compute countdown and will neither touch a channel nor change state
    /// for the next `k` calls to `step`. `None` when the module's behaviour
    /// depends on channel state (or it is idle/done).
    fn next_event(&self) -> Option<u64> {
        None
    }

    /// Advance a pure countdown by `k` cycles (`k < next_event()`),
    /// accounting the skipped cycles as busy. Only called by the scheduler
    /// fast path.
    fn fast_forward(&mut self, _k: u64) {}
}

/// Common helper: a compute countdown.
#[derive(Debug, Default, Clone)]
pub struct Countdown(pub u64);

impl Countdown {
    #[inline]
    pub fn busy(&self) -> bool {
        self.0 > 0
    }
    #[inline]
    pub fn tick(&mut self) -> bool {
        if self.0 > 0 {
            self.0 -= 1;
        }
        self.0 == 0
    }
    #[inline]
    pub fn start(&mut self, cycles: u64) {
        debug_assert_eq!(self.0, 0);
        self.0 = cycles;
    }
}

/// `ceil(macs / pf)` — cycles for a PE array of `pf` MACs/cycle to chew
/// through `macs` multiply-accumulates (the paper's `C/PF` terms).
#[inline]
pub fn pe_cycles(macs: usize, pf: usize) -> u64 {
    ((macs + pf - 1) / pf.max(1)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn countdown_ticks_to_zero() {
        let mut c = Countdown::default();
        c.start(3);
        assert!(c.busy());
        assert!(!c.tick());
        assert!(!c.tick());
        assert!(c.tick());
        assert!(!c.busy());
    }

    #[test]
    fn pe_cycles_rounds_up() {
        assert_eq!(pe_cycles(9, 4), 3);
        assert_eq!(pe_cycles(8, 4), 2);
        assert_eq!(pe_cycles(1, 16), 1);
        assert_eq!(pe_cycles(0, 8), 0);
    }
}
