// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Model/hardware co-optimization demo (paper §3.4.2): run the two-step
//! greedy NAS for a dataset and print the candidate table — architectures
//! sampled, hardware-optimized with Eqn. 6, top-k scored by the linear
//! probe, best model first.
//!
//! Run: `cargo run --release --example search_models -- --dataset roshambo17 --samples 24`

use esda::events::DatasetProfile;
use esda::hwopt::power::CLOCK_HZ;
use esda::nas::{search, SearchConfig, SearchSpace};
use esda::report::Table;
use esda::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let name = args.get_or("dataset", "roshambo17");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let space = SearchSpace::for_dataset(profile.w, profile.h, profile.n_classes);
    let cfg = SearchConfig {
        n_samples: args.get_usize("samples", 24).unwrap(),
        top_k: args.get_usize("top-k", 4).unwrap(),
        ..Default::default()
    };
    println!(
        "searching {} architectures for {} ({}×{}, downsample {}×, ≤{} params)",
        cfg.n_samples, profile.name, profile.w, profile.h, space.total_downsample, space.max_params
    );
    let out = search(&profile, &space, &cfg);
    let mut t = Table::new(
        "ESDA-Net candidates (best first)",
        &["rank", "params", "blocks", "lat (ms)", "fps", "DSP", "BRAM", "probe acc"],
    );
    for (i, c) in out.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            c.spec.param_count().to_string(),
            c.spec.blocks.len().to_string(),
            format!("{:.3}", c.alloc.latency / CLOCK_HZ * 1e3),
            format!("{:.0}", c.throughput),
            c.alloc.resources.dsp.to_string(),
            c.alloc.resources.bram.to_string(),
            format!("{:.2}", c.accuracy.unwrap_or(f64::NAN)),
        ]);
    }
    println!("{}", t.render());
    if let Some(best) = out.first() {
        println!("selected: {:?}", best.spec.blocks);
    }
}
