//! Sparse-tensor substrate: coordinate tokens, bitmaps, sparse feature maps,
//! and *functional* (non-cycle-level) reference implementations of the
//! convolutions the paper uses.
//!
//! Everything in `arch` (the cycle-level hardware model) is checked against
//! the functional references here, and the references themselves are checked
//! against dense convolution and against the python/JAX oracles via golden
//! vectors.
//!
//! Conventions (shared with the hardware model and the python side):
//! - Coordinates are `(x, y)` with `x` the column and `y` the row.
//! - Streaming/storage order is **ravel order** `y * W + x` (left-to-right,
//!   top-to-bottom), strictly increasing — Eqn. 1 of the paper.
//! - k×k kernels use offset index `off = dy * k + dx`, `dy, dx ∈ [0, k)`,
//!   measured from the window's top-left; the window of output `(ox, oy)`
//!   at stride `s` covers inputs `(ox*s + dx - pad, oy*s + dy - pad)`.
//! - Stride-1 convs are **submanifold**: output tokens = input tokens.
//! - Stride-2 convs emit an output token iff the corresponding `s×s` input
//!   grid contains any nonzero (paper §3.2, Fig. 3b).
pub mod token;
pub mod bitmap;
pub mod map;
pub mod conv;
pub mod rulebook;
pub mod quant;

pub use bitmap::Bitmap;
pub use map::SparseMap;
pub use token::{ravel, Token};
