//! Tests for the debug-build lock-rank witness
//! (`esda::util::lockcheck`): ordered acquisition passes, an inversion
//! panics (debug builds), guards may retire out of order, the condvar
//! handoff keeps the witness accurate, and poisoning behaves exactly
//! like `std`. A randomized driver replays thousands of rank-ascending
//! schedules to prove the witness never false-positives on legal order.

use esda::util::lockcheck::{debug_assert_no_locks_held, RankedCondvar, RankedMutex};
use esda::util::Rng;
use std::time::Duration;

#[test]
fn ordered_acquisition_passes_and_retires_cleanly() {
    let a = RankedMutex::new(10, "a", 1u32);
    let b = RankedMutex::new(20, "b", 2u32);
    let c = RankedMutex::new(30, "c", 3u32);
    {
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        let gc = c.lock().unwrap();
        assert_eq!(*ga + *gb + *gc, 6);
    }
    debug_assert_no_locks_held("after ordered acquisition");
}

#[test]
fn guards_may_be_dropped_out_of_acquisition_order() {
    let a = RankedMutex::new(10, "a", ());
    let b = RankedMutex::new(20, "b", ());
    let ga = a.lock().unwrap();
    let gb = b.lock().unwrap();
    // Retire the *lower* rank first: the witness release is positional,
    // not a strict stack pop.
    drop(ga);
    // With only rank 20 held, rank 30 is still legal.
    let c = RankedMutex::new(30, "c", ());
    let gc = c.lock().unwrap();
    drop(gc);
    drop(gb);
    debug_assert_no_locks_held("after out-of-order retirement");
}

/// The whole point of the witness: an inversion panics in debug builds
/// (instead of deadlocking in production). Release builds compile the
/// witness away, so the test only exists under `debug_assertions`.
#[cfg(debug_assertions)]
#[test]
fn inverted_acquisition_panics_in_debug_builds() {
    let lo = RankedMutex::new(10, "lo", ());
    let hi = RankedMutex::new(20, "hi", ());
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _g_hi = hi.lock().unwrap();
        let _g_lo = lo.lock().unwrap(); // 10 while holding 20: inversion
    }))
    .expect_err("acquiring rank 10 under rank 20 must panic");
    let msg = esda::util::panic_message(&*err);
    assert!(msg.contains("lock-rank inversion"), "unexpected panic: {msg}");
    assert!(msg.contains("`lo` (rank 10)"), "unexpected panic: {msg}");
    assert!(msg.contains("`hi` (rank 20)"), "unexpected panic: {msg}");
    // The unwind dropped both guards; the witness stack must be empty.
    debug_assert_no_locks_held("after the caught inversion");
}

/// Equal ranks invert too: the order must be *strictly* increasing, so
/// two locks sharing a rank can never nest (in either order).
#[cfg(debug_assertions)]
#[test]
fn equal_rank_nesting_panics_in_debug_builds() {
    let x = RankedMutex::new(20, "x", ());
    let y = RankedMutex::new(20, "y", ());
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _gx = x.lock().unwrap();
        let _gy = y.lock().unwrap();
    }))
    .expect_err("nesting two rank-20 locks must panic");
    let msg = esda::util::panic_message(&*err);
    assert!(msg.contains("lock-rank inversion"), "unexpected panic: {msg}");
    debug_assert_no_locks_held("after the caught equal-rank nesting");
}

#[test]
fn condvar_wait_timeout_hands_the_guard_back() {
    let mx = RankedMutex::new(50, "stop", false);
    let cv = RankedCondvar::new();
    let g = mx.lock().unwrap();
    let (g, timed) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
    assert!(timed.timed_out(), "nobody notified: the wait must time out");
    // The guard is live again after the wait — and still witnessed, so a
    // lower-rank acquisition under it still trips the checker.
    assert!(!*g);
    drop(g);
    debug_assert_no_locks_held("after the condvar roundtrip");
}

#[test]
fn condvar_notify_crosses_threads() {
    let pair = std::sync::Arc::new((RankedMutex::new(50, "stop", false), RankedCondvar::new()));
    let waker = std::sync::Arc::clone(&pair);
    let t = std::thread::spawn(move || {
        let (mx, cv) = &*waker;
        *mx.lock().unwrap() = true;
        cv.notify_all();
    });
    let (mx, cv) = &*pair;
    let mut g = mx.lock().unwrap();
    while !*g {
        g = cv.wait_timeout(g, Duration::from_millis(50)).unwrap().0;
    }
    drop(g);
    t.join().unwrap();
    debug_assert_no_locks_held("after the cross-thread notify");
}

#[test]
fn poisoning_behaves_like_std() {
    let mx = std::sync::Arc::new(RankedMutex::new(10, "poisoned", 7u32));
    let holder = std::sync::Arc::clone(&mx);
    let t = std::thread::spawn(move || {
        let _g = holder.lock().unwrap();
        panic!("poison the lock");
    });
    assert!(t.join().is_err());
    // The repo's poison-tolerant idiom recovers a usable guard.
    let mut g = mx.lock().unwrap_or_else(|e| e.into_inner());
    *g += 1;
    assert_eq!(*g, 8);
    drop(g);
    debug_assert_no_locks_held("after poison recovery");
}

#[test]
fn into_inner_returns_the_value() {
    let mx = RankedMutex::new(10, "owned", vec![1, 2, 3]);
    assert_eq!(mx.rank(), 10);
    assert_eq!(mx.name(), "owned");
    assert_eq!(mx.into_inner().unwrap(), vec![1, 2, 3]);
}

/// Randomized legal-schedule driver: replay thousands of rank-ascending
/// acquire/release interleavings (random subsets, random early drops)
/// and require the witness to stay silent throughout. Any panic here is
/// a witness false positive.
#[test]
fn witness_never_fires_on_rank_ascending_schedules() {
    let locks: Vec<RankedMutex<u32>> =
        (0..8u32).map(|i| RankedMutex::new((i + 1) * 10, "fuzz", i)).collect();
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..2_000 {
        let mut held = Vec::new();
        for lk in &locks {
            if rng.chance(0.5) {
                held.push(lk.lock().unwrap());
            }
            // Randomly retire a random already-held guard mid-schedule:
            // out-of-order drops are legal and must stay silent too.
            if !held.is_empty() && rng.chance(0.3) {
                held.remove(rng.index(held.len()));
            }
        }
        drop(held);
        debug_assert_no_locks_held("after a randomized legal schedule");
    }
}

/// CI runs this suite once under `--release` (the default everywhere
/// else in the pipeline) and once in the debug profile with
/// `ESDA_EXPECT_DEBUG=1`, which asserts the witness is actually
/// compiled in — otherwise a workflow edit could silently demote the
/// whole lockcheck gate to the no-op release wrappers.
#[test]
fn ci_debug_profile_is_live() {
    if std::env::var("ESDA_EXPECT_DEBUG").is_err() {
        return; // not the pinned-profile CI step
    }
    assert!(
        cfg!(debug_assertions),
        "ESDA_EXPECT_DEBUG=1 but debug_assertions are off — the lock witness is compiled out"
    );
}
