// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Table 1: end-to-end system performance — accuracy, latency, throughput,
//! power, energy efficiency, and resources for every dataset, our measured
//! ESDA rows next to the paper's published rows and the quoted comparator
//! systems (NullHop, PPF, TrueNorth, Loihi, Asynet).

use esda::arch::nullhop::{nullhop_latency, NullHopConfig};
use esda::arch::{simulate_inference, HwConfig};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::power::{PowerModel, CLOCK_HZ};
use esda::hwopt::{allocate, stats::collect_stats, Budget};
use esda::model::quant::quantize_network;
use esda::model::weights::{load_float_weights, FloatWeights};
use esda::model::NetworkSpec;
use esda::report::Table;
use esda::util::Rng;

/// Paper's published ESDA rows for side-by-side comparison:
/// (dataset, model, acc%, lat ms, fps, W, mJ/inf, dsp, bram).
const PAPER_ROWS: &[(&str, &str, f64, f64, f64, f64, f64, usize, usize)] = &[
    ("n_caltech101", "ESDA-Net", 72.4, 3.09, 323.0, 1.81, 5.61, 1792, 1278),
    ("n_caltech101", "MobileNetV2", 71.6, 7.12, 140.0, 2.10, 14.96, 1992, 1600),
    ("dvs_gesture", "ESDA-Net", 92.5, 0.66, 1526.0, 1.58, 1.03, 1532, 848),
    ("dvs_gesture", "MobileNetV2", 93.9, 1.19, 839.0, 1.73, 2.06, 1636, 1134),
    ("asl_dvs", "ESDA-Net", 99.5, 0.71, 1406.0, 1.60, 1.14, 1494, 917),
    ("asl_dvs", "MobileNetV2", 99.3, 1.08, 927.0, 1.75, 1.88, 1416, 1069),
    ("n_mnist", "ESDA-Net", 98.9, 0.15, 6657.0, 1.55, 0.23, 1525, 978),
    ("roshambo17", "ESDA-Net", 99.6, 0.98, 1016.0, 1.40, 1.38, 1282, 765),
];

fn trained_accuracy(ds: &str) -> Option<f64> {
    let src = std::fs::read_to_string("artifacts/train_summary.json").ok()?;
    let j = esda::util::json::parse(&src).ok()?;
    j.get(ds)?.get("test_acc")?.as_f64().map(|a| a * 100.0)
}

fn main() {
    println!("# Table 1 — system performance (measured on the cycle-level model @187 MHz)\n");
    let pm = PowerModel::calibrated();
    println!(
        "power model fit vs paper rows: RMS residual {:.3} W\n",
        pm.rms_residual
    );
    let mut t = Table::new(
        "ESDA rows (ours)",
        &[
            "dataset", "model", "acc %", "lat (ms)", "fps", "power (W)", "mJ/inf",
            "DSP", "BRAM", "FF", "LUT",
        ],
    );
    let n_eval = 4usize;
    let mut measured: Vec<(String, String, f64, f64)> = Vec::new(); // ds, model, lat_ms, mj
    for profile in DatasetProfile::all() {
        let models: Vec<(&str, NetworkSpec)> = if profile.w.min(profile.h) >= 128 {
            vec![
                (
                    "ESDA-Net",
                    NetworkSpec::compact("esda_net", profile.w, profile.h, profile.n_classes),
                ),
                (
                    "MobileNetV2",
                    NetworkSpec::mobilenet_v2_05("mbv2", profile.w, profile.h, profile.n_classes),
                ),
            ]
        } else {
            vec![(
                "ESDA-Net",
                NetworkSpec::compact("esda_net", profile.w, profile.h, profile.n_classes),
            )]
        };
        for (mname, spec) in models {
            let mut rng = Rng::new(0x7AB1E1);
            let mk = |rng: &mut Rng, i: usize| {
                let es = profile.sample(i % profile.n_classes, rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            };
            // Trained weights when the artifact exists (ESDA-Net/compact),
            // random otherwise — accuracy column marks which.
            let stem = format!("compact_{}", profile.name);
            let weights_path = esda::runtime::artifacts_dir().join(format!("{stem}_weights.esdw"));
            let (weights, acc_str) = if mname == "ESDA-Net" && weights_path.exists() {
                let w = load_float_weights(&weights_path, &spec).expect("artifact weights align");
                let acc = trained_accuracy(profile.name)
                    .map(|a| format!("{a:.1}"))
                    .unwrap_or_else(|| "n/a".into());
                (w, acc)
            } else {
                (FloatWeights::random(&spec, 1), "rand-w".to_string())
            };
            let calib: Vec<_> = (0..3).map(|i| mk(&mut rng, i)).collect();
            let qnet = quantize_network(&spec, &weights, &calib);
            let bms: Vec<_> = calib.iter().map(|m| m.bitmap()).collect();
            let stats = collect_stats(&spec, &bms);
            let Some(alloc) = allocate(&spec, &stats, &Budget::zcu102()) else {
                println!("  ({}/{}: does not fit — skipped)", profile.name, mname);
                continue;
            };
            let cfg = HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
            let mut cycles = 0f64;
            for i in 0..n_eval {
                let input = mk(&mut rng, 10 + i);
                let (_, report) =
                    simulate_inference(&qnet, &cfg, &input, 50_000_000_000).unwrap();
                cycles += report.cycles as f64;
            }
            cycles /= n_eval as f64;
            let lat_ms = cycles / CLOCK_HZ * 1e3;
            let fps = CLOCK_HZ / cycles;
            let watts = pm.watts(&alloc.resources);
            let mj = pm.energy_mj(&alloc.resources, cycles, CLOCK_HZ);
            measured.push((profile.name.to_string(), mname.to_string(), lat_ms, mj));
            t.row(vec![
                profile.name.to_string(),
                mname.to_string(),
                acc_str,
                format!("{lat_ms:.2}"),
                format!("{fps:.0}"),
                format!("{watts:.2}"),
                format!("{mj:.2}"),
                alloc.resources.dsp.to_string(),
                alloc.resources.bram.to_string(),
                format!("{}K", alloc.resources.ff / 1000),
                format!("{}K", alloc.resources.lut / 1000),
            ]);
        }
    }
    println!("{}", t.render());

    let mut tp = Table::new(
        "paper's published ESDA rows (ZCU102, for shape comparison)",
        &["dataset", "model", "acc %", "lat (ms)", "fps", "W", "mJ/inf", "DSP", "BRAM"],
    );
    for &(ds, m, acc, lat, fps, w, mj, dsp, bram) in PAPER_ROWS {
        tp.row(vec![
            ds.into(),
            m.into(),
            format!("{acc:.1}"),
            format!("{lat:.2}"),
            format!("{fps:.0}"),
            format!("{w:.2}"),
            format!("{mj:.2}"),
            dsp.to_string(),
            bram.to_string(),
        ]);
    }
    println!("{}", tp.render());

    // Comparator systems (quoted from the paper; our executable NullHop
    // model provides the measured ratio).
    println!("== comparators ==");
    let ro = DatasetProfile::roshambo17();
    let spec = NetworkSpec::compact("esda_net", ro.w, ro.h, ro.n_classes);
    let mut rng = Rng::new(5);
    let bms: Vec<_> = (0..4)
        .map(|i| {
            let es = ro.sample(i % ro.n_classes, &mut rng);
            histogram2_norm(&es, ro.w, ro.h, 8.0).bitmap()
        })
        .collect();
    let stats = collect_stats(&spec, &bms);
    let nh_cycles = nullhop_latency(&spec, &stats, &NullHopConfig::default());
    let esda_alloc = allocate(&spec, &stats, &Budget::zcu102()).unwrap();
    let nh_ms = nh_cycles / 60e6 * 1e3; // NullHop ran at 60 MHz (paper §4.5)
    let esda_ms = esda_alloc.latency / CLOCK_HZ * 1e3;
    println!(
        "NullHop model (RoShamBo17): {nh_ms:.2} ms @60 MHz vs ESDA {esda_ms:.2} ms @187 MHz → {:.1}× (paper: 10.2×; published NullHop 10 ms vs ESDA 0.98 ms)",
        nh_ms / esda_ms
    );
    if let Some((_, _, lat, mj)) = measured
        .iter()
        .find(|(d, m, _, _)| d == "dvs_gesture" && m == "ESDA-Net")
        .map(|(a, b, c, d)| (a.clone(), b.clone(), *c, *d))
    {
        println!(
            "TrueNorth (DvsGesture): 105 ms, 18.7 mJ/inf → our ESDA row {lat:.2} ms ({:.0}× faster), {mj:.2} mJ ({:.1}× better)",
            105.0 / lat,
            18.7 / mj
        );
        println!(
            "Loihi (DvsGesture): 11.43 ms → {:.1}× ; Asynet CPU (N-Caltech101): 80.4 ms",
            11.43 / lat
        );
    }
    println!("PPF (BNN, 60×40): 7.71 ms — quoted; no dataset released (paper §4.5).");
}
