//! Thread-local heap-allocation counting, used to *prove* the
//! compile-once/execute-many engine's zero-allocation steady state
//! (`rust/tests/exec_plan.rs`, `rust/benches/exec_plan.rs`).
//!
//! [`CountingAllocator`] wraps [`System`] and bumps a thread-local counter
//! on every `alloc` / `alloc_zeroed` / `realloc`. Counting per thread keeps
//! the measurement exact under the multi-threaded test harness. Install it
//! in the *binary* crate under measurement:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: esda::util::alloc::CountingAllocator =
//!     esda::util::alloc::CountingAllocator;
//!
//! let before = esda::util::alloc::CountingAllocator::thread_allocs();
//! hot_path();
//! assert_eq!(esda::util::alloc::CountingAllocator::thread_allocs(), before);
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // `const` initialization: reading the counter from inside the
    // allocator itself must not allocate (no lazy TLS registration).
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// System-allocator wrapper that counts this thread's allocations.
pub struct CountingAllocator;

impl CountingAllocator {
    /// Heap allocations (alloc + alloc_zeroed + realloc calls) made by the
    /// current thread since it started. Monotonic; diff two readings to
    /// count a region. Always 0 unless the wrapper is installed as the
    /// `#[global_allocator]`.
    pub fn thread_allocs() -> u64 {
        ALLOC_COUNT.with(|c| c.get())
    }
}

#[inline]
fn bump() {
    ALLOC_COUNT.with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Without installation the counter stays 0 but the API is usable;
    /// with installation (integration tests) it is monotonic — both
    /// properties reduce to "two reads never go backwards".
    #[test]
    fn counter_is_monotonic() {
        let a = CountingAllocator::thread_allocs();
        let v: Vec<u64> = (0..256).collect();
        let b = CountingAllocator::thread_allocs();
        assert!(b >= a);
        assert_eq!(v.len(), 256);
    }
}
