//! Bounded ingress queue with admission control.
//!
//! `std::sync::mpsc` cannot evict, so the drop-oldest policy needs its own
//! queue: a mutex-guarded deque with two condvars (classic bounded-buffer)
//! plus admission accounting. Under saturation the queue either exerts
//! backpressure ([`DropPolicy::Block`], the paper's all-on-chip FIFO
//! behaviour) or sheds load by evicting the stalest request
//! ([`DropPolicy::DropOldest`], the ESST-style smart-tracker policy —
//! fresh events supersede stale ones for a live vision stream).

use crate::coordinator::lock_ranks;
use crate::util::lockcheck::{RankedCondvar, RankedMutex};
use std::collections::VecDeque;

/// What to do when a request arrives and the ingress queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DropPolicy {
    /// Block the producer until a worker frees a slot (lossless).
    #[default]
    Block,
    /// Evict the oldest queued request and admit the new one (lossy,
    /// bounded staleness — the ESST admission policy).
    DropOldest,
}

impl DropPolicy {
    /// Parse a CLI spelling (`block` | `drop-oldest`).
    pub fn parse(s: &str) -> Option<DropPolicy> {
        match s {
            "block" => Some(DropPolicy::Block),
            "drop-oldest" | "drop_oldest" | "oldest" => Some(DropPolicy::DropOldest),
            _ => None,
        }
    }
}

struct State<T> {
    items: VecDeque<T>,
    /// No further admissions; consumers drain what's queued, then stop.
    closed: bool,
    /// Hard stop: consumers return immediately, leaving queued items
    /// unserved (they are accounted as in-flight by the caller).
    aborted: bool,
    /// Requests admitted into the queue (including ones later evicted).
    submitted: usize,
    /// Requests evicted by `DropOldest` admission control.
    dropped: usize,
}

/// Why a non-blocking [`AdmissionQueue::try_push`] declined the item; the
/// item rides back so the caller can redirect it.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPushError<T> {
    /// The queue is at capacity (and this call never blocks or evicts).
    Full(T),
    /// The queue is closed (or aborted) to producers.
    Closed(T),
}

/// Bounded MPMC queue with a saturation policy and drop accounting.
///
/// Queue operations never nest (no method acquires another queue's
/// state), so every instance — ingress, class, and side queues — shares
/// one rank.
pub struct AdmissionQueue<T> {
    // lint: lock-rank(20): queue-state
    state: RankedMutex<State<T>>,
    // lint: lock-rank(20): queue-state — waits release the state guard
    not_empty: RankedCondvar,
    // lint: lock-rank(20): queue-state — waits release the state guard
    not_full: RankedCondvar,
    cap: usize,
    policy: DropPolicy,
}

impl<T> AdmissionQueue<T> {
    pub fn new(cap: usize, policy: DropPolicy) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: RankedMutex::new(
                lock_ranks::QUEUE_STATE,
                "queue-state",
                State {
                    items: VecDeque::new(),
                    closed: false,
                    aborted: false,
                    submitted: 0,
                    dropped: 0,
                },
            ),
            not_empty: RankedCondvar::new(),
            not_full: RankedCondvar::new(),
            cap: cap.max(1),
            policy,
        }
    }

    /// Admit one request. Returns `Err(item)` if the queue is closed.
    /// Under `Block`, waits for a free slot; under `DropOldest`, evicts the
    /// stalest queued request when full and never waits.
    pub fn push(&self, item: T) -> Result<(), T> {
        self.push_evicting(item).map(|_| ())
    }

    /// [`AdmissionQueue::push`] that hands an evicted request back to the
    /// caller instead of silently discarding it: `Ok(Some(victim))` when
    /// `DropOldest` had to make room (the victim is still counted in the
    /// queue's drop books — the caller's job is attribution, e.g. charging
    /// the drop to the victim's tenant, not re-accounting it).
    pub fn push_evicting(&self, item: T) -> Result<Option<T>, T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.cap {
                st.items.push_back(item);
                st.submitted += 1;
                self.not_empty.notify_one();
                return Ok(None);
            }
            match self.policy {
                // lint:allow(panic): condvar wait re-acquires the state lock;
                // poisoning is the lock-poisoning idiom (holders don't panic)
                DropPolicy::Block => st = self.not_full.wait(st).unwrap(),
                DropPolicy::DropOldest => {
                    let victim = st.items.pop_front();
                    st.dropped += 1;
                    st.items.push_back(item);
                    st.submitted += 1;
                    self.not_empty.notify_one();
                    return Ok(victim);
                }
            }
        }
    }

    /// Non-blocking, non-evicting admission regardless of policy: admit if
    /// a slot is free, otherwise hand the item straight back with the
    /// reason. The sticky router uses this — a full or closed affinity
    /// queue means "fall back to cost-aware placement", never "wait" and
    /// never "evict someone else's work".
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(TryPushError::Closed(item));
        }
        if st.items.len() >= self.cap {
            return Err(TryPushError::Full(item));
        }
        st.items.push_back(item);
        st.submitted += 1;
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take the oldest admitted request; `None` once the queue is closed
    /// and drained, or immediately after an abort (queued items stay put
    /// and show up in [`AdmissionQueue::stats`] as still queued).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return None;
            }
            if let Some(x) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if st.closed {
                return None;
            }
            // lint:allow(panic): condvar wait re-acquires the state lock;
            // poisoning is the lock-poisoning idiom (holders don't panic)
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Blocking micro-batch pop: wait like [`AdmissionQueue::pop`] for the
    /// first item, then greedily drain whatever is *already queued*, up to
    /// `max` items, without waiting again. `out` is cleared first and left
    /// empty once the queue is closed-and-drained or aborted — reusing the
    /// caller's buffer keeps the worker loop allocation-free.
    pub fn pop_batch(&self, max: usize, out: &mut Vec<T>) {
        self.pop_batch_where(max, out, |_| false);
    }

    /// [`AdmissionQueue::pop_batch`] with an inline rejection filter:
    /// queued items matching `reject` are removed and **discarded**
    /// (freeing their slots) without occupying batch capacity; the return
    /// value is how many were discarded, for the caller's accounting.
    /// Returns as soon as it has made progress — at least one accepted
    /// item, **or** at least one discard (possibly with an empty `out`),
    /// or the queue is closed-and-drained / aborted. Returning promptly
    /// on an all-reject drain matters: the caller's scheduling state
    /// (backlogs, drop counters) is stale until it folds the discards in,
    /// and blocking here would let a router route against phantom
    /// backlog. Callers must therefore treat "empty `out`, nonzero
    /// return" as *look again*, not end-of-stream. The serving runtime
    /// uses this to expire deadline-passed requests at the pop, inside
    /// the lock, so an expired request never wastes a batch slot or an
    /// accelerator visit.
    pub fn pop_batch_where<F: FnMut(&T) -> bool>(
        &self,
        max: usize,
        out: &mut Vec<T>,
        reject: F,
    ) -> usize {
        self.pop_batch_where_cancellable(max, out, reject, || false)
    }

    /// [`AdmissionQueue::pop_batch_where`] with a cancellation predicate:
    /// a consumer that would otherwise block on an empty queue first
    /// checks `cancelled()` and, when it reports true, returns with an
    /// empty `out` (and whatever discard count it accumulated) instead of
    /// waiting. The predicate is re-checked on every wakeup, so a caller
    /// that flips external retire state and then calls
    /// [`AdmissionQueue::wake_consumers`] reliably unparks the consumer —
    /// the autoscaler uses this to retire a worker replica that is parked
    /// on an idle queue without closing the queue for everyone else.
    /// Cancellation never discards work: a consumer holding popped items
    /// is not in this function, and the drain attempt happens before the
    /// cancellation check, so a cancelled consumer that found work still
    /// returns it.
    pub fn pop_batch_where_cancellable<F, C>(
        &self,
        max: usize,
        out: &mut Vec<T>,
        mut reject: F,
        cancelled: C,
    ) -> usize
    where
        F: FnMut(&T) -> bool,
        C: Fn() -> bool,
    {
        out.clear();
        let max = max.max(1);
        let mut rejected = 0usize;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return rejected;
            }
            while out.len() < max {
                match st.items.pop_front() {
                    Some(x) => {
                        if reject(&x) {
                            rejected += 1;
                        } else {
                            out.push(x);
                        }
                    }
                    None => break,
                }
            }
            if !out.is_empty() || rejected > 0 {
                // Slots freed (served or discarded): wake every blocked
                // producer, and hand control back so the caller can
                // account for the discards immediately.
                self.not_full.notify_all();
                return rejected;
            }
            if st.closed {
                return rejected;
            }
            if cancelled() {
                return rejected;
            }
            // lint:allow(panic): condvar wait re-acquires the state lock;
            // poisoning is the lock-poisoning idiom (holders don't panic)
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Wake every blocked consumer without closing the queue, so each
    /// re-evaluates its cancellation predicate (see
    /// [`AdmissionQueue::pop_batch_where_cancellable`]). Non-cancelled
    /// consumers observe no queue state change and simply wait again.
    /// The notify happens under the state lock: a consumer is either
    /// still holding the lock (and will see the caller's already-flipped
    /// external state at its next predicate check) or already waiting
    /// (and receives the notification) — no lost-wakeup window.
    pub fn wake_consumers(&self) {
        let _st = self.state.lock().unwrap();
        self.not_empty.notify_all();
    }

    /// Close the queue: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Abort: close *and* stop consumers immediately without draining —
    /// the error path, where serving queued work would only delay the
    /// failure report (its results would be discarded anyway).
    pub fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        st.aborted = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// True once the queue is closed (or aborted) to producers; consumers
    /// may still be draining what is queued. Lets a consumer woken
    /// empty-handed from a cancellable pop distinguish "the queue ended"
    /// from "a cancellation signal meant for a sibling".
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// `(submitted, dropped, still_queued)` snapshot.
    pub fn stats(&self) -> (usize, usize, usize) {
        let st = self.state.lock().unwrap();
        (st.submitted, st.dropped, st.items.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn drop_oldest_evicts_stalest() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(2, DropPolicy::DropOldest);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap(); // evicts 1
        let (submitted, dropped, queued) = q.stats();
        assert_eq!((submitted, dropped, queued), (3, 1, 2));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_batch_drains_up_to_max_without_waiting() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(8, DropPolicy::Block);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let mut batch = Vec::new();
        q.pop_batch(3, &mut batch);
        assert_eq!(batch, vec![0, 1, 2]);
        // Fewer queued than max: return what's there, don't block.
        q.pop_batch(16, &mut batch);
        assert_eq!(batch, vec![3, 4]);
        q.close();
        q.pop_batch(4, &mut batch);
        assert!(batch.is_empty(), "closed+drained queue must yield an empty batch");
    }

    #[test]
    fn pop_batch_blocks_for_first_item_and_wakes_on_close() {
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(2, DropPolicy::Block));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut b = Vec::new();
            q2.pop_batch(4, &mut b);
            b
        });
        std::thread::sleep(Duration::from_millis(10));
        q.push(9).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got, vec![9]);
        // Abort wakes a blocked batch consumer with an empty batch.
        let q3 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut b = Vec::new();
            q3.pop_batch(4, &mut b);
            b
        });
        std::thread::sleep(Duration::from_millis(10));
        q.abort();
        assert!(h.join().unwrap().is_empty());
    }

    /// The filtered pop discards rejects without letting them occupy
    /// batch slots and reports the discard count; batch capacity counts
    /// accepted items only.
    #[test]
    fn pop_batch_where_discards_rejects_without_eating_slots() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(8, DropPolicy::Block);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        let mut batch = Vec::new();
        // Reject odd items: the drain walks 0..=4 to fill 3 accepted
        // slots, discarding the 2 odds in between; 5 stays queued.
        let rejected = q.pop_batch_where(3, &mut batch, |&x| x % 2 == 1);
        assert_eq!(batch, vec![0, 2, 4]);
        assert_eq!(rejected, 2);
        // All-reject queue + close: returns empty with the discard count.
        q.push(7).unwrap();
        q.close();
        let rejected = q.pop_batch_where(4, &mut batch, |_| true);
        assert!(batch.is_empty());
        assert_eq!(rejected, 2, "5 and 7 both discarded");
    }

    /// An all-reject drain returns promptly (empty batch, nonzero count)
    /// so the caller can account for the discards — and the freed slot
    /// unblocks a waiting producer; a subsequent call blocks for a real
    /// item as usual.
    #[test]
    fn pop_batch_where_returns_promptly_on_all_reject_drains() {
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(1, DropPolicy::Block));
        q.push(99).unwrap(); // the reject, filling the depth-1 queue
        let q2 = Arc::clone(&q);
        // Producer blocked on the full queue until the discard frees it.
        let producer = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(Duration::from_millis(10));
        let mut batch = Vec::new();
        let rejected = q.pop_batch_where(2, &mut batch, |&x| x == 99);
        assert!(batch.is_empty(), "all-reject drain must not fabricate items");
        assert_eq!(rejected, 1);
        producer.join().unwrap().unwrap();
        // The next call picks up the producer's accepted item.
        let rejected = q.pop_batch_where(2, &mut batch, |&x| x == 99);
        assert_eq!(batch, vec![1]);
        assert_eq!(rejected, 0);
    }

    /// A cancelled consumer parked on an empty queue returns promptly
    /// after `wake_consumers`, without the queue closing — and a consumer
    /// whose predicate stays false keeps waiting through the same wakeup.
    #[test]
    fn cancellable_pop_unparks_on_wake_without_close() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(4, DropPolicy::Block));
        let retire = Arc::new(AtomicBool::new(false));
        let (q2, r2) = (Arc::clone(&q), Arc::clone(&retire));
        let h = std::thread::spawn(move || {
            let mut b = Vec::new();
            let rej =
                q2.pop_batch_where_cancellable(4, &mut b, |_| false, || r2.load(Ordering::SeqCst));
            (b, rej)
        });
        std::thread::sleep(Duration::from_millis(10));
        // A wake without the predicate flipped must NOT unpark it for good.
        q.wake_consumers();
        std::thread::sleep(Duration::from_millis(10));
        assert!(!h.is_finished(), "non-cancelled consumer must keep waiting");
        retire.store(true, Ordering::SeqCst);
        q.wake_consumers();
        let (b, rej) = h.join().unwrap();
        assert!(b.is_empty(), "cancellation must not fabricate items");
        assert_eq!(rej, 0);
        // The queue itself is still open for other consumers.
        q.push(5).unwrap();
        assert_eq!(q.pop(), Some(5));
    }

    /// Cancellation never discards found work: a consumer whose predicate
    /// is already true still drains what is queued before returning.
    #[test]
    fn cancellable_pop_still_returns_queued_work() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(4, DropPolicy::Block);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let mut b = Vec::new();
        let rej = q.pop_batch_where_cancellable(4, &mut b, |_| false, || true);
        assert_eq!(b, vec![1, 2], "drain happens before the cancellation check");
        assert_eq!(rej, 0);
    }

    /// The evicting push surfaces the drop-oldest victim for caller-side
    /// attribution while the queue's own drop books stay authoritative.
    #[test]
    fn push_evicting_hands_back_the_victim() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(2, DropPolicy::DropOldest);
        assert_eq!(q.push_evicting(1), Ok(None));
        assert_eq!(q.push_evicting(2), Ok(None));
        assert_eq!(q.push_evicting(3), Ok(Some(1)), "full queue evicts the stalest");
        let (submitted, dropped, queued) = q.stats();
        assert_eq!((submitted, dropped, queued), (3, 1, 2));
        q.close();
        assert_eq!(q.push_evicting(4), Err(4));
    }

    /// `try_push` admits into free slots, reports Full without blocking or
    /// evicting (even under DropOldest), and reports Closed after close.
    #[test]
    fn try_push_never_blocks_or_evicts() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(2, DropPolicy::DropOldest);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Ok(()));
        assert_eq!(q.try_push(3), Err(TryPushError::Full(3)));
        assert_eq!(q.pop(), Some(1), "no eviction happened");
        assert_eq!(q.try_push(4), Ok(()));
        let (submitted, dropped, queued) = q.stats();
        assert_eq!((submitted, dropped, queued), (3, 0, 2));
        q.close();
        assert_eq!(q.try_push(5), Err(TryPushError::Closed(5)));
    }

    #[test]
    fn push_after_close_returns_item() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(1, DropPolicy::Block);
        q.close();
        assert_eq!(q.push(7), Err(7));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(1, DropPolicy::Block));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn block_policy_applies_backpressure() {
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(1, DropPolicy::Block));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        // Producer blocks on the full queue until the consumer pops.
        let h = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
        let (submitted, dropped, _) = q.stats();
        assert_eq!((submitted, dropped), (2, 0));
    }

    #[test]
    fn abort_stops_consumers_without_draining() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(4, DropPolicy::Block);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.abort();
        assert_eq!(q.pop(), None, "abort must not hand out queued items");
        assert_eq!(q.push(3), Err(3), "abort implies closed");
        let (submitted, dropped, queued) = q.stats();
        assert_eq!((submitted, dropped, queued), (2, 0, 2));
    }

    #[test]
    fn parse_policies() {
        assert_eq!(DropPolicy::parse("block"), Some(DropPolicy::Block));
        assert_eq!(DropPolicy::parse("drop-oldest"), Some(DropPolicy::DropOldest));
        assert_eq!(DropPolicy::parse("nope"), None);
    }
}
