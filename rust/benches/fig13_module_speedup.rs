// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Figure 13: per-MBConv-block speedup of the sparse dataflow modules over
//! the dense sliding-window baseline, across input NZ ratios 10%–90%.
//!
//! Per the paper's §4.3 protocol: each MobileNetV2 block is synthesized
//! individually with the hardware configuration from the whole-network
//! optimization; inputs are randomly generated at swept sparsity; the
//! dense baseline keeps identical PF/bitwidth but iterates every position
//! and every kernel offset. Expected shape: 4.5–11× at 10% NZ, ~linear
//! decay, crossover below 1× for early blocks above ~70% NZ.

use esda::arch::builder::{build_pipeline, HwConfig};
use esda::arch::dense::dense_chain_latency;
use esda::hwopt::{allocate, stats::collect_stats, Budget};
use esda::model::graph::Block;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::report::{render_series, Series};
use esda::sparse::{Bitmap, SparseMap, Token};
use esda::util::Rng;

/// One MBConv block as a standalone spec with direct channel input.
fn block_spec(cin: usize, b: Block, w: usize, h: usize) -> NetworkSpec {
    NetworkSpec {
        name: "blk".into(),
        w,
        h,
        cin,
        n_classes: 2, // unused — no PoolFc
        blocks: vec![b],
    }
}

fn random_input(rng: &mut Rng, w: usize, h: usize, c: usize, p: f64) -> SparseMap<f32> {
    let mut m = SparseMap::empty(w, h, c);
    for y in 0..h {
        for x in 0..w {
            if rng.chance(p) {
                let f: Vec<f32> = (0..c).map(|_| rng.f32() * 2.0 - 1.0).collect();
                m.push(Token::new(x as u16, y as u16), &f);
            }
        }
    }
    m
}

fn main() {
    println!("# Fig. 13 — sparse dataflow speedup over dense baseline per MBConv block\n");
    // MobileNetV2-0.5 on a 128×128 input (DvsGesture geometry); block list
    // with the resolution each block sees.
    let net = NetworkSpec::mobilenet_v2_05("mbv2", 128, 128, 10);
    let mut blocks: Vec<(usize, Block, usize, usize)> = Vec::new(); // (cin, block, w, h)
    let (mut w, mut h) = (net.w, net.h);
    let mut c = net.cin;
    for b in &net.blocks {
        match *b {
            Block::Stem { cout, stride, .. } => {
                if stride == 2 {
                    w = (w + 1) / 2;
                    h = (h + 1) / 2;
                }
                c = cout;
            }
            Block::MBConv { cout, stride, .. } => {
                blocks.push((c, *b, w, h));
                if stride == 2 {
                    w = (w + 1) / 2;
                    h = (h + 1) / 2;
                }
                c = cout;
            }
            _ => {}
        }
    }
    // Whole-network PF allocation at a representative sparsity (20%),
    // mirroring "the hardware configuration of each block aligns with the
    // overall optimization result" (§4.3).
    let mut rng = Rng::new(0xF16_13);
    let overall_stats = {
        let mut bms = Vec::new();
        for _ in 0..4 {
            let mut b = Bitmap::new(net.w, net.h);
            for y in 0..net.h {
                for x in 0..net.w {
                    if rng.chance(0.2) {
                        b.set(x, y);
                    }
                }
            }
            bms.push(b);
        }
        collect_stats(&net, &bms)
    };
    let overall = allocate(&net, &overall_stats, &Budget::zcu102()).expect("mbv2 fits");
    // Map op index → PF so each block reuses its own ops' PFs.
    let net_ops = net.ops();

    let densities = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
    let n_show = blocks.len().min(11);
    let mut series: Vec<Series> = Vec::new();
    for (bi, (cin, blk, bw, bh)) in blocks.iter().take(n_show).enumerate() {
        let spec = block_spec(*cin, *blk, *bw, *bh);
        let ops = spec.ops();
        // PFs: find this block's ops inside the whole-net allocation by
        // structural match (same op kind and shape, first unused match).
        let mut pfs = Vec::with_capacity(ops.len());
        let mut cursor = 0usize;
        for op in &ops {
            let found = net_ops[cursor..]
                .iter()
                .position(|o| o == op)
                .map(|p| cursor + p);
            match found {
                Some(idx) => {
                    pfs.push(overall.pf[idx]);
                    cursor = idx + 1;
                }
                None => pfs.push(16),
            }
        }
        let weights = FloatWeights::random(&spec, bi as u64 + 1);
        let mut points = Vec::new();
        for &p in &densities {
            // Calibrate + quantize on an input at this density.
            let calib = vec![random_input(&mut rng, *bw, *bh, *cin, p)];
            let qnet = quantize_network(&spec, &weights, &calib);
            let input = random_input(&mut rng, *bw, *bh, *cin, p);
            let qin = esda::model::exec::quantize_input(&qnet, &input);
            let cfg = HwConfig { pf: pfs.clone(), fifo_depth: 8 };
            let mut pipe = build_pipeline(&qnet, &cfg, &qin);
            let report = pipe.run(20_000_000_000).expect("block sim");
            let sparse_cycles = report.cycles as f64;
            let dense_cycles = dense_chain_latency(&ops, &pfs, *bw, *bh) as f64;
            points.push((p, dense_cycles / sparse_cycles));
        }
        series.push(Series { name: format!("blk_{bi}"), points });
    }
    println!(
        "{}",
        render_series("speedup (dense cycles / sparse cycles)", "input NZ ratio", &series)
    );
    // Headline checks mirrored in EXPERIMENTS.md.
    let at10: Vec<f64> = series.iter().map(|s| s.points[0].1).collect();
    let max10 = at10.iter().cloned().fold(0.0, f64::max);
    let min10 = at10.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("speedup range at 10% NZ: {min10:.1}×–{max10:.1}× (paper: 4.5×–11×)");
    let crossovers = series
        .iter()
        .filter(|s| s.points.iter().any(|&(p, v)| p >= 0.7 && v < 1.0))
        .count();
    println!("blocks slower than dense above 70% NZ: {crossovers} (paper: early blocks blk_0–blk_5)");
}
