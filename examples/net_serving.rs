// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Multi-tenant network front door demo: two producers push event
//! packets over loopback TCP — one floods the door, one trickles — and
//! the serving runtime's weighted admission quotas keep the quiet tenant
//! whole while the flood is shed at its quota.
//!
//! Three things are on display:
//! 1. socket ingestion: length-prefixed event packets land in DMA-style
//!    buffers flushed on size or timeout, exactly the `--source tcp:port`
//!    path of `esda serve`,
//! 2. tenant isolation: the saturating tenant's surplus is shed at its
//!    ingress quota, so every one of the quiet tenant's requests is
//!    served and its SLO attainment stays perfect,
//! 3. the ingestion bugfix: a corrupt packet spliced into the flood is a
//!    *recoverable* reject — skipped and counted under `ingest_rejects`
//!    instead of killing the run.
//!
//! With `--report-out path` a machine-readable JSON summary is written —
//! CI greps it for `null` to catch NaN/inf leaking into reports.
//!
//! Run: `cargo run --release --example net_serving -- --dataset n_mnist`
//! (add `--smoke` for the quick CI-sized run)

use esda::coordinator::net::MAX_PACKET_EVENTS;
use esda::coordinator::{
    encode_packet, run_server_source, Backend, BackendError, Classification, DropPolicy,
    Functional, NetConfig, NetSource, ServerConfig, TenantConfig,
};
use esda::events::DatasetProfile;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::json::Json;
use esda::util::Rng;
use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

/// A deliberately slow backend so the flood actually saturates.
struct Throttled {
    inner: Functional,
    delay: Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled-functional"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

/// One length-prefixed TCP frame around an encoded packet.
fn frame(pkt: &[u8]) -> Vec<u8> {
    let len = u32::try_from(pkt.len()).expect("packet fits a u32 frame header");
    let mut f = len.to_le_bytes().to_vec();
    f.extend_from_slice(pkt);
    f
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["smoke"]).unwrap();
    let smoke = args.has("smoke");
    let name = args.get_or("dataset", "n_mnist");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            esda::events::repr::histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);

    let n_flood = if smoke { 24 } else { 60 };
    let n_quiet = 5;
    // Pre-encode every producer's packets (real synthetic recordings,
    // windowed to the packet cap) so the send loops are pure socket I/O.
    let pkts = |tenant: u16, n: usize, rng: &mut Rng| -> Vec<Vec<u8>> {
        (0..n)
            .map(|i| {
                let label = i % profile.n_classes;
                let mut events = profile.sample(label, rng);
                events.truncate(MAX_PACKET_EVENTS);
                let wire_label = u32::try_from(label).expect("class label fits u32");
                frame(&encode_packet(tenant, wire_label, &events))
            })
            .collect()
    };
    let flood_pkts = pkts(0, n_flood, &mut rng);
    let quiet_pkts = pkts(1, n_quiet, &mut rng);

    // Bind the front door on an ephemeral loopback port; the receive
    // threads land packets in DMA buffers behind the scenes.
    let ncfg =
        NetConfig { tenants: 2, idle_timeout: Duration::from_secs(5), ..NetConfig::default() };
    let src = NetSource::tcp(0, profile.w, profile.h, ncfg)
        .expect("bind tcp front door")
        .with_limit(n_flood + n_quiet);
    let port = src.local_port();
    println!("== front door bound at tcp:{port} ==");

    // Producer 1: the flood, back-to-back on one connection — with one
    // corrupt packet spliced in (bad magic). The boundary skips it
    // recoverably; without the severity split it would kill the run.
    let flood = std::thread::spawn(move || {
        let mut c = TcpStream::connect(("127.0.0.1", port)).unwrap();
        for (i, f) in flood_pkts.iter().enumerate() {
            if i == flood_pkts.len() / 2 {
                let mut bad = f.clone();
                bad[4] ^= 0xff; // corrupt the packet magic, keep the frame
                c.write_all(&bad).unwrap();
            }
            c.write_all(f).unwrap();
        }
        c.flush().unwrap();
    });
    // Producer 2: the quiet tenant, trickling mid-saturation.
    let quiet = std::thread::spawn(move || {
        let mut c = TcpStream::connect(("127.0.0.1", port)).unwrap();
        for f in &quiet_pkts {
            c.write_all(f).unwrap();
            c.flush().unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    // Depth 16 split 1:1 gives each tenant an ingress quota of 8: the
    // flood can hold at most half the queue, so the quiet tenant's
    // (at most 5 concurrent) requests are always admitted.
    let backend = Throttled { inner: Functional::new(qnet), delay: Duration::from_millis(2) };
    let cfg = ServerConfig {
        workers: 1,
        queue_depth: 16,
        drop_policy: DropPolicy::DropOldest,
        tenants: vec![
            TenantConfig::new("flood", 1).with_slo(Duration::from_secs(60)),
            TenantConfig::new("quiet", 1).with_slo(Duration::from_secs(60)),
        ],
        ..Default::default()
    };
    let r = run_server_source(Box::new(src), &backend, &cfg).expect("front-door serve");
    flood.join().unwrap();
    quiet.join().unwrap();

    let m = &r.metrics;
    println!(
        "  {} served | {} quota/queue drop(s) | {} recoverable ingest reject(s)",
        m.total, m.dropped, m.ingest_rejects
    );
    if let Some(line) = esda::report::slo_line(m) {
        println!("  {line}");
    }
    println!("{}", esda::report::tenant_table(m).render());

    // The demo is also an acceptance check: the corrupt packet was
    // counted (not fatal), the books balance, and the quiet tenant rode
    // out the flood untouched.
    assert_eq!(m.ingest_rejects, 1, "the corrupt packet must be skipped and counted");
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        n_flood + n_quiet,
        "global books must cover the full stream"
    );
    let fl = &m.per_tenant[0];
    let qt = &m.per_tenant[1];
    assert_eq!(fl.offered(), n_flood, "TCP delivers the whole flood");
    assert_eq!(qt.served, n_quiet, "the quiet tenant must not be starved");
    assert_eq!(qt.dropped, 0);
    let qt_slo = qt.slo_attainment().expect("quiet tenant carries an SLO");
    assert!((qt_slo - 1.0).abs() < f64::EPSILON, "quiet SLO attainment must be perfect");
    assert!(fl.dropped >= 1, "the flood must be shed at its quota");

    // Machine-readable summary (CI greps this for `null`).
    if let Some(out) = args.get("report-out") {
        let doc = Json::obj(vec![
            ("offered", Json::Num((n_flood + n_quiet) as f64)),
            ("served", Json::Num(m.total as f64)),
            ("queue_drops", Json::Num(m.dropped as f64)),
            ("deadline_drops", Json::Num(m.deadline_drops() as f64)),
            ("ingest_rejects", Json::Num(m.ingest_rejects as f64)),
            (
                "conservation_ok",
                Json::Bool(m.total + m.dropped + m.deadline_drops() == n_flood + n_quiet),
            ),
            ("flood_offered", Json::Num(fl.offered() as f64)),
            ("flood_served", Json::Num(fl.served as f64)),
            ("flood_dropped", Json::Num(fl.dropped as f64)),
            ("flood_quota", Json::Num(fl.quota as f64)),
            ("quiet_served", Json::Num(qt.served as f64)),
            ("quiet_quota", Json::Num(qt.quota as f64)),
            ("quiet_slo_attainment", Json::Num(qt_slo)),
        ]);
        std::fs::write(out, doc.to_string()).expect("write report");
        println!("report written -> {out}");
    }
}
