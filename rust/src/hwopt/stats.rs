//! Per-layer sparsity statistics (paper §3.4.1).
//!
//! Submanifold networks have a weight-independent sparsity *pattern*: the
//! token set of every intermediate layer is a pure function of the input
//! bitmap (stride-1 ops preserve it, stride-2 ops downsample it by the 2×2
//! grid rule). Statistics therefore propagate bitmaps only — no weights,
//! no feature arithmetic — which is what makes collecting them over whole
//! datasets cheap.

use crate::model::graph::{NetworkSpec, Op};
use crate::sparse::Bitmap;

/// Sparsity statistics for one op.
#[derive(Debug, Clone, Default)]
pub struct LayerStats {
    /// Mean spatial NZ ratio of the op's *output* tokens (S_s).
    pub s_s: f64,
    /// Mean fraction of the k×k kernel offsets that are nonzero per output
    /// window (S_k); 1.0 for non-windowed ops.
    pub s_k: f64,
    /// Mean number of output tokens the op iterates (H·W·S_s of Eqn. 5).
    pub tokens: f64,
    /// Number of samples aggregated.
    pub n: usize,
}

impl LayerStats {
    fn add(&mut self, s_s: f64, s_k: f64, tokens: f64) {
        let n = self.n as f64;
        self.s_s = (self.s_s * n + s_s) / (n + 1.0);
        self.s_k = (self.s_k * n + s_k) / (n + 1.0);
        self.tokens = (self.tokens * n + tokens) / (n + 1.0);
        self.n += 1;
    }
}

/// Mean fraction of nonzero offsets in the k×k window around each set cell
/// (stride 1) — the S_k of Eqn. 5.
fn kernel_occupancy_s1(bm: &Bitmap, k: usize) -> f64 {
    let u = (k as isize - 1) / 2;
    let mut total = 0usize;
    let mut windows = 0usize;
    for (x, y) in bm.iter_set() {
        windows += 1;
        for dy in -u..=u {
            for dx in -u..=u {
                let ix = x as isize + dx;
                let iy = y as isize + dy;
                if ix >= 0
                    && iy >= 0
                    && (ix as usize) < bm.w
                    && (iy as usize) < bm.h
                    && bm.get(ix as usize, iy as usize)
                {
                    total += 1;
                }
            }
        }
    }
    if windows == 0 {
        0.0
    } else {
        total as f64 / (windows * k * k) as f64
    }
}

/// S_k for stride-2 windows: occupancy of the k×k input window around each
/// *output* token's anchor (2gx, 2gy).
fn kernel_occupancy_s2(input: &Bitmap, out: &Bitmap, k: usize) -> f64 {
    let pad = (k as isize - 1) / 2;
    let mut total = 0usize;
    let mut windows = 0usize;
    for (gx, gy) in out.iter_set() {
        windows += 1;
        for dy in 0..k as isize {
            for dx in 0..k as isize {
                let ix = 2 * gx as isize + dx - pad;
                let iy = 2 * gy as isize + dy - pad;
                if ix >= 0
                    && iy >= 0
                    && (ix as usize) < input.w
                    && (iy as usize) < input.h
                    && input.get(ix as usize, iy as usize)
                {
                    total += 1;
                }
            }
        }
    }
    if windows == 0 {
        0.0
    } else {
        total as f64 / (windows * k * k) as f64
    }
}

/// Propagate one input bitmap through the op program, updating `acc`.
pub fn accumulate_stats(spec: &NetworkSpec, input: &Bitmap, acc: &mut [LayerStats]) {
    let ops = spec.ops();
    assert_eq!(acc.len(), ops.len());
    let mut bm = input.clone();
    let mut fork_stack: Vec<Bitmap> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Conv1x1 { .. } => {
                acc[i].add(bm.nz_ratio(), 1.0, bm.count() as f64);
            }
            Op::ConvKxK { k, stride, .. } | Op::DwConv { k, stride, .. } => {
                if stride == 1 {
                    let s_k = kernel_occupancy_s1(&bm, k);
                    acc[i].add(bm.nz_ratio(), s_k, bm.count() as f64);
                } else {
                    let out = bm.downsample_sparse(2);
                    let s_k = kernel_occupancy_s2(&bm, &out, k);
                    acc[i].add(out.nz_ratio(), s_k, out.count() as f64);
                    bm = out;
                }
            }
            Op::ResFork => {
                fork_stack.push(bm.clone());
                acc[i].add(bm.nz_ratio(), 1.0, bm.count() as f64);
            }
            Op::ResAdd => {
                let other = fork_stack.pop().expect("unbalanced fork");
                debug_assert_eq!(other, bm, "submanifold branches must share patterns");
                acc[i].add(bm.nz_ratio(), 1.0, bm.count() as f64);
            }
            Op::GlobalPool { .. } => {
                acc[i].add(bm.nz_ratio(), 1.0, bm.count() as f64);
            }
            Op::Fc { .. } => {
                acc[i].add(1.0, 1.0, 1.0);
            }
        }
    }
}

/// Collect statistics for a network over dataset samples (bitmaps of the
/// 2-channel histogram representation).
pub fn collect_stats(spec: &NetworkSpec, inputs: &[Bitmap]) -> Vec<LayerStats> {
    let mut acc = vec![LayerStats::default(); spec.ops().len()];
    for bm in inputs {
        assert_eq!((bm.w, bm.h), (spec.w, spec.h));
        accumulate_stats(spec, bm, &mut acc);
    }
    acc
}

/// Convenience: sample `n_samples` synthetic recordings from a profile and
/// collect stats for `spec`.
pub fn collect_stats_for_profile(
    spec: &NetworkSpec,
    profile: &crate::events::DatasetProfile,
    n_samples: usize,
    seed: u64,
) -> Vec<LayerStats> {
    let mut rng = crate::util::Rng::new(seed);
    let mut bitmaps = Vec::with_capacity(n_samples);
    for i in 0..n_samples {
        let es = profile.sample(i % profile.n_classes, &mut rng);
        let m = crate::events::repr::histogram2(&es, profile.w, profile.h);
        bitmaps.push(m.bitmap());
    }
    collect_stats(spec, &bitmaps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NetworkSpec;
    use crate::util::Rng;

    fn random_bitmap(rng: &mut Rng, w: usize, h: usize, p: f64) -> Bitmap {
        let mut b = Bitmap::new(w, h);
        for y in 0..h {
            for x in 0..w {
                if rng.chance(p) {
                    b.set(x, y);
                }
            }
        }
        b
    }

    #[test]
    fn stride1_ops_share_input_sparsity() {
        let spec = NetworkSpec::tiny(16, 16, 3);
        let mut rng = Rng::new(1);
        let inputs: Vec<Bitmap> = (0..4).map(|_| random_bitmap(&mut rng, 16, 16, 0.2)).collect();
        let stats = collect_stats(&spec, &inputs);
        let ops = spec.ops();
        let mean_in: f64 = inputs.iter().map(|b| b.nz_ratio()).sum::<f64>() / 4.0;
        // All ops before the stride-2 dw see the input sparsity.
        let first_s2 = ops.iter().position(|o| o.stride() == 2).unwrap();
        for i in 0..first_s2 {
            if !matches!(ops[i], Op::Fc { .. }) {
                assert!((stats[i].s_s - mean_in).abs() < 1e-9, "op {i}");
            }
        }
        // After downsampling sparsity can only grow (denser) per area.
        assert!(stats[first_s2].s_s >= mean_in * 0.9);
    }

    #[test]
    fn kernel_occupancy_bounds() {
        let mut rng = Rng::new(2);
        for &p in &[0.05, 0.3, 0.9] {
            let bm = random_bitmap(&mut rng, 20, 20, p);
            if bm.count() == 0 {
                continue;
            }
            let sk = kernel_occupancy_s1(&bm, 3);
            // Window always contains its own center.
            assert!(sk >= 1.0 / 9.0 - 1e-9, "p={p} sk={sk}");
            assert!(sk <= 1.0);
        }
    }

    #[test]
    fn full_bitmap_has_full_occupancy_interior() {
        let mut bm = Bitmap::new(10, 10);
        for y in 0..10 {
            for x in 0..10 {
                bm.set(x, y);
            }
        }
        let sk = kernel_occupancy_s1(&bm, 3);
        // Border windows are clipped, so slightly below 1.
        assert!(sk > 0.8 && sk <= 1.0, "{sk}");
    }

    #[test]
    fn denser_input_higher_sk() {
        let mut rng = Rng::new(3);
        let sparse = random_bitmap(&mut rng, 24, 24, 0.05);
        let dense = random_bitmap(&mut rng, 24, 24, 0.6);
        assert!(kernel_occupancy_s1(&dense, 3) > kernel_occupancy_s1(&sparse, 3));
    }
}
