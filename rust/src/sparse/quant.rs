//! Fixed-point (int8) arithmetic primitives for the hardware path.
//!
//! Mirrors HAWQ-V3-style *dyadic* quantization (the paper's training flow,
//! §4.1): symmetric int8 weights/activations, int32 accumulators, and a
//! per-layer requantization `out = clamp(round(acc · m / 2^s))` with integer
//! multiplier `m` and shift `s` — exactly representable in hardware and
//! mirrored bit-for-bit by `python/compile/quantize.py`.

/// Saturating int8 range.
pub const QMIN: i32 = -128;
pub const QMAX: i32 = 127;

/// Per-layer requantization + activation parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    /// Dyadic multiplier (15-bit, positive).
    pub mult: i32,
    /// Right shift.
    pub shift: u32,
    /// Lower clamp after requant: 0 for ReLU/ReLU6 layers, QMIN otherwise.
    pub lo: i32,
    /// Upper clamp: quantized 6 for ReLU6 layers, QMAX otherwise.
    pub hi: i32,
}

impl Requant {
    /// Identity-ish requant for tests (scale 1, no activation).
    pub fn unit() -> Requant {
        Requant { mult: 1 << 14, shift: 14, lo: QMIN, hi: QMAX }
    }

    /// Build from an effective float rescale `scale = s_in · s_w / s_out`
    /// and activation clamps. The multiplier is normalized into
    /// `[2^14, 2^15)` so every layer carries the same precision; this exact
    /// procedure is mirrored in python.
    pub fn from_scale(scale: f64, lo: i32, hi: i32) -> Requant {
        assert!(scale > 0.0 && scale.is_finite(), "bad requant scale {scale}");
        // Normalize mantissa into [0.5, 1.0), then take 15 bits.
        let mut m = scale;
        let mut e = 0i32;
        while m >= 1.0 {
            m /= 2.0;
            e += 1;
        }
        while m < 0.5 {
            m *= 2.0;
            e -= 1;
        }
        // scale = m · 2^e with m ∈ [0.5, 1): mult = round(m·2^15), shift = 15 − e.
        let mut mult = (m * (1 << 15) as f64).round() as i32;
        let mut shift = 15 - e;
        if mult == (1 << 15) {
            mult >>= 1;
            shift -= 1;
        }
        assert!((1..=62).contains(&shift), "requant shift {shift} out of range (scale {scale})");
        Requant { mult, shift: shift as u32, lo, hi }
    }

    /// Apply to an int32 accumulator.
    #[inline]
    pub fn apply(&self, acc: i32) -> i8 {
        requant(acc as i64, self)
    }
}

/// Round-half-up dyadic requantization with clamping.
#[inline]
pub fn requant(acc: i64, rq: &Requant) -> i8 {
    let prod = acc * rq.mult as i64;
    let rounded = (prod + (1i64 << (rq.shift - 1))) >> rq.shift;
    rounded.clamp(rq.lo as i64, rq.hi as i64) as i8
}

/// Symmetric per-tensor quantization scale for a float tensor: maps
/// `max(|x|)` to 127. Returns (scale, quantized values).
pub fn quantize_symmetric(xs: &[f32]) -> (f32, Vec<i8>) {
    let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    let scale = amax / 127.0;
    let q = xs
        .iter()
        .map(|&x| ((x / scale).round() as i32).clamp(QMIN, QMAX) as i8)
        .collect();
    (scale, q)
}

/// Dequantize.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    #[test]
    fn unit_requant_is_identity_in_range() {
        let rq = Requant::unit();
        for v in -128..=127i32 {
            assert_eq!(rq.apply(v), v as i8);
        }
    }

    #[test]
    fn requant_saturates() {
        let rq = Requant::unit();
        assert_eq!(rq.apply(100000), 127);
        assert_eq!(rq.apply(-100000), -128);
    }

    #[test]
    fn relu_clamps_negative() {
        let rq = Requant { lo: 0, ..Requant::unit() };
        assert_eq!(rq.apply(-5), 0);
        assert_eq!(rq.apply(5), 5);
    }

    #[test]
    fn from_scale_approximates() {
        check("dyadic requant ≈ float rescale", 256, |g| {
            // scales spanning the realistic range for conv layers
            let scale = 2.0f64.powf(g.f64() * 16.0 - 12.0); // 2^-12 .. 2^4
            let rq = Requant::from_scale(scale, QMIN, QMAX);
            let eff = rq.mult as f64 / 2.0f64.powi(rq.shift as i32);
            let rel = (eff - scale).abs() / scale;
            assert!(rel < 1e-4, "scale {scale} -> mult {} shift {} rel {rel}", rq.mult, rq.shift);
            // Multiplier normalized to 15 bits.
            assert!(rq.mult >= (1 << 14) && rq.mult < (1 << 15));
        });
    }

    #[test]
    fn from_scale_matches_float_on_accs() {
        check("requant(acc) ≈ round(acc·scale)", 256, |g| {
            let scale = 2.0f64.powf(g.f64() * 10.0 - 8.0);
            let rq = Requant::from_scale(scale, QMIN, QMAX);
            let acc = g.i64(-(1 << 20), 1 << 20);
            let float = (acc as f64 * scale).round().clamp(-128.0, 127.0) as i8;
            let fixed = requant(acc, &rq);
            assert!(
                (float as i32 - fixed as i32).abs() <= 1,
                "acc {acc} scale {scale}: float {float} fixed {fixed}"
            );
        });
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        check("symmetric quant error ≤ scale/2", 128, |g| {
            let n = g.usize(1, 64);
            let xs: Vec<f32> = (0..n).map(|_| (g.f64() as f32 - 0.5) * 8.0).collect();
            let (scale, q) = quantize_symmetric(&xs);
            let back = dequantize(&q, scale);
            for (a, b) in xs.iter().zip(&back) {
                assert!((a - b).abs() <= scale * 0.5 + 1e-6);
            }
        });
    }
}
