//! NullHop-style layer-sequential accelerator model (Aimar et al., TNNLS
//! 2019) — the prior-work FPGA comparator of Table 1 and the ablation
//! bench.
//!
//! NullHop processes one layer at a time on a reusable engine: activations
//! are encoded with a binary bitmap + nonzero list, zero *activations* are
//! skipped inside the MAC array, but every layer's input activations and
//! weights stream from off-chip and outputs stream back. The paper's
//! critique (§1) is precisely this "recurrent input/output operations
//! involving weights and intermediate activations" — latency stacks up
//! layer-sequentially instead of pipelining, and DMA traffic is paid per
//! layer. The analytic model below reproduces that structure:
//!
//! ```text
//! lat = Σ_layers max( compute(layer), dma(act_in + weights + act_out) )
//! compute = nonzero MACs / MAC_array   (bitmap skipping ⇒ only NZ inputs)
//! dma     = bytes / bus_bytes_per_cycle
//! ```

use super::module::pe_cycles;
use crate::hwopt::stats::LayerStats;
use crate::model::graph::{NetworkSpec, Op};

/// NullHop-like engine configuration (roughly the 2019 paper's Zynq
/// instance: 128 MACs, 64-bit DDR bus at the accelerator clock).
#[derive(Clone, Copy, Debug)]
pub struct NullHopConfig {
    /// MAC array size (shared by all layers).
    pub macs: usize,
    /// DMA bus width in bytes/cycle.
    pub bus_bytes: usize,
}

impl Default for NullHopConfig {
    fn default() -> Self {
        NullHopConfig { macs: 128, bus_bytes: 8 }
    }
}

/// Estimated cycles for one inference, layer-sequential with bitmap
/// activation skipping. Uses the same sparsity statistics as the ESDA
/// cost model, so the comparison isolates *architecture*, not workload.
pub fn nullhop_latency(spec: &NetworkSpec, stats: &[LayerStats], cfg: &NullHopConfig) -> f64 {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    let mut total = 0f64;
    for (i, op) in ops.iter().enumerate() {
        let (w, h) = res[i];
        let st = &stats[i];
        let (macs_nz, cin, cout): (f64, usize, usize) = match *op {
            Op::Conv1x1 { cin, cout, .. } => (st.tokens * (cin * cout) as f64, cin, cout),
            Op::ConvKxK { k, cin, cout, .. } => {
                (st.tokens * (k * k) as f64 * st.s_k * (cin * cout) as f64, cin, cout)
            }
            Op::DwConv { k, c, .. } => (st.tokens * (k * k) as f64 * st.s_k * c as f64, c, c),
            Op::ResFork | Op::ResAdd => (0.0, 0, 0),
            Op::GlobalPool { c } => (st.tokens * c as f64, c, c),
            Op::Fc { cin, cout } => ((cin * cout) as f64, cin, cout),
        };
        if cin == 0 {
            continue;
        }
        let compute = macs_nz / cfg.macs as f64;
        // DMA: sparse activations in (nnz × cin bytes + bitmap), weights in,
        // activations out. ESDA pays none of this — everything is on-chip.
        let act_in_bytes = st.tokens * cin as f64 + (w * h) as f64 / 8.0;
        let act_out_bytes = st.tokens * cout as f64;
        let weight_bytes = op.weight_count() as f64;
        let dma = (act_in_bytes + act_out_bytes + weight_bytes) / cfg.bus_bytes as f64;
        // NullHop overlaps compute with streaming; stay favourable to it:
        total += compute.max(dma);
    }
    total
}

/// ESDA pipeline latency under the same statistics and a comparable PE
/// budget (apples-to-apples): the Eqn. 6 optimum.
pub fn esda_latency_matched(spec: &NetworkSpec, stats: &[LayerStats], total_pe: usize) -> f64 {
    let budget = crate::hwopt::Budget { dsp: total_pe, bram: 4096 };
    crate::hwopt::allocate(spec, stats, &budget)
        .map(|a| a.latency)
        .unwrap_or(f64::INFINITY)
}

/// Dense compute lower bound for the engine (test helper).
pub fn nullhop_dense_compute(spec: &NetworkSpec, cfg: &NullHopConfig) -> f64 {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    ops.iter()
        .enumerate()
        .map(|(i, op)| {
            let (w, h) = res[i];
            let per_pos = match *op {
                Op::Conv1x1 { cin, cout, .. } => cin * cout,
                Op::ConvKxK { k, cin, cout, .. } => k * k * cin * cout,
                Op::DwConv { k, c, .. } => k * k * c,
                _ => 0,
            };
            (w * h * per_pos) as f64 / cfg.macs as f64
        })
        .sum::<f64>()
        .max(pe_cycles(1, 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwopt::stats::collect_stats;
    use crate::sparse::Bitmap;
    use crate::util::Rng;

    fn stats_at(spec: &NetworkSpec, p: f64, seed: u64) -> Vec<LayerStats> {
        let mut rng = Rng::new(seed);
        let mut bms = Vec::new();
        for _ in 0..3 {
            let mut b = Bitmap::new(spec.w, spec.h);
            for y in 0..spec.h {
                for x in 0..spec.w {
                    if rng.chance(p) {
                        b.set(x, y);
                    }
                }
            }
            bms.push(b);
        }
        collect_stats(spec, &bms)
    }

    #[test]
    fn sparsity_reduces_nullhop_latency() {
        let spec = NetworkSpec::compact("c", 64, 64, 3);
        let cfg = NullHopConfig::default();
        let sparse = nullhop_latency(&spec, &stats_at(&spec, 0.05, 1), &cfg);
        let dense = nullhop_latency(&spec, &stats_at(&spec, 0.6, 1), &cfg);
        assert!(sparse < dense);
    }

    /// The paper's headline vs NullHop: a pipelined all-on-chip design is
    /// several times faster at matched PE count on sparse input.
    #[test]
    fn esda_beats_nullhop_on_sparse_input() {
        let spec = NetworkSpec::compact("c", 64, 64, 3);
        let stats = stats_at(&spec, 0.12, 2); // RoShamBo-like density
        let cfg = NullHopConfig::default();
        let nh = nullhop_latency(&spec, &stats, &cfg);
        let esda = esda_latency_matched(&spec, &stats, 1282); // Table-1 ESDA DSP
        assert!(esda.is_finite());
        let speedup = nh / esda;
        assert!(speedup > 2.0, "speedup only {speedup:.2}×");
    }

    #[test]
    fn layer_sequential_exceeds_any_single_layer() {
        let spec = NetworkSpec::compact("c", 64, 64, 3);
        let stats = stats_at(&spec, 0.2, 3);
        let cfg = NullHopConfig::default();
        let nh = nullhop_latency(&spec, &stats, &cfg);
        assert!(nh > 0.0);
        assert!(nh >= nullhop_dense_compute(&spec, &cfg) * 0.01);
    }
}
