// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Quickstart: build a tiny submanifold network, co-optimize it for the
//! ZCU102 with the Eqn. 5/6 flow, and cycle-simulate one event-camera
//! inference — the whole ESDA stack in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use esda::arch::{simulate_inference, HwConfig};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::{
    allocate, power::PowerModel, power::CLOCK_HZ, stats::collect_stats_for_profile, Budget,
};
use esda::model::exec::argmax;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::util::Rng;

fn main() {
    // 1. A dataset profile (synthetic stand-in for DvsGesture et al.).
    let profile = DatasetProfile::n_mnist();
    println!(
        "dataset: {} ({}×{}, {} classes)",
        profile.name, profile.w, profile.h, profile.n_classes
    );

    // 2. A network: stem → MBConv blocks → pool+FC (paper Fig. 10).
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    println!("network: {} ops, {} params", spec.ops().len(), spec.param_count());

    // 3. Sparsity statistics → Eqn. 6 hardware allocation.
    let stats = collect_stats_for_profile(&spec, &profile, 8, 1);
    let alloc = allocate(&spec, &stats, &Budget::zcu102()).expect("fits ZCU102");
    println!(
        "allocation: bottleneck {:.0} cycles ({:.3} ms @187 MHz), {} DSP, {} BRAM",
        alloc.latency,
        alloc.latency / CLOCK_HZ * 1e3,
        alloc.resources.dsp,
        alloc.resources.bram
    );

    // 4. Quantize (HAWQ-style int8) and simulate one inference cycle-by-cycle.
    let weights = FloatWeights::random(&spec, 42);
    let mut rng = Rng::new(7);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);

    let events = profile.sample(3, &mut rng);
    let input = histogram2_norm(&events, profile.w, profile.h, 8.0);
    println!(
        "input: {} events → {} tokens ({:.1}% NZ)",
        events.len(),
        input.nnz(),
        input.nz_ratio() * 100.0
    );

    let cfg = HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
    let (logits, report) = simulate_inference(&qnet, &cfg, &input, 1_000_000_000).unwrap();
    println!(
        "simulated: {} cycles = {:.3} ms @187 MHz → class {}",
        report.cycles,
        report.cycles as f64 / CLOCK_HZ * 1e3,
        argmax(&logits)
    );
    let (name, st, _) = report.bottleneck().unwrap();
    println!("bottleneck module: {name} (busy {} cycles)", st.busy);

    // 5. Energy from the Table-1-calibrated power model.
    let pm = PowerModel::calibrated();
    println!(
        "estimated power {:.2} W, energy {:.3} mJ/inference",
        pm.watts(&alloc.resources),
        pm.energy_mj(&alloc.resources, report.cycles as f64, CLOCK_HZ)
    );
}
