//! The sharded serving runtime with a heterogeneous, cost-aware pool and
//! deadline-aware admission.
//!
//! ```text
//!                                              ┌ class "func" ┬ worker 0 ┐
//! event source → repr builder → ingress → router┤  sub-queue   └ worker 1 ┤→ merged
//!  (synth /       (histogram2)   queue   (cost- │             …           │  metrics +
//!   replay /                   (admission aware, └ class "sim" ── worker N ┘  predictions
//!   tail)                       + deadline  SLO
//!                               expiry)     shed)
//! ```
//!
//! The runtime is composed from **stage modules**, one file per pipeline
//! stage, glued by a lifecycle spine; stages communicate only through
//! the shared-state structs in [`state`]:
//!
//! | module      | owns |
//! |-------------|------|
//! | `ingress`   | the source pump and the repr builder + admission gate (quotas, expiry) |
//! | `router`    | cost/sticky/deadline routing over per-class sub-queues |
//! | `workers`   | the accelerator worker loop: batch drain, retire tokens, shadow mirroring |
//! | `scaler`    | the autoscale controller |
//! | `lifecycle` | spawn/join ordering, first-error funnel, metrics finalization |
//! | `state`     | the `pub(super)` context structs the stages share |
//!
//! The source is any [`EventSource`] — the synthetic camera, a paced
//! dataset replay, or a tailed capture file — producing requests with
//! **real arrival times**; an optional SLO turns each arrival into a
//! deadline (`arrival + slo`). Deadlines are enforced at the three
//! cheapest points, in order:
//!
//! 1. **ingress** — a request already past its deadline is dropped before
//!    the representation is even built (`deadline_ingress`),
//! 2. **router** — with several classes, a request is shed when even the
//!    best class's predicted completion time (service EWMA × backlog)
//!    cannot meet the deadline — the cheapest point to kill work that is
//!    doomed anyway (`deadline_router`),
//! 3. **worker pop** — a request that expired while queued is discarded
//!    inside the queue lock without occupying a batch slot or an
//!    accelerator visit (also `deadline_router`; in the routerless
//!    single-class path this *is* the scheduling point).
//!
//! Served requests are additionally scored against their deadline for the
//! SLO-attainment figure ([`Metrics::slo_attainment`]) — a late
//! completion counts as served but against the SLO.
//!
//! With more than one replica class, admitted requests flow through a
//! **router** that picks a class per request (with a single class,
//! workers drain the ingress directly — no router thread, no cost-model
//! overhead, and the original drop-oldest semantics): each class
//! advertises a cost model (an EWMA of observed service seconds per
//! event-count bucket, seeded from its first requests — see
//! [`CostModel`](super::metrics::CostModel)) and a batch affinity (the
//! micro-batch cap its workers drain; dense engines want large batches,
//! the cycle simulator wants batch 1). The router sends each request to
//! the class minimizing predicted completion time given current
//! per-class backlogs, via per-class sub-queues layered on the global
//! [`AdmissionQueue`](super::queue::AdmissionQueue).
//!
//! Admission control stays **global**: only the ingress queue drops
//! (`Block` exerts backpressure, `DropOldest` sheds stale load and counts
//! every drop); sub-queues always block, so a saturated class
//! back-pressures the router and the shedding decision is still made — and
//! accounted — at one place.
//!
//! Pool classes declared with a replica *range* (`ReplicaSpec::
//! with_max_replicas`, CLI `class=min..max`) are **autoscaled**: a
//! controller thread ([`AutoscaleConfig`]) samples per-class backlog and
//! windowed deadline-drop/busy counters, growing a pressured class by
//! building its next replica through the pool's retained factory and
//! spawning a worker for it mid-run, and shrinking an idle class by
//! retiring one worker (which drains its in-flight batch before its
//! thread exits). Every decision lands in `Metrics::scaling_events`.
//! Cost models can be **persisted** across runs ([`CostProfile`],
//! `ServerConfig::cost_profile`): a seeded class predicts — and the SLO
//! shed can act — from its very first request, with zero probe traffic.
//! Persisted snapshots are **aged** at seed time ([`CostSnapshot::
//! decayed`](super::metrics::CostSnapshot::decayed)): stale buckets (and,
//! much later, the global mean) are dropped rather than trusted.
//!
//! **Incremental (delta) inference + sticky routing.** Delta-capable
//! backends ([`Backend::supports_delta`]) cache each stream's previous
//! window and re-execute only the sites the new window changed
//! ([`crate::model::ExecPlan::execute_delta`] — bit-exact by
//! construction, with a full-recompute fallback above a dirty-fraction
//! threshold). To keep a stream's cache hot, the router first attempts a
//! **sticky** delivery through a bounded per-worker side queue owned by
//! the worker that served the stream last. Every miss — cold stream,
//! retired worker, full side queue — falls back to the cost-aware route,
//! and replicas of a class share one delta store, so a request landing
//! elsewhere is still served correctly: stickiness buys performance,
//! never correctness. Hits and every fallback reason are counted in
//! [`Metrics::delta`].
//!
//! **Multi-tenant front door.** Every [`super::ingest::SourcedRequest`]
//! carries a tenant id (file/synthetic sources map to the single default tenant; the
//! socket sources in [`super::net`] take it from the packet header).
//! Configuring more than one [`TenantConfig`] partitions the ingress
//! queue by weighted fair share: each tenant may occupy at most
//! `max(1, depth × weight / Σweights)` slots, and an arrival from a
//! tenant already at its quota is dropped — so a saturating tenant
//! exhausts only its own share and cannot starve the rest. Tenants may
//! also carry their own SLO, overriding the global `slo` for their
//! requests, and the merged metrics grow a per-tenant section
//! ([`TenantStats`](super::metrics::TenantStats)). With a single tenant
//! the quota gate is inert and admission semantics are bit-for-bit the
//! pre-tenant ones.
//!
//! **Multi-model fleet serving.** Replica classes carry a *model tag*
//! (`ReplicaSpec::for_model`; the CLI builds one class per `--model
//! name=arch` entry) and every request carries a model id — stamped
//! cyclically by [`MixSource`](super::ingest::MixSource) (`--model-mix`)
//! or taken from the ESNP v2 packet header. The router treats the tag as
//! a hard filter: a request is only ever offered to classes serving its
//! model, and cost-aware placement happens *within* that model's
//! classes. The merged metrics grow a per-model section
//! ([`ModelStats`](super::metrics::ModelStats)) whose books satisfy the
//! same conservation identity as the tenant books. Single-model runs get
//! one implicit entry under the default tag and behave bit-for-bit as
//! before fleets existed. Two fleet operations ride on this:
//!
//! - **Hot swap** — a model served through a
//!   [`Swappable`](super::backend::Swappable) handle can have its
//!   backend atomically replaced mid-run; in-flight requests finish on
//!   the backend they started on and no request is lost or torn.
//! - **Shadow conformance** — [`ShadowConfig`] mirrors a deterministic
//!   fraction of a model's *served* traffic to a candidate backend and
//!   compares predictions bit-exactly. Mirrored visits never count as
//!   served traffic; disagreements (a candidate error counts — a backend
//!   that cannot classify does not conform) are tallied per model, and
//!   [`ShadowCaptureConfig`] appends each disagreeing sample to a
//!   replayable `.esda` capture, capped, with overflow counted as
//!   capture drops.
//!
//! **Recoverable source rejects.** A *recoverable*
//! [`super::ingest::IngestError`] from the source (a corrupt or
//! out-of-geometry sample the reader skipped past — see
//! [`super::ingest`]) does not abort the run: the spine counts
//! it under `Metrics::ingest_rejects` (global and per-tenant) and keeps
//! pulling. Only fatal errors (latched byte-stream failures) end the
//! stream and surface as a [`PipelineError`].
//!
//! Worker panics and backend errors are caught and surfaced as
//! [`PipelineError`] — they never poison a join — and requests that were
//! admitted but not classified when the run aborts are counted as
//! `in_flight`.
//!
//! Entry points: [`run_server`] / [`run_pool`] (synthetic source built
//! from a dataset profile) and [`run_server_source`] /
//! [`run_pool_source`] (any [`EventSource`]).

mod ingress;
mod lifecycle;
mod router;
mod scaler;
mod state;
#[cfg(test)]
mod tests;
mod workers;

use super::backend::{Backend, ReplicaPool, DEFAULT_MODEL};
use super::ingest::{EventSource, SyntheticSource};
use super::metrics::{CostProfile, Metrics};
use super::queue::DropPolicy;
use crate::events::DatasetProfile;
use lifecycle::serve_classes;
use state::{BackendRef, ClassSlots};
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Serving-runtime configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of requests the synthetic source generates ([`run_server`] /
    /// [`run_pool`] only — an explicit [`EventSource`] owns its stream
    /// length).
    pub n_requests: usize,
    /// Source seed (fixes the request stream).
    pub seed: u64,
    /// Histogram clip value.
    pub clip: f32,
    /// Accelerator worker replicas ([`run_server`] only — a
    /// [`ReplicaPool`] carries its own per-class counts).
    pub workers: usize,
    /// Ingress queue depth (also the depth of each per-class sub-queue).
    pub queue_depth: usize,
    /// Admission control policy when the ingress queue saturates.
    pub drop_policy: DropPolicy,
    /// Max requests a worker drains from its queue per wakeup
    /// ([`run_server`] only — pool classes carry their own batch
    /// affinity; 1 = classic one-at-a-time). Workers never wait to fill a
    /// batch — they take what is already queued — so batching adds no
    /// latency when the system is unloaded and amortizes per-visit
    /// backend overhead when it is saturated.
    pub batch: usize,
    /// Per-request latency SLO: each request's deadline is its arrival
    /// plus this. `None` disables every deadline mechanism (the pre-SLO
    /// behavior, bit for bit).
    pub slo: Option<Duration>,
    /// Autoscaler controller configuration. `None` keeps every class at
    /// its configured replica count; `Some` runs the controller loop,
    /// which has an effect only on classes whose `max` exceeds their base
    /// count (see [`crate::coordinator::ReplicaSpec::with_max_replicas`]).
    pub autoscale: Option<AutoscaleConfig>,
    /// Cost-model seed: per-class snapshots from a previous run's
    /// profile. Seeded classes predict (and SLO-shed) from their first
    /// request instead of burning probes — and freshly scaled-up replicas
    /// join a class that already knows its costs.
    pub cost_profile: Option<CostProfile>,
    /// Tenant table for the multi-tenant front door (CLI `--tenant
    /// name=weight[,slo_ms]`). Empty = single implicit `default` tenant
    /// with weight 1 — the quota gate stays inert and admission behaves
    /// exactly as before tenancy existed. With several tenants, each
    /// request's `tenant` field indexes this table, admission enforces the
    /// weighted ingress quotas, and a tenant's own `slo` overrides the
    /// global one for its requests.
    pub tenants: Vec<TenantConfig>,
    /// Synthetic-source sliding-window overlap fraction ([`run_server`] /
    /// [`run_pool`] only — an explicit [`EventSource`] owns its own
    /// stream shape). 0 = independent windows (the classic source); > 0
    /// emits `streams` interleaved per-stream sliding windows, each
    /// window after a stream's first carrying over this fraction of its
    /// predecessor's events — the workload shape the delta/sticky path
    /// exists for.
    pub overlap: f64,
    /// Interleaved synthetic streams in overlap mode (ignored when
    /// `overlap` is 0).
    pub streams: usize,
    /// Shadow deployments (CLI `--shadow name=arch[@frac]`): each entry
    /// mirrors a fraction of one fleet model's served traffic to a
    /// candidate backend for bit-exact conformance checking. Entries
    /// naming a model no class serves are ignored (the CLI validates
    /// names up front). Empty = no shadowing, zero overhead.
    pub shadows: Vec<ShadowConfig>,
    /// Where shadow disagreements are captured (CLI `--shadow-capture
    /// path`). `None` = count disagreements but keep no samples. One
    /// capture file serves every shadowed model in the run.
    pub shadow_capture: Option<ShadowCaptureConfig>,
}

/// One tenant of the multi-tenant front door: a display name, a fair-share
/// weight (its slice of the ingress queue is `depth × weight / Σweights`,
/// floored, min 1), and an optional per-tenant SLO overriding
/// [`ServerConfig::slo`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantConfig {
    pub name: String,
    pub weight: usize,
    pub slo: Option<Duration>,
}

impl TenantConfig {
    pub fn new(name: impl Into<String>, weight: usize) -> TenantConfig {
        TenantConfig { name: name.into(), weight, slo: None }
    }

    pub fn with_slo(mut self, slo: Duration) -> TenantConfig {
        self.slo = Some(slo);
        self
    }
}

/// One shadow deployment: mirror `fraction` of `model`'s served traffic
/// to `candidate` and compare predictions bit-exactly (functional
/// backends are deterministic, so any divergence is a real conformance
/// break, not noise). The mirror is evaluated on the serving worker
/// *after* the primary result is recorded — shadow traffic never counts
/// as served and never delays the reply path's books.
#[derive(Clone)]
pub struct ShadowConfig {
    /// Fleet model name whose traffic is mirrored.
    pub model: String,
    /// Candidate backend receiving the mirrored requests.
    pub candidate: Arc<dyn Backend>,
    /// Fraction of the model's served requests to mirror, in (0, 1].
    pub fraction: f64,
}

impl fmt::Debug for ShadowConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShadowConfig")
            .field("model", &self.model)
            .field("candidate", &self.candidate.name())
            .field("fraction", &self.fraction)
            .finish()
    }
}

/// Shadow disagreement capture: every mirrored request whose candidate
/// prediction diverges from the primary's is appended — raw events plus
/// ground-truth label — to a replayable `.esda` file, up to
/// `max_samples`; drops past the cap are counted per model
/// (`shadow_capture_drops`), never silent.
#[derive(Debug, Clone)]
pub struct ShadowCaptureConfig {
    /// Capture file path (overwritten at run start).
    pub path: PathBuf,
    /// Cap on captured samples — bounds file growth under a
    /// badly-diverging candidate.
    pub max_samples: usize,
}

impl Default for ShadowCaptureConfig {
    fn default() -> Self {
        ShadowCaptureConfig { path: PathBuf::new(), max_samples: 256 }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_requests: 32,
            seed: 1,
            clip: 8.0,
            workers: 1,
            queue_depth: 4,
            drop_policy: DropPolicy::Block,
            batch: 1,
            slo: None,
            autoscale: None,
            cost_profile: None,
            tenants: Vec::new(),
            overlap: 0.0,
            streams: 1,
            shadows: Vec::new(),
            shadow_capture: None,
        }
    }
}

/// Autoscaler controller tuning. The controller samples every class each
/// `interval`: it reads the class backlog plus two
/// [`SlidingWindow`](super::metrics::SlidingWindow) counters (deadline
/// drops, accelerator-busy time) over `window`, and takes at most one
/// scaling step per class per tick:
///
/// - **up** (toward the class max) when deadline drops landed in the
///   window, or the backlog per active replica exceeds `high_backlog` —
///   both read "this class cannot keep up";
/// - **down** (toward the class min) when the class is idle: zero
///   backlog, no deadline drops in the window, and windowed utilization
///   below `low_util`. A retiring replica finishes the batch it holds
///   before its worker thread exits, and grown backends stay warm for
///   re-activation.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Controller tick (sampling + at most one step per class).
    pub interval: Duration,
    /// Sliding-window span the drop/busy counters are read over.
    pub window: Duration,
    /// Queued-plus-in-service requests per active replica above which the
    /// class scales up.
    pub high_backlog: f64,
    /// Windowed utilization below which an idle class scales down.
    pub low_util: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            interval: Duration::from_millis(20),
            window: Duration::from_millis(200),
            high_backlog: 2.0,
            low_util: 0.2,
        }
    }
}

/// One served request's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prediction {
    /// Ground-truth class of the synthetic recording.
    pub label: usize,
    /// Backend's predicted class.
    pub pred: usize,
    /// Worker replica that served it.
    pub worker: usize,
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServerResult {
    pub metrics: Metrics,
    /// Per-request outcomes, grouped by worker (use as a multiset: the
    /// worker interleaving is scheduling-dependent).
    pub predictions: Vec<Prediction>,
}

/// A serving run that aborted: the first backend error or worker panic,
/// plus how much work completed and how much was stranded.
#[derive(Debug, Clone)]
pub struct PipelineError {
    pub msg: String,
    /// Requests classified before the abort.
    pub completed: usize,
    /// Requests admitted but never classified.
    pub in_flight: usize,
    /// Requests evicted by admission control before the abort.
    pub dropped: usize,
}

impl fmt::Display for PipelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serving aborted after {} request(s) ({} in flight, {} dropped): {}",
            self.completed, self.in_flight, self.dropped, self.msg
        )
    }
}

impl std::error::Error for PipelineError {}

/// Run the serving pipeline to completion over `cfg.n_requests` synthetic
/// requests with a **homogeneous** pool: `cfg.workers` replicas sharing
/// one backend, a single class. With one class there is no routing
/// decision, so no router thread runs — workers drain the ingress queue
/// directly, exactly as the pre-pool runtime did (same admission and
/// drop-oldest semantics, no cost-model overhead).
pub fn run_server(
    profile: &DatasetProfile,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    run_server_source(Box::new(synthetic_source(profile, cfg)), backend, cfg)
}

/// The synthetic source every profile-based entry point shares:
/// independent windows classically, or interleaved per-stream sliding
/// windows when `cfg.overlap` asks for them. Public so fleet drivers can
/// build the same stream and wrap it (e.g. in
/// [`MixSource`](super::ingest::MixSource)) themselves.
pub fn synthetic_source(profile: &DatasetProfile, cfg: &ServerConfig) -> SyntheticSource {
    let source = SyntheticSource::new(profile.clone(), cfg.n_requests, cfg.seed);
    if cfg.overlap > 0.0 {
        source.with_overlap(cfg.overlap, cfg.streams)
    } else {
        source
    }
}

/// [`run_server`] over an arbitrary [`EventSource`] — replayed datasets,
/// tailed capture files, or anything implementing the trait. The source
/// owns the stream length; `cfg.n_requests` is ignored.
pub fn run_server_source(
    source: Box<dyn EventSource>,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(cfg.workers >= 1, "need at least one worker replica");
    let slots = vec![ClassSlots {
        name: backend.name().to_string(),
        model: DEFAULT_MODEL.to_string(),
        batch: cfg.batch.max(1),
        backends: vec![BackendRef::Borrowed(backend); cfg.workers],
        max: cfg.workers,
        grow: None,
    }];
    serve_classes(source, slots, cfg)
}

/// Run the serving pipeline over a **heterogeneous** [`ReplicaPool`]: each
/// class brings its own replica count, per-replica backend instances, and
/// batch affinity; the router spreads admitted requests across classes by
/// predicted completion time. `cfg.workers` and `cfg.batch` are ignored —
/// the pool defines the shape.
pub fn run_pool(
    profile: &DatasetProfile,
    pool: &ReplicaPool,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    run_pool_source(Box::new(synthetic_source(profile, cfg)), pool, cfg)
}

/// [`run_pool`] over an arbitrary [`EventSource`].
pub fn run_pool_source(
    source: Box<dyn EventSource>,
    pool: &ReplicaPool,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!pool.classes.is_empty(), "pool needs at least one replica class");
    let slots: Vec<ClassSlots<'_>> = pool
        .classes
        .iter()
        .map(|c| ClassSlots {
            name: c.name.clone(),
            model: c.model.clone(),
            batch: c.batch,
            backends: c.replicas.iter().map(|b| BackendRef::Shared(Arc::clone(b))).collect(),
            max: c.max,
            grow: Some(c),
        })
        .collect();
    serve_classes(source, slots, cfg)
}
