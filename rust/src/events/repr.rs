//! 2D representations constructed from event windows (paper §2.1/§4.1):
//! the 2-channel **event histogram** (positive/negative counts — the
//! representation used in all the paper's experiments) and a **time
//! surface** alternative (exponential decay of most-recent timestamps) to
//! demonstrate the interface is representation-agnostic.

use super::aer::Event;
use crate::sparse::{SparseMap, Token};

/// 2-channel event histogram: `feat = [#ON, #OFF]` per pixel, over the
/// given events. Produces a [`SparseMap<f32>`] with tokens at every pixel
/// that received at least one event.
pub fn histogram2(events: &[Event], w: usize, h: usize) -> SparseMap<f32> {
    let mut counts = vec![[0f32; 2]; w * h];
    let mut touched: Vec<u32> = Vec::with_capacity(events.len());
    for e in events {
        let idx = e.y as usize * w + e.x as usize;
        if counts[idx][0] == 0.0 && counts[idx][1] == 0.0 {
            touched.push(idx as u32);
        }
        counts[idx][if e.polarity { 0 } else { 1 }] += 1.0;
    }
    touched.sort_unstable();
    let mut m = SparseMap::empty(w, h, 2);
    for &idx in &touched {
        let (x, y) = ((idx as usize % w) as u16, (idx as usize / w) as u16);
        m.push(Token::new(x, y), &counts[idx as usize]);
    }
    m
}

/// Histogram clipped at `clip` counts and scaled to [0, 1] — the
/// normalization used before quantization in the training path.
pub fn histogram2_norm(events: &[Event], w: usize, h: usize, clip: f32) -> SparseMap<f32> {
    let mut m = histogram2(events, w, h);
    for f in m.feats.iter_mut() {
        *f = (*f).min(clip) / clip;
    }
    m
}

/// 2-channel exponential time surface: `feat[p] = exp(-(t_end - t_last,p)/τ)`
/// at each pixel's most recent event of polarity `p`.
pub fn time_surface(events: &[Event], w: usize, h: usize, tau_us: f32) -> SparseMap<f32> {
    if events.is_empty() {
        return SparseMap::empty(w, h, 2);
    }
    // lint:allow(panic): non-empty guaranteed by the early return above
    let t_end = events.last().unwrap().t_us as f32;
    let mut last = vec![[f32::NEG_INFINITY; 2]; w * h];
    let mut touched: Vec<u32> = Vec::new();
    for e in events {
        let idx = e.y as usize * w + e.x as usize;
        if last[idx][0] == f32::NEG_INFINITY && last[idx][1] == f32::NEG_INFINITY {
            touched.push(idx as u32);
        }
        last[idx][if e.polarity { 0 } else { 1 }] = e.t_us as f32;
    }
    touched.sort_unstable();
    let mut m = SparseMap::empty(w, h, 2);
    for &idx in &touched {
        let (x, y) = ((idx as usize % w) as u16, (idx as usize / w) as u16);
        let f = |t: f32| {
            if t == f32::NEG_INFINITY {
                0.0
            } else {
                (-(t_end - t) / tau_us).exp()
            }
        };
        m.push(
            Token::new(x, y),
            &[f(last[idx as usize][0]), f(last[idx as usize][1])],
        );
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(x: u16, y: u16, p: bool, t: u32) -> Event {
        Event { t_us: t, x, y, polarity: p }
    }

    #[test]
    fn histogram_counts_polarities() {
        let es = vec![ev(1, 1, true, 0), ev(1, 1, true, 5), ev(1, 1, false, 7), ev(3, 2, false, 9)];
        let m = histogram2(&es, 8, 8);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 2);
        let i = m.find(1, 1).unwrap();
        assert_eq!(m.feat(i), &[2.0, 1.0]);
        let j = m.find(3, 2).unwrap();
        assert_eq!(m.feat(j), &[0.0, 1.0]);
    }

    #[test]
    fn histogram_tokens_in_ravel_order() {
        // Events arrive in time order, not spatial order.
        let es = vec![ev(7, 7, true, 0), ev(0, 0, true, 1), ev(3, 4, false, 2)];
        let m = histogram2(&es, 8, 8);
        m.validate().unwrap();
        assert_eq!(m.tokens[0], Token::new(0, 0));
        assert_eq!(m.tokens[2], Token::new(7, 7));
    }

    #[test]
    fn norm_clips_and_scales() {
        let es: Vec<Event> = (0..10).map(|i| ev(2, 2, true, i)).collect();
        let m = histogram2_norm(&es, 4, 4, 4.0);
        let i = m.find(2, 2).unwrap();
        assert_eq!(m.feat(i), &[1.0, 0.0]); // 10 clipped to 4, /4
    }

    #[test]
    fn time_surface_decays() {
        let es = vec![ev(0, 0, true, 0), ev(1, 0, true, 1000)];
        let m = time_surface(&es, 4, 4, 500.0);
        let early = m.feat(m.find(0, 0).unwrap())[0];
        let late = m.feat(m.find(1, 0).unwrap())[0];
        assert!(late > early);
        assert!((late - 1.0).abs() < 1e-6);
        assert!((early - (-2.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn empty_events_empty_map() {
        let m = histogram2(&[], 4, 4);
        assert_eq!(m.nnz(), 0);
        let ts = time_surface(&[], 4, 4, 100.0);
        assert_eq!(ts.nnz(), 0);
    }
}
