//! Minimal JSON value model, recursive-descent parser, and writer.
//!
//! Used for the artifact metadata files (`artifacts/*.json`) written by the
//! python compile path (model/layer shapes, quantization scales, dataset
//! profiles, golden-vector manifest). Only what the interchange needs:
//! objects, arrays, strings, f64 numbers, bools, null. No serde available
//! offline, hence in-tree.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are f64 (all our metadata fits exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` chained for required fields, with a readable error.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// `Some(v)` ⇒ number, `None` ⇒ null — the emit side of optional
    /// numeric fields (e.g. unobserved cost-model slots).
    pub fn opt_num(v: Option<f64>) -> Json {
        match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        }
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write(self, f)
    }
}

/// Largest magnitude where every integral f64 is exactly representable and
/// the `as i64` conversion is lossless (2^53). Integral values beyond it
/// take the float path — `i64` casts would saturate/mangle them.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn write(j: &Json, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match j {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/Infinity literal; `null` is the
                // conventional stand-in (what `JSON.stringify` emits) and
                // keeps reports loadable by strict parsers.
                write!(f, "null")
            } else if n.fract() == 0.0 && n.abs() <= MAX_EXACT_INT {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Json::Str(s) => write_str(s, f),
        Json::Arr(a) => {
            write!(f, "[")?;
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write(v, f)?;
            }
            write!(f, "]")
        }
        Json::Obj(o) => {
            write!(f, "{{")?;
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_str(k, f)?;
                write!(f, ":")?;
                write(v, f)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_str(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Errors carry byte offsets.
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn parse_basic() {
        let j = parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e1}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-25.0));
        let b = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[0], Json::Bool(true));
        assert_eq!(b[1], Json::Null);
        assert_eq!(b[2].as_str(), Some("x\n"));
    }

    #[test]
    fn opt_num_maps_none_to_null() {
        assert_eq!(Json::opt_num(None), Json::Null);
        assert_eq!(Json::opt_num(Some(2.5)), Json::Num(2.5));
        let doc = Json::Arr(vec![Json::opt_num(None), Json::opt_num(Some(1.0))]).to_string();
        assert_eq!(doc, "[null,1]");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    fn arbitrary_json(g: &mut Gen, depth: usize) -> Json {
        let choice = if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.i64(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => {
                let n = g.len(0, 12);
                Json::Str(
                    (0..n)
                        .map(|_| char::from_u32(g.u64(32..=126) as u32).unwrap())
                        .collect(),
                )
            }
            4 => {
                let n = g.len(0, 4);
                Json::Arr((0..n).map(|_| arbitrary_json(g, depth - 1)).collect())
            }
            _ => {
                let n = g.len(0, 4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), arbitrary_json(g, depth - 1)))
                        .collect(),
                )
            }
        }
    }

    /// Non-finite numbers must serialize to valid JSON (`null`), not the
    /// literal `NaN`/`inf` that breaks any downstream `json.load` — the
    /// shape an empty-sample `PercentileReport::default()` produces.
    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(v).to_string();
            assert_eq!(s, "null", "{v} must not leak into JSON");
            assert_eq!(parse(&s).unwrap(), Json::Null, "null must round-trip");
        }
        // A default (empty-sample) percentile report is all-NaN with n = 0:
        // exactly what the serving report writes for an idle worker.
        let p = crate::coordinator::PercentileReport::default();
        let doc = Json::obj(vec![
            ("n", Json::Num(p.n as f64)),
            ("mean", Json::Num(p.mean)),
            ("p50", Json::Num(p.p50)),
            ("p95", Json::Num(p.p95)),
            ("p99", Json::Num(p.p99)),
            ("max", Json::Num(p.max)),
        ]);
        let s = doc.to_string();
        let back = parse(&s).unwrap_or_else(|e| panic!("invalid JSON emitted: {e}\ndoc: {s}"));
        assert_eq!(back.get("n").unwrap().as_f64(), Some(0.0));
        for k in ["mean", "p50", "p95", "p99", "max"] {
            assert_eq!(back.get(k), Some(&Json::Null), "{k} in {s}");
        }
    }

    /// Finite integral values beyond 2^53 must not go through the `as i64`
    /// fast path (saturation would silently mangle them): they take the
    /// float formatter and round-trip exactly.
    #[test]
    fn huge_integral_numbers_round_trip() {
        for v in [
            super::MAX_EXACT_INT,
            -super::MAX_EXACT_INT,
            super::MAX_EXACT_INT * 4.0,
            i64::MAX as f64 * 8.0, // far above any i64
            1e300,
            -1e300,
        ] {
            let s = Json::Num(v).to_string();
            let back = parse(&s).unwrap_or_else(|e| panic!("parse failed: {e}\ndoc: {s}"));
            assert_eq!(back.as_f64(), Some(v), "doc: {s}");
        }
        // The exact-boundary value still uses the compact integer form.
        assert_eq!(Json::Num(super::MAX_EXACT_INT).to_string(), "9007199254740992");
    }

    #[test]
    fn roundtrip_property() {
        check("json write→parse roundtrip", 256, |g| {
            let j = arbitrary_json(g, 3);
            let s = j.to_string();
            let back = parse(&s).unwrap_or_else(|e| panic!("parse failed: {e}\ndoc: {s}"));
            assert_eq!(j, back, "doc: {s}");
        });
    }
}
