//! The sharded serving runtime with a heterogeneous, cost-aware pool and
//! deadline-aware admission.
//!
//! ```text
//!                                              ┌ class "func" ┬ worker 0 ┐
//! event source → repr builder → ingress → router┤  sub-queue   └ worker 1 ┤→ merged
//!  (synth /       (histogram2)   queue   (cost- │             …           │  metrics +
//!   replay /                   (admission aware, └ class "sim" ── worker N ┘  predictions
//!   tail)                       + deadline  SLO
//!                               expiry)     shed)
//! ```
//!
//! The source is any [`EventSource`] — the synthetic camera, a paced
//! dataset replay, or a tailed capture file — producing requests with
//! **real arrival times**; an optional SLO turns each arrival into a
//! deadline (`arrival + slo`). Deadlines are enforced at the three
//! cheapest points, in order:
//!
//! 1. **ingress** — a request already past its deadline is dropped before
//!    the representation is even built (`deadline_ingress`),
//! 2. **router** — with several classes, a request is shed when even the
//!    best class's predicted completion time (service EWMA × backlog)
//!    cannot meet the deadline — the cheapest point to kill work that is
//!    doomed anyway (`deadline_router`),
//! 3. **worker pop** — a request that expired while queued is discarded
//!    inside the queue lock without occupying a batch slot or an
//!    accelerator visit (also `deadline_router`; in the routerless
//!    single-class path this *is* the scheduling point).
//!
//! Served requests are additionally scored against their deadline for the
//! SLO-attainment figure ([`Metrics::slo_attainment`]) — a late
//! completion counts as served but against the SLO.
//!
//! With more than one replica class, admitted requests flow through a
//! **router** that picks a class per request (with a single class,
//! workers drain the ingress directly — no router thread, no cost-model
//! overhead, and the original drop-oldest semantics): each class
//! advertises a cost model (an EWMA of observed service seconds per
//! event-count bucket, seeded from its first requests — see
//! [`CostModel`]) and a batch affinity (the micro-batch cap its workers
//! drain; dense engines want large batches, the cycle simulator wants
//! batch 1). The router sends each request to the class minimizing
//! predicted completion time given current per-class backlogs, via
//! per-class sub-queues layered on the global [`AdmissionQueue`].
//!
//! Admission control stays **global**: only the ingress queue drops
//! (`Block` exerts backpressure, `DropOldest` sheds stale load and counts
//! every drop); sub-queues always block, so a saturated class
//! back-pressures the router and the shedding decision is still made — and
//! accounted — at one place.
//!
//! Pool classes declared with a replica *range* (`ReplicaSpec::
//! with_max_replicas`, CLI `class=min..max`) are **autoscaled**: a
//! controller thread ([`AutoscaleConfig`]) samples per-class backlog and
//! windowed deadline-drop/busy counters, growing a pressured class by
//! building its next replica through the pool's retained factory and
//! spawning a worker for it mid-run, and shrinking an idle class by
//! retiring one worker (which drains its in-flight batch before its
//! thread exits). Every decision lands in `Metrics::scaling_events`.
//! Cost models can be **persisted** across runs ([`CostProfile`],
//! `ServerConfig::cost_profile`): a seeded class predicts — and the SLO
//! shed can act — from its very first request, with zero probe traffic.
//! Persisted snapshots are **aged** at seed time ([`CostSnapshot::
//! decayed`](super::metrics::CostSnapshot::decayed)): stale buckets (and,
//! much later, the global mean) are dropped rather than trusted.
//!
//! **Incremental (delta) inference + sticky routing.** Delta-capable
//! backends ([`Backend::supports_delta`]) cache each stream's previous
//! window and re-execute only the sites the new window changed
//! ([`crate::model::ExecPlan::execute_delta`] — bit-exact by
//! construction, with a full-recompute fallback above a dirty-fraction
//! threshold). To keep a stream's cache hot, the router first attempts a
//! **sticky** delivery through a bounded per-worker side queue owned by
//! the worker that served the stream last. Every miss — cold stream,
//! retired worker, full side queue — falls back to the cost-aware route,
//! and replicas of a class share one delta store, so a request landing
//! elsewhere is still served correctly: stickiness buys performance,
//! never correctness. Hits and every fallback reason are counted in
//! [`Metrics::delta`].
//!
//! **Multi-tenant front door.** Every [`super::ingest::SourcedRequest`]
//! carries a tenant id (file/synthetic sources map to the single default tenant; the
//! socket sources in [`super::net`] take it from the packet header).
//! Configuring more than one [`TenantConfig`] partitions the ingress
//! queue by weighted fair share: each tenant may occupy at most
//! `max(1, depth × weight / Σweights)` slots, and an arrival from a
//! tenant already at its quota is dropped — so a saturating tenant
//! exhausts only its own share and cannot starve the rest. Tenants may
//! also carry their own SLO, overriding the global `slo` for their
//! requests, and the merged metrics grow a per-tenant section
//! ([`TenantStats`]). With a single tenant the quota gate is inert and
//! admission semantics are bit-for-bit the pre-tenant ones.
//!
//! **Recoverable source rejects.** A *recoverable*
//! [`super::ingest::IngestError`] from the source (a corrupt or
//! out-of-geometry sample the reader skipped past — see
//! [`super::ingest`]) does not abort the run: the spine counts
//! it under `Metrics::ingest_rejects` (global and per-tenant) and keeps
//! pulling. Only fatal errors (latched byte-stream failures) end the
//! stream and surface as a [`PipelineError`].
//!
//! Worker panics and backend errors are caught and surfaced as
//! [`PipelineError`] — they never poison a join — and requests that were
//! admitted but not classified when the run aborts are counted as
//! `in_flight`.
//!
//! Entry points: [`run_server`] / [`run_pool`] (synthetic source built
//! from a dataset profile) and [`run_server_source`] /
//! [`run_pool_source`] (any [`EventSource`]).

use super::backend::{Backend, DeltaStatus, PoolClass, ReplicaPool};
use super::ingest::{EventSource, SyntheticSource};
use super::metrics::{
    ClassStats, CostModel, CostProfile, DeltaMetrics, Metrics, PercentileReport, RequestTiming,
    ScalingEvent, SlidingWindow, TenantStats, WorkerStats,
};
use super::queue::{AdmissionQueue, DropPolicy, TryPushError};
use crate::events::{repr::histogram2_norm, DatasetProfile};
use crate::model::FullReason;
use crate::sparse::SparseMap;
use crate::util::panic_message;
use std::collections::HashMap;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Serving-runtime configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of requests the synthetic source generates ([`run_server`] /
    /// [`run_pool`] only — an explicit [`EventSource`] owns its stream
    /// length).
    pub n_requests: usize,
    /// Source seed (fixes the request stream).
    pub seed: u64,
    /// Histogram clip value.
    pub clip: f32,
    /// Accelerator worker replicas ([`run_server`] only — a
    /// [`ReplicaPool`] carries its own per-class counts).
    pub workers: usize,
    /// Ingress queue depth (also the depth of each per-class sub-queue).
    pub queue_depth: usize,
    /// Admission control policy when the ingress queue saturates.
    pub drop_policy: DropPolicy,
    /// Max requests a worker drains from its queue per wakeup
    /// ([`run_server`] only — pool classes carry their own batch
    /// affinity; 1 = classic one-at-a-time). Workers never wait to fill a
    /// batch — they take what is already queued — so batching adds no
    /// latency when the system is unloaded and amortizes per-visit
    /// backend overhead when it is saturated.
    pub batch: usize,
    /// Per-request latency SLO: each request's deadline is its arrival
    /// plus this. `None` disables every deadline mechanism (the pre-SLO
    /// behavior, bit for bit).
    pub slo: Option<Duration>,
    /// Autoscaler controller configuration. `None` keeps every class at
    /// its configured replica count; `Some` runs the controller loop,
    /// which has an effect only on classes whose `max` exceeds their base
    /// count (see [`crate::coordinator::ReplicaSpec::with_max_replicas`]).
    pub autoscale: Option<AutoscaleConfig>,
    /// Cost-model seed: per-class snapshots from a previous run's
    /// profile. Seeded classes predict (and SLO-shed) from their first
    /// request instead of burning probes — and freshly scaled-up replicas
    /// join a class that already knows its costs.
    pub cost_profile: Option<CostProfile>,
    /// Tenant table for the multi-tenant front door (CLI `--tenant
    /// name=weight[,slo_ms]`). Empty = single implicit `default` tenant
    /// with weight 1 — the quota gate stays inert and admission behaves
    /// exactly as before tenancy existed. With several tenants, each
    /// request's `tenant` field indexes this table, admission enforces the
    /// weighted ingress quotas, and a tenant's own `slo` overrides the
    /// global one for its requests.
    pub tenants: Vec<TenantConfig>,
    /// Synthetic-source sliding-window overlap fraction ([`run_server`] /
    /// [`run_pool`] only — an explicit [`EventSource`] owns its own
    /// stream shape). 0 = independent windows (the classic source); > 0
    /// emits `streams` interleaved per-stream sliding windows, each
    /// window after a stream's first carrying over this fraction of its
    /// predecessor's events — the workload shape the delta/sticky path
    /// exists for.
    pub overlap: f64,
    /// Interleaved synthetic streams in overlap mode (ignored when
    /// `overlap` is 0).
    pub streams: usize,
}

/// One tenant of the multi-tenant front door: a display name, a fair-share
/// weight (its slice of the ingress queue is `depth × weight / Σweights`,
/// floored, min 1), and an optional per-tenant SLO overriding
/// [`ServerConfig::slo`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantConfig {
    pub name: String,
    pub weight: usize,
    pub slo: Option<Duration>,
}

impl TenantConfig {
    pub fn new(name: impl Into<String>, weight: usize) -> TenantConfig {
        TenantConfig { name: name.into(), weight, slo: None }
    }

    pub fn with_slo(mut self, slo: Duration) -> TenantConfig {
        self.slo = Some(slo);
        self
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_requests: 32,
            seed: 1,
            clip: 8.0,
            workers: 1,
            queue_depth: 4,
            drop_policy: DropPolicy::Block,
            batch: 1,
            slo: None,
            autoscale: None,
            cost_profile: None,
            tenants: Vec::new(),
            overlap: 0.0,
            streams: 1,
        }
    }
}

/// Autoscaler controller tuning. The controller samples every class each
/// `interval`: it reads the class backlog plus two [`SlidingWindow`]
/// counters (deadline drops, accelerator-busy time) over `window`, and
/// takes at most one scaling step per class per tick:
///
/// - **up** (toward the class max) when deadline drops landed in the
///   window, or the backlog per active replica exceeds `high_backlog` —
///   both read "this class cannot keep up";
/// - **down** (toward the class min) when the class is idle: zero
///   backlog, no deadline drops in the window, and windowed utilization
///   below `low_util`. A retiring replica finishes the batch it holds
///   before its worker thread exits, and grown backends stay warm for
///   re-activation.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Controller tick (sampling + at most one step per class).
    pub interval: Duration,
    /// Sliding-window span the drop/busy counters are read over.
    pub window: Duration,
    /// Queued-plus-in-service requests per active replica above which the
    /// class scales up.
    pub high_backlog: f64,
    /// Windowed utilization below which an idle class scales down.
    pub low_util: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            interval: Duration::from_millis(20),
            window: Duration::from_millis(200),
            high_backlog: 2.0,
            low_util: 0.2,
        }
    }
}

/// One served request's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prediction {
    /// Ground-truth class of the synthetic recording.
    pub label: usize,
    /// Backend's predicted class.
    pub pred: usize,
    /// Worker replica that served it.
    pub worker: usize,
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServerResult {
    pub metrics: Metrics,
    /// Per-request outcomes, grouped by worker (use as a multiset: the
    /// worker interleaving is scheduling-dependent).
    pub predictions: Vec<Prediction>,
}

/// A serving run that aborted: the first backend error or worker panic,
/// plus how much work completed and how much was stranded.
#[derive(Debug, Clone)]
pub struct PipelineError {
    pub msg: String,
    /// Requests classified before the abort.
    pub completed: usize,
    /// Requests admitted but never classified.
    pub in_flight: usize,
    /// Requests evicted by admission control before the abort.
    pub dropped: usize,
}

impl fmt::Display for PipelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serving aborted after {} request(s) ({} in flight, {} dropped): {}",
            self.completed, self.in_flight, self.dropped, self.msg
        )
    }
}

impl std::error::Error for PipelineError {}

/// An admitted request: built by the repr stage, (optionally) routed, then
/// served from a queue. With a single replica class there is no router and
/// workers drain the ingress directly; with several, the router fills in
/// `predicted_s` and moves it to a class sub-queue.
struct Routed {
    label: usize,
    /// Index into the run's tenant table (0 for single-tenant runs).
    tenant: usize,
    map: SparseMap<f32>,
    /// When the request was born at its source — end-to-end latency and
    /// the deadline are measured from here.
    arrival: Instant,
    /// `arrival + slo` when an SLO is configured; a request past this is
    /// worthless and every stage may discard it.
    deadline: Option<Instant>,
    /// Event-count bucket ([`CostModel::bucket_of`]), computed once at
    /// admission.
    bucket: usize,
    /// Service seconds the router predicted for this request (NaN when no
    /// router ran or the class was unseeded at routing time).
    predicted_s: f64,
    /// Per-stream identity for delta inference (see
    /// [`super::ingest::SourcedRequest::stream`]); `None` = no stream.
    stream: Option<u64>,
    /// True when the router delivered this request over the sticky fast
    /// path: `predicted_s` stays NaN by design, so the per-class rollup
    /// must not count it as an unseeded probe.
    sticky: bool,
}

impl Routed {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|dl| now >= dl)
    }
}

/// A worker's handle on its backend: borrowed from the caller (the
/// homogeneous path shares one `&dyn Backend` across replicas) or shared
/// ownership of a pool replica (`Arc`, so the autoscaler can hand clones
/// to worker threads it spawns mid-run).
#[derive(Clone)]
enum BackendRef<'a> {
    Borrowed(&'a dyn Backend),
    Shared(Arc<dyn Backend>),
}

impl<'a> BackendRef<'a> {
    fn get(&self) -> &dyn Backend {
        match self {
            BackendRef::Borrowed(b) => *b,
            BackendRef::Shared(a) => a.as_ref(),
        }
    }
}

/// One replica class's scheduling inputs: display name, batch affinity,
/// one backend per base worker replica, and (for scalable pool classes)
/// the growth bound plus factory access.
struct ClassSlots<'a> {
    name: String,
    batch: usize,
    backends: Vec<BackendRef<'a>>,
    /// Upper replica bound (== `backends.len()` when not scalable).
    max: usize,
    /// Factory access for on-demand replicas past the base count (pool
    /// classes only; the homogeneous path cannot grow).
    grow: Option<&'a PoolClass>,
}

/// A replica class's live runtime state.
struct ClassCtx<'a> {
    name: String,
    batch: usize,
    /// Instantiated replica backends, indexed by slot. Grows monotonically
    /// (scale-up instantiates lazily, scale-down keeps the warm backend
    /// for re-activation); only slots `< active` serve.
    slots: Mutex<Vec<BackendRef<'a>>>,
    /// Active replica count — the scheduling truth the router divides
    /// backlogs by and workers compare their slot index against. Always
    /// within `[min, max]`.
    active: AtomicUsize,
    /// Highest `active` value seen (for the report).
    peak: AtomicUsize,
    /// Lower replica bound: the controller never takes `active` below it,
    /// and retire tokens are only minted on scale-down, so the class
    /// always keeps at least `min` serving workers.
    min: usize,
    /// Upper replica bound the autoscaler may grow to.
    max: usize,
    /// Factory access for slots past the eagerly-built base replicas.
    grow: Option<&'a PoolClass>,
    /// Pending retire tokens: each scale-down step deposits one, and
    /// exactly one worker of the class claims it and exits after draining
    /// its in-flight batch. Token-based (rather than slot-indexed)
    /// retirement makes re-growth race-free: there is never a moment
    /// where a re-activated slot is served twice.
    retire: AtomicUsize,
    /// Per-class sub-queue (always blocking — drops are global-only).
    queue: AdmissionQueue<Routed>,
    /// Requests routed here and not yet classified (queued + in service).
    backlog: AtomicUsize,
    /// Observed-service-time predictor the router consults.
    cost: CostModel,
    /// Deadline sheds attributed to this class: router-predicted
    /// infeasibility plus pop-time expiries.
    deadline_drops: AtomicUsize,
    /// Cumulative accelerator-busy microseconds across the class's
    /// replicas, updated per visit — the autoscaler's windowed
    /// utilization input.
    busy_us: AtomicU64,
}

/// What the router decided for one request.
struct RouteDecision {
    /// Chosen class index.
    class: usize,
    /// Per-request service-seconds prediction the decision was based on
    /// (NaN for a probe), recorded so the caller logs exactly what the
    /// router saw — not a re-query that a concurrent `observe` may have
    /// seeded in the meantime.
    predicted_s: f64,
    /// Predicted *completion* seconds including queueing ahead (NaN when
    /// unknown — a probe, or every class unseeded). The deadline shed
    /// compares this against the request's remaining budget.
    completion_s: f64,
}

/// Pick the class minimizing predicted completion time for a request in
/// `bucket`, given current backlogs. Unseeded classes are probed eagerly
/// (their real cost is unknown and must be learned) but only up to one
/// outstanding request per replica while any alternative — seeded, or
/// under its probe cap — exists. In the cold-start corner where *every*
/// class is unseeded and probe-capped, requests spread by per-replica
/// backlog (and each sub-queue's bounded depth caps how much can ever
/// stack behind one slow class). Ties break toward the smaller
/// per-replica backlog.
fn route(classes: &[ClassCtx<'_>], bucket: usize) -> RouteDecision {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    let mut best_load = f64::INFINITY;
    let mut best_pred = f64::NAN;
    for (i, c) in classes.iter().enumerate() {
        let backlog = c.backlog.load(Ordering::SeqCst);
        // Active (not instantiated) replicas: the autoscaler moves this,
        // and routing decisions must follow the live serving capacity.
        let replicas = c.active.load(Ordering::SeqCst).max(1);
        // Queued + in-service requests per replica: the tie-break key, so
        // a 1-replica class doesn't absorb as much as a 4-replica one.
        let load = backlog as f64 / replicas as f64;
        let pred = c.cost.predict(bucket);
        let cost = match pred {
            // Predicted completion ≈ own service time scaled by how many
            // requests already wait ahead of it per replica.
            Some(s) => s * (load + 1.0),
            None if backlog < replicas => f64::NEG_INFINITY,
            None => f64::INFINITY,
        };
        if cost < best_cost || (cost == best_cost && load < best_load) {
            best = i;
            best_cost = cost;
            best_load = load;
            best_pred = pred.unwrap_or(f64::NAN);
        }
    }
    RouteDecision {
        class: best,
        predicted_s: best_pred,
        completion_s: if best_cost.is_finite() { best_cost } else { f64::NAN },
    }
}

/// One classified request as a worker recorded it.
struct ServedRecord {
    label: usize,
    tenant: usize,
    pred: usize,
    timing: RequestTiming,
    predicted_s: f64,
    /// Whether the request completed within its deadline (`None`: no
    /// deadline was set).
    met_deadline: Option<bool>,
    /// Delivered via the sticky fast path (excluded from the unseeded
    /// probe count — its NaN prediction is by design, not ignorance).
    sticky: bool,
}

/// Per-request metadata a worker holds across the backend visit.
struct Meta {
    label: usize,
    tenant: usize,
    arrival: Instant,
    bucket: usize,
    predicted_s: f64,
    deadline: Option<Instant>,
    sticky: bool,
}

/// Sticky (cache-affinity) routing state — present only when a router
/// runs AND some class backend supports delta inference. `table`
/// remembers which worker holds each stream's delta cache warm; `sides`
/// holds one bounded side queue per delta-capable worker. Stickiness is a
/// pure performance hint: every miss (cold stream, retired worker, full
/// side queue) falls back to cost-aware routing, and replicas of a class
/// share one delta store, so a request that lands elsewhere is still
/// served correctly — it just pays cache traffic it could have avoided.
struct StickyCtx {
    /// stream id → worker that served the stream last.
    table: Mutex<HashMap<u64, usize>>,
    /// Live sticky targets: `(worker id, class index, side queue)`. A
    /// retiring worker deregisters itself before draining its remainder.
    sides: Mutex<Vec<(usize, usize, Arc<AdmissionQueue<Routed>>)>>,
    hits: AtomicUsize,
    miss_cold: AtomicUsize,
    miss_retired: AtomicUsize,
    miss_capacity: AtomicUsize,
}

impl StickyCtx {
    fn new() -> StickyCtx {
        StickyCtx {
            table: Mutex::new(HashMap::new()),
            sides: Mutex::new(Vec::new()),
            hits: AtomicUsize::new(0),
            miss_cold: AtomicUsize::new(0),
            miss_retired: AtomicUsize::new(0),
            miss_capacity: AtomicUsize::new(0),
        }
    }

    /// Advertise worker `wid` (serving class `ci`) as a sticky target.
    fn enroll(&self, wid: usize, ci: usize, side: &Arc<AdmissionQueue<Routed>>) {
        self.sides.lock().unwrap().push((wid, ci, Arc::clone(side)));
    }

    /// Remember where a stream's delta cache now lives.
    fn remember(&self, stream: u64, wid: usize) {
        self.table.lock().unwrap().insert(stream, wid);
    }

    /// Withdraw a retiring worker from the target list. The worker closes
    /// its side queue *after* this call, so a concurrently in-flight
    /// sticky push bounces back ([`TryPushError::Closed`]) to the router,
    /// which cost-routes the request to a live worker instead.
    fn deregister(&self, wid: usize) {
        self.sides.lock().unwrap().retain(|(w, _, _)| *w != wid);
    }

    /// Try to deliver `req` to the worker holding its stream's cache.
    /// `None`: delivered, books updated. `Some`: handed back for
    /// cost-aware routing, with the miss reason counted.
    fn try_route(&self, mut req: Routed, classes: &[ClassCtx<'_>]) -> Option<Routed> {
        let Some(stream) = req.stream else {
            return Some(req);
        };
        let Some(wid) = self.table.lock().unwrap().get(&stream).copied() else {
            self.miss_cold.fetch_add(1, Ordering::SeqCst);
            return Some(req);
        };
        let entry = self
            .sides
            .lock()
            .unwrap()
            .iter()
            .find(|(w, _, _)| *w == wid)
            .map(|(_, ci, q)| (*ci, Arc::clone(q)));
        let Some((ci, side)) = entry else {
            // The worker retired since it last served this stream.
            self.table.lock().unwrap().remove(&stream);
            self.miss_retired.fetch_add(1, Ordering::SeqCst);
            return Some(req);
        };
        // A sticky delivery is not a cost-model prediction: NaN keeps it
        // out of the router-accuracy books, and the `sticky` flag keeps
        // it out of the unseeded-probe count.
        req.sticky = true;
        req.predicted_s = f64::NAN;
        // Backlog up *before* the push: the worker's pop decrements, and
        // the counter must never dip below zero in between.
        classes[ci].backlog.fetch_add(1, Ordering::SeqCst);
        match side.try_push(req) {
            Ok(()) => {
                self.hits.fetch_add(1, Ordering::SeqCst);
                // The target may be parked on an empty class queue —
                // unpark it so its cancellation predicate sees side work.
                classes[ci].queue.wake_consumers();
                None
            }
            Err(e) => {
                classes[ci].backlog.fetch_sub(1, Ordering::SeqCst);
                let mut r = match e {
                    // Bounded stickiness: a hot worker must not build an
                    // unbounded private backlog while siblings idle.
                    TryPushError::Full(r) => {
                        self.miss_capacity.fetch_add(1, Ordering::SeqCst);
                        r
                    }
                    TryPushError::Closed(r) => {
                        self.table.lock().unwrap().remove(&stream);
                        self.miss_retired.fetch_add(1, Ordering::SeqCst);
                        r
                    }
                };
                r.sticky = false;
                Some(r)
            }
        }
    }
}

/// One tenant's live admission state and books. The `in_queue` occupancy
/// tracks this tenant's requests sitting in the *ingress* queue only —
/// the quota is an admission concept; once the router moves a request to
/// a class sub-queue it has been admitted and scheduled. All counters are
/// written from the stage threads and read after the scope joins.
struct TenantCtx {
    name: String,
    weight: usize,
    /// Ingress slots this tenant may occupy (weighted share of the queue
    /// depth; the full depth when the run has a single tenant).
    quota: usize,
    /// Per-tenant SLO overriding the global one.
    slo: Option<Duration>,
    /// This tenant's requests currently in the ingress queue (maintained
    /// only in multi-tenant runs — the single-tenant path never reads it).
    in_queue: AtomicUsize,
    /// Admission sheds: drop-oldest evictions + over-quota arrivals.
    dropped: AtomicUsize,
    deadline_offered: AtomicUsize,
    deadline_ingress: AtomicUsize,
    /// Router sheds + worker-pop expiries.
    deadline_router: AtomicUsize,
    /// Recoverable source rejects attributed to this tenant.
    ingest_rejects: AtomicUsize,
}

impl TenantCtx {
    fn new(name: String, weight: usize, slo: Option<Duration>, quota: usize) -> TenantCtx {
        TenantCtx {
            name,
            weight,
            quota,
            slo,
            in_queue: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
            deadline_offered: AtomicUsize::new(0),
            deadline_ingress: AtomicUsize::new(0),
            deadline_router: AtomicUsize::new(0),
            ingest_rejects: AtomicUsize::new(0),
        }
    }
}

/// Claim one pending retire token (false when none are pending). CAS
/// loop so concurrent claimers never double-spend a token — each
/// scale-down step retires exactly one worker.
fn take_retire_token(tokens: &AtomicUsize) -> bool {
    let mut t = tokens.load(Ordering::SeqCst);
    while t > 0 {
        match tokens.compare_exchange(t, t - 1, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(_) => return true,
            Err(cur) => t = cur,
        }
    }
    false
}

/// Per-worker raw output collected at join time.
struct WorkerOutput {
    wid: usize,
    class: usize,
    busy_s: f64,
    records: Vec<ServedRecord>,
    batch_sizes: Vec<usize>,
    /// Delta-inference outcome tallies for requests this worker served.
    delta: DeltaMetrics,
}

/// The accelerator worker body: drain `queue` in micro-batches — expiring
/// deadline-passed requests at the pop, without spending a batch slot on
/// them — and classify through this replica's backend. `routed` is true
/// when a router feeds this class (several classes): the worker then
/// maintains the class backlog and folds observed service times back into
/// the class cost model; in the single-class fast path (`queue` *is* the
/// ingress) both are skipped — there is no routing decision to inform.
///
/// Autoscaler retirement: a scale-down step deposits a retire token at
/// the class; the first worker to claim it finishes the batch it holds
/// (in-flight work is always drained), stops taking new work, and exits —
/// a parked worker is unblocked via the queue's cancellable pop and
/// re-parks if a sibling claimed the token first.
///
/// Sticky routing: a delta-capable worker under a router additionally
/// owns a bounded `side` queue of requests pinned to it because it holds
/// their stream's delta cache. Side work is drained first (non-blocking)
/// each lap; after a served batch the worker re-advertises the streams it
/// refreshed via `sticky`. A retiring sticky worker first withdraws from
/// the target list and closes its side queue (in-flight pushes bounce to
/// the router for cost routing), then serves the remainder itself — no
/// pinned request is ever stranded or double-served.
#[allow(clippy::too_many_arguments)]
/// Join one pipeline thread, funneling a panic into the run's
/// first-error slot instead of tearing down the coordinator mid-shutdown.
/// The remaining stages still get joined and their outputs collected.
fn join_noting<T>(r: std::thread::Result<T>, what: &str, first_error: &Mutex<Option<String>>) {
    if r.is_err() {
        let msg = format!("{what} thread panicked");
        first_error.lock().unwrap_or_else(|e| e.into_inner()).get_or_insert_with(|| msg);
    }
}

fn worker_loop(
    wid: usize,
    ci: usize,
    class: &ClassCtx<'_>,
    queue: &AdmissionQueue<Routed>,
    routed: bool,
    backend: &dyn Backend,
    classes: &[ClassCtx<'_>],
    ingress: &AdmissionQueue<Routed>,
    tenants: &[TenantCtx],
    sticky: Option<&StickyCtx>,
    side: Option<Arc<AdmissionQueue<Routed>>>,
    first_error: &Mutex<Option<String>>,
) -> WorkerOutput {
    let multi_tenant = tenants.len() > 1;
    // Record the first failure and hard-stop every stage: producers fail
    // fast, the router and all class workers wake and exit.
    let fail = |msg: String| {
        first_error.lock().unwrap().get_or_insert_with(|| msg);
        ingress.abort();
        for c in classes {
            c.queue.abort();
        }
    };
    let mut records: Vec<ServedRecord> = Vec::new();
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut busy_s = 0.0f64;
    let mut delta = DeltaMetrics::default();
    let use_delta = backend.supports_delta();
    let batch_cap = class.batch.max(1);
    let mut batch: Vec<Routed> = Vec::with_capacity(batch_cap);
    let mut metas: Vec<Meta> = Vec::with_capacity(batch_cap);
    let mut maps: Vec<SparseMap<f32>> = Vec::with_capacity(batch_cap);
    let mut streams: Vec<Option<u64>> = Vec::with_capacity(batch_cap);
    let side_pending = || side.as_ref().is_some_and(|q| q.stats().2 > 0);
    let mut retiring = false;
    loop {
        // Retired by the autoscaler: claim the pending token (the
        // previous iteration's batch was fully served — in-flight work is
        // never abandoned), stop being a sticky target, then serve out
        // the side-queue remainder before exiting.
        if !retiring && take_retire_token(&class.retire) {
            retiring = true;
            if let Some(sq) = &side {
                if let Some(sc) = sticky {
                    sc.deregister(wid);
                }
                // Closed *after* deregistration: an in-flight sticky push
                // bounces back to the router, which cost-routes it.
                sq.close();
            }
        }
        if retiring && side.is_none() {
            break;
        }
        // Affinity work first: requests the router pinned to this worker
        // because it holds their stream's delta cache. The always-true
        // cancellation predicate makes this a non-blocking drain.
        let mut side_expired = 0usize;
        if let Some(sq) = &side {
            side_expired = sq.pop_batch_where_cancellable(
                batch_cap,
                &mut batch,
                |r| {
                    let ex = r.expired(Instant::now());
                    if ex {
                        tenants[r.tenant].deadline_router.fetch_add(1, Ordering::SeqCst);
                    }
                    ex
                },
                || true,
            );
            if side_expired > 0 {
                // Side queues exist only under a router: the class books
                // always apply.
                class.deadline_drops.fetch_add(side_expired, Ordering::SeqCst);
                class.backlog.fetch_sub(side_expired, Ordering::SeqCst);
            }
        }
        if batch.is_empty() && retiring {
            if side_expired > 0 {
                continue; // expiries accounted; re-check for a remainder
            }
            break; // side queue drained — retirement complete
        }
        if batch.is_empty() {
            // No pinned work: drain the class queue (or, routerless, the
            // ingress) like any sibling. Deadline-passed requests are
            // discarded inside the queue lock: they must not waste a
            // batch slot, let alone a backend visit. The pop returns
            // promptly on an all-reject drain so the class backlog and
            // drop books update *before* the next routing decision — the
            // router must not see phantom backlog. The cancellation
            // predicate unparks workers (empty-handed) when the
            // autoscaler deposits a retire token — or the router lands
            // sticky work — while the queue is idle.
            let expired = queue.pop_batch_where_cancellable(
                batch_cap,
                &mut batch,
                |r| {
                    let ex = r.expired(Instant::now());
                    if ex {
                        // Attribute the expiry to its tenant here, where
                        // the item is still visible; in the routerless
                        // path the queue *is* the ingress, so the expiry
                        // also frees the tenant's quota slot.
                        tenants[r.tenant].deadline_router.fetch_add(1, Ordering::SeqCst);
                        if !routed && multi_tenant {
                            tenants[r.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    ex
                },
                || class.retire.load(Ordering::SeqCst) > 0 || side_pending(),
            );
            if expired > 0 {
                class.deadline_drops.fetch_add(expired, Ordering::SeqCst);
                if routed {
                    class.backlog.fetch_sub(expired, Ordering::SeqCst);
                }
            }
            if batch.is_empty() {
                if expired > 0 {
                    continue; // expiries accounted; look for real work again
                }
                if side_pending() {
                    continue; // woken for pinned work — the top of the loop drains it
                }
                // Empty-handed: the stream ended, or a retire token woke
                // the class (claimed at the top of the loop — exactly one
                // worker gets it; the rest find it gone and park again).
                if class.retire.load(Ordering::SeqCst) > 0 {
                    continue;
                }
                if queue.is_closed() {
                    // Closed and drained, or aborted. Anything still on
                    // the side queue was pushed before the router exited —
                    // serve it before leaving (re-checked after observing
                    // the close, so no later push can be missed).
                    if side_pending() {
                        continue;
                    }
                    if let Some(sq) = &side {
                        if let Some(sc) = sticky {
                            sc.deregister(wid);
                        }
                        sq.close();
                    }
                    break;
                }
                continue; // the token went to a sibling — look for work again
            }
        }
        let n = batch.len();
        metas.clear();
        maps.clear();
        streams.clear();
        for req in batch.drain(..) {
            // In the routerless path this pop took the request out of the
            // ingress queue, freeing its tenant's quota slot (the routed
            // path freed it when the router popped the ingress).
            if !routed && multi_tenant {
                tenants[req.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
            }
            metas.push(Meta {
                label: req.label,
                tenant: req.tenant,
                arrival: req.arrival,
                bucket: req.bucket,
                predicted_s: req.predicted_s,
                deadline: req.deadline,
                sticky: req.sticky,
            });
            streams.push(req.stream);
            maps.push(req.map);
        }
        let t0 = Instant::now();
        // Delta-capable backends take the stream-labelled entry point;
        // the plain path is adapted so both arms yield one result shape.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if use_delta {
                backend.classify_batch_delta(&streams, &maps)
            } else {
                backend
                    .classify_batch(&maps)
                    .into_iter()
                    .map(|r| r.map(|c| (c, DeltaStatus::NotApplicable)))
                    .collect()
            }
        }));
        let visit_s = t0.elapsed().as_secs_f64();
        let done = Instant::now();
        if routed {
            // The visit is over: these requests leave the class's routing
            // backlog whatever the outcome.
            class.backlog.fetch_sub(n, Ordering::SeqCst);
        }
        let results = match outcome {
            Ok(rs) => rs,
            Err(p) => {
                fail(format!("worker panic: {}", panic_message(p.as_ref())));
                break;
            }
        };
        if results.len() != n {
            // A broken Backend impl must fail loudly, not silently lose
            // requests to zip truncation.
            fail(format!(
                "backend '{}' returned {} result(s) for a batch of {n}",
                backend.name(),
                results.len(),
            ));
            break;
        }
        busy_s += visit_s;
        // Class-level busy books feed the autoscaler's windowed
        // utilization (cheap: one atomic add per accelerator visit).
        class.busy_us.fetch_add((visit_s * 1e6) as u64, Ordering::SeqCst);
        batch_sizes.push(n);
        // The visit is one accelerator pass; attribute its cost evenly
        // across the requests it served, and — when a router is making
        // decisions — teach it what this class actually costs at each
        // request's event-count bucket.
        let service_s = visit_s / n as f64;
        if routed {
            for m in &metas {
                class.cost.observe(m.bucket, service_s);
            }
        }
        let mut failed = false;
        for (m, res) in metas.iter().zip(results) {
            match res {
                Ok((c, st)) => {
                    match st {
                        DeltaStatus::NotApplicable => delta.not_applicable += 1,
                        DeltaStatus::Hit { dirty_frac, recomputed_frac } => {
                            delta.hits += 1;
                            delta.dirty_frac_sum += dirty_frac;
                            delta.recomputed_frac_sum += recomputed_frac;
                        }
                        DeltaStatus::Full(FullReason::ColdCache) => delta.full_cold += 1,
                        DeltaStatus::Full(FullReason::Geometry) => delta.full_geometry += 1,
                        DeltaStatus::Full(FullReason::OverThreshold) => {
                            delta.full_over_threshold += 1;
                        }
                    }
                    let timing = RequestTiming {
                        e2e_s: done.duration_since(m.arrival).as_secs_f64(),
                        service_s,
                        sim_cycles: c.sim_cycles,
                    };
                    records.push(ServedRecord {
                        label: m.label,
                        tenant: m.tenant,
                        pred: c.pred,
                        timing,
                        predicted_s: m.predicted_s,
                        met_deadline: m.deadline.map(|dl| done <= dl),
                        sticky: m.sticky,
                    });
                }
                Err(e) => {
                    fail(e.to_string());
                    failed = true;
                    break;
                }
            }
        }
        if failed {
            break;
        }
        // The batch is served: future windows of these streams should come
        // back here, where their freshly written caches live. A retiring
        // worker must not re-advertise itself.
        if use_delta && !retiring {
            if let (Some(sc), Some(_)) = (sticky, &side) {
                for &s in streams.iter().flatten() {
                    sc.remember(s, wid);
                }
            }
        }
    }
    WorkerOutput { wid, class: ci, busy_s, records, batch_sizes, delta }
}

/// The autoscaler controller loop: every `auto.interval` it samples each
/// class's backlog plus sliding-window deadline-drop and busy counters,
/// then takes at most one scaling step per class per tick.
///
/// - **Scale up** (pressure): deadline drops landed in the window, or the
///   per-active-replica backlog exceeds the high watermark. The next
///   replica slot's backend is built on demand through the pool's
///   retained factory (and kept warm for later re-activation); a fresh
///   worker thread is spawned into the serving scope for it.
/// - **Scale down** (idle): zero backlog, no deadline drops in the
///   window, and windowed utilization under the low watermark. One
///   retire token is deposited; the first worker of the class to see it
///   drains its in-flight batch and exits.
///
/// A failed scale-up (factory error) is recorded as a scaling event and
/// does not abort serving — the class simply stays at its current size.
/// The controller exits when the spine flips the `stop` latch after the
/// stream has drained.
#[allow(clippy::too_many_arguments)]
fn run_autoscaler<'scope, 'a: 'scope>(
    auto: &AutoscaleConfig,
    s: &'scope std::thread::Scope<'scope, '_>,
    classes: &'scope [ClassCtx<'a>],
    tenants: &'scope [TenantCtx],
    has_router: bool,
    ingress: &'scope AdmissionQueue<Routed>,
    t_start: Instant,
    stop: &'scope (Mutex<bool>, Condvar),
    events: &'scope Mutex<Vec<ScalingEvent>>,
    next_wid: &'scope AtomicUsize,
    outputs: &'scope Mutex<Vec<WorkerOutput>>,
    sticky: Option<&'scope StickyCtx>,
    depth: usize,
    first_error: &'scope Mutex<Option<String>>,
) {
    let mut drops_w: Vec<SlidingWindow> =
        classes.iter().map(|_| SlidingWindow::new(auto.window)).collect();
    let mut busy_w: Vec<SlidingWindow> =
        classes.iter().map(|_| SlidingWindow::new(auto.window)).collect();
    let push_event = |class: &ClassCtx<'_>, from: usize, to: usize, reason: String| {
        events.lock().unwrap().push(ScalingEvent {
            at_s: t_start.elapsed().as_secs_f64(),
            class: class.name.clone(),
            from,
            to,
            reason,
        });
    };
    loop {
        // Sleep one tick — or wake immediately when the spine stops us.
        {
            let (lock, cv) = stop;
            let mut stopped = lock.lock().unwrap();
            if !*stopped {
                // lint:allow(panic): condvar poisoning is the lock-poisoning
                // idiom — holders never panic while flipping the stop flag
                stopped = cv.wait_timeout(stopped, auto.interval).unwrap().0;
            }
            if *stopped {
                return;
            }
        }
        let now = Instant::now();
        for (ci, class) in classes.iter().enumerate() {
            let active = class.active.load(Ordering::SeqCst);
            drops_w[ci].record(now, class.deadline_drops.load(Ordering::SeqCst) as u64);
            busy_w[ci].record(now, class.busy_us.load(Ordering::SeqCst));
            let drop_rate = drops_w[ci].rate();
            let span = busy_w[ci].span_secs();
            let util = if span > 0.0 && active > 0 {
                (busy_w[ci].delta() as f64 / 1e6) / (span * active as f64)
            } else {
                0.0
            };
            // Backlog: the router maintains per-class counts; the
            // routerless single-class path reads the ingress queue.
            let backlog = if has_router {
                class.backlog.load(Ordering::SeqCst)
            } else {
                ingress.stats().2
            };
            let per_replica = backlog as f64 / active.max(1) as f64;
            let pressured = drop_rate > 0.0 || per_replica > auto.high_backlog;
            if pressured && active < class.max {
                // Scale up: fetch (or lazily build) the next slot's
                // backend, then spawn a worker for it.
                let slot = active;
                let backend = {
                    let mut slots = class.slots.lock().unwrap();
                    match slots.get(slot) {
                        Some(b) => Some(b.clone()), // warm from an earlier grow
                        None => match class.grow.map(|pc| pc.build_replica(slot)) {
                            Some(Ok(b)) => {
                                let r = BackendRef::Shared(b);
                                slots.push(r.clone());
                                Some(r)
                            }
                            Some(Err(e)) => {
                                push_event(
                                    class,
                                    active,
                                    active,
                                    format!("scale-up failed: {e}"),
                                );
                                None
                            }
                            // Not growable (homogeneous path): max ==
                            // base count, so this arm is unreachable —
                            // kept total for safety.
                            None => None,
                        },
                    }
                };
                if let Some(backend) = backend {
                    // Publish the capacity before the worker exists so its
                    // very first retire-token check cannot see a stale
                    // count; the router immediately routes against it.
                    class.active.store(active + 1, Ordering::SeqCst);
                    class.peak.fetch_max(active + 1, Ordering::SeqCst);
                    push_event(
                        class,
                        active,
                        active + 1,
                        if drop_rate > 0.0 {
                            format!("deadline-drop rate {drop_rate:.1}/s in window")
                        } else {
                            format!(
                                "backlog {per_replica:.1}/replica > {:.1}",
                                auto.high_backlog
                            )
                        },
                    );
                    let wid = next_wid.fetch_add(1, Ordering::SeqCst);
                    let queue = if has_router { &class.queue } else { ingress };
                    // A delta-capable replica joins the sticky target
                    // list before its worker runs: streams it serves can
                    // be pinned back to it from its very first batch.
                    let side = sticky.and_then(|sc| {
                        backend.get().supports_delta().then(|| {
                            let q =
                                Arc::new(AdmissionQueue::new(depth, DropPolicy::Block));
                            sc.enroll(wid, ci, &q);
                            q
                        })
                    });
                    s.spawn(move || {
                        let out = worker_loop(
                            wid, ci, class, queue, has_router, backend.get(), classes,
                            ingress, tenants, sticky, side, first_error,
                        );
                        outputs.lock().unwrap().push(out);
                    });
                }
            } else if !pressured
                && active > class.min
                && backlog == 0
                && util < auto.low_util
                && span >= auto.window.as_secs_f64() * 0.5
            {
                // Scale down: shrink the advertised capacity first so the
                // router stops counting the leaving replica, then deposit
                // the retire token and wake any parked worker to claim it.
                class.active.store(active - 1, Ordering::SeqCst);
                class.retire.fetch_add(1, Ordering::SeqCst);
                push_event(
                    class,
                    active,
                    active - 1,
                    format!("idle: backlog 0, util {:.0}% < {:.0}%", util * 100.0,
                        auto.low_util * 100.0),
                );
                if has_router {
                    class.queue.wake_consumers();
                } else {
                    ingress.wake_consumers();
                }
            }
        }
    }
}

/// Run the serving pipeline to completion over `cfg.n_requests` synthetic
/// requests with a **homogeneous** pool: `cfg.workers` replicas sharing
/// one backend, a single class. With one class there is no routing
/// decision, so no router thread runs — workers drain the ingress queue
/// directly, exactly as the pre-pool runtime did (same admission and
/// drop-oldest semantics, no cost-model overhead).
pub fn run_server(
    profile: &DatasetProfile,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    run_server_source(Box::new(synthetic_source(profile, cfg)), backend, cfg)
}

/// The synthetic source every profile-based entry point shares:
/// independent windows classically, or interleaved per-stream sliding
/// windows when `cfg.overlap` asks for them.
fn synthetic_source(profile: &DatasetProfile, cfg: &ServerConfig) -> SyntheticSource {
    let source = SyntheticSource::new(profile.clone(), cfg.n_requests, cfg.seed);
    if cfg.overlap > 0.0 {
        source.with_overlap(cfg.overlap, cfg.streams)
    } else {
        source
    }
}

/// [`run_server`] over an arbitrary [`EventSource`] — replayed datasets,
/// tailed capture files, or anything implementing the trait. The source
/// owns the stream length; `cfg.n_requests` is ignored.
pub fn run_server_source(
    source: Box<dyn EventSource>,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(cfg.workers >= 1, "need at least one worker replica");
    let slots = vec![ClassSlots {
        name: backend.name().to_string(),
        batch: cfg.batch.max(1),
        backends: vec![BackendRef::Borrowed(backend); cfg.workers],
        max: cfg.workers,
        grow: None,
    }];
    serve_classes(source, slots, cfg)
}

/// Run the serving pipeline over a **heterogeneous** [`ReplicaPool`]: each
/// class brings its own replica count, per-replica backend instances, and
/// batch affinity; the router spreads admitted requests across classes by
/// predicted completion time. `cfg.workers` and `cfg.batch` are ignored —
/// the pool defines the shape.
pub fn run_pool(
    profile: &DatasetProfile,
    pool: &ReplicaPool,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    run_pool_source(Box::new(synthetic_source(profile, cfg)), pool, cfg)
}

/// [`run_pool`] over an arbitrary [`EventSource`].
pub fn run_pool_source(
    source: Box<dyn EventSource>,
    pool: &ReplicaPool,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!pool.classes.is_empty(), "pool needs at least one replica class");
    let slots: Vec<ClassSlots<'_>> = pool
        .classes
        .iter()
        .map(|c| ClassSlots {
            name: c.name.clone(),
            batch: c.batch,
            backends: c.replicas.iter().map(|b| BackendRef::Shared(Arc::clone(b))).collect(),
            max: c.max,
            grow: Some(c),
        })
        .collect();
    serve_classes(source, slots, cfg)
}

/// The shared serving spine behind every entry point.
fn serve_classes(
    source: Box<dyn EventSource>,
    slots: Vec<ClassSlots<'_>>,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!slots.is_empty(), "need at least one replica class");
    assert!(
        slots.iter().all(|c| !c.backends.is_empty()),
        "every replica class needs at least one worker"
    );
    let t_start = Instant::now();
    // With a single class there is nothing to route: workers drain the
    // ingress directly (no router thread, no cost-model locks), which also
    // preserves the exact drop-oldest semantics the homogeneous runtime
    // always had — the stalest *queued* request is the one evicted.
    let has_router = slots.len() > 1;
    let ingress: AdmissionQueue<Routed> = AdmissionQueue::new(cfg.queue_depth, cfg.drop_policy);
    // Tenant table: the configured tenants, or a single implicit default
    // whose quota is the whole queue — the front door stays inert and
    // single-tenant admission semantics are exactly the pre-tenant ones.
    let depth = cfg.queue_depth.max(1);
    let multi_tenant = cfg.tenants.len() > 1;
    let total_weight: usize =
        cfg.tenants.iter().map(|t| t.weight.max(1)).sum::<usize>().max(1);
    let tenants: Vec<TenantCtx> = if cfg.tenants.is_empty() {
        vec![TenantCtx::new("default".to_string(), 1, None, depth)]
    } else {
        cfg.tenants
            .iter()
            .map(|t| {
                let weight = t.weight.max(1);
                // Floor-share quotas keep Σ quotas ≤ depth (short of the
                // min-1 floor with many tiny tenants), so an under-quota
                // arrival finds a free slot instead of blocking on other
                // tenants' traffic.
                let quota =
                    if multi_tenant { (depth * weight / total_weight).max(1) } else { depth };
                TenantCtx::new(t.name.clone(), weight, t.slo, quota)
            })
            .collect()
    };
    let classes: Vec<ClassCtx<'_>> = slots
        .into_iter()
        .map(|c| {
            let min = c.backends.len();
            let cost = CostModel::new();
            // Seed the predictor from a previous run's persisted profile:
            // the class routes and SLO-sheds from its first request
            // instead of burning probe traffic, and replicas the
            // autoscaler grows later join a class that already knows its
            // costs.
            if let Some(profile) = &cfg.cost_profile {
                if let Some(snap) = profile.classes.get(&c.name) {
                    // Aged knowledge decays before it seeds: stale buckets
                    // (and, much later, the global mean) are dropped so a
                    // profile from last week cannot mis-route or mis-shed
                    // today's traffic (see [`CostSnapshot::decayed`]).
                    cost.seed(&snap.decayed(profile.age_secs()));
                }
            }
            ClassCtx {
                // Sub-queues always block: admission control (and its drop
                // accounting) lives at the global ingress only. A full
                // sub-queue back-pressures the router, which lets the ingress
                // saturate, where the shedding decision is made and counted.
                // (Trade-off vs the single-class path: requests already routed
                // into a sub-queue are no longer evictable by drop-oldest —
                // though a deadline can still expire them at the worker pop.)
                queue: AdmissionQueue::new(cfg.queue_depth, DropPolicy::Block),
                backlog: AtomicUsize::new(0),
                cost,
                deadline_drops: AtomicUsize::new(0),
                busy_us: AtomicU64::new(0),
                active: AtomicUsize::new(min),
                peak: AtomicUsize::new(min),
                retire: AtomicUsize::new(0),
                min,
                max: c.max.max(min),
                grow: c.grow,
                slots: Mutex::new(c.backends),
                name: c.name,
                batch: c.batch.max(1),
            }
        })
        .collect();
    // Sticky (cache-affinity) routing exists only when a router makes
    // placement decisions AND some class can actually reuse per-stream
    // state. Declared before the thread scope so the router, workers,
    // and autoscaler all borrow one context.
    let any_delta = classes
        .iter()
        .any(|c| c.slots.lock().unwrap().iter().any(|b| b.get().supports_delta()));
    let sticky_ctx = (has_router && any_delta).then(StickyCtx::new);
    let sticky_ref = sticky_ctx.as_ref();
    let first_error: Mutex<Option<String>> = Mutex::new(None);
    let deadline_offered = AtomicUsize::new(0);
    let deadline_ingress = AtomicUsize::new(0);
    // Recoverable source rejects (the stream skipped past them) and
    // over-quota admission drops — both outside the queue's own books.
    let ingest_rejects = AtomicUsize::new(0);
    let quota_drops = AtomicUsize::new(0);
    // Worker outputs land here (workers push at exit rather than being
    // joined for a return value, because the autoscaler spawns workers
    // the spine never held handles for).
    let outputs_mx: Mutex<Vec<WorkerOutput>> = Mutex::new(Vec::new());
    let scaling_events: Mutex<Vec<ScalingEvent>> = Mutex::new(Vec::new());
    // Autoscaler shutdown latch: flag + condvar so the controller can be
    // woken mid-sleep once the stream has fully drained.
    let scaler_stop: (Mutex<bool>, Condvar) = (Mutex::new(false), Condvar::new());
    let next_wid = AtomicUsize::new(classes.iter().map(|c| c.min).sum());
    let (w, h) = source.geometry();
    let (tx_ev, rx_ev) =
        sync_channel::<super::ingest::SourcedRequest>(cfg.queue_depth.max(1));

    std::thread::scope(|s| {
        let error_ref = &first_error;
        let tenants_ref: &[TenantCtx] = &tenants;
        let rejects_ref = &ingest_rejects;

        // Stage 1: the event source (synthetic camera, dataset replay,
        // capture tail, or socket) — owns pacing and arrival timestamps.
        let src_thread = s.spawn(move || {
            let mut src = source;
            loop {
                match src.next_request() {
                    Ok(Some(req)) => {
                        if tx_ev.send(req).is_err() {
                            return; // downstream hung up early
                        }
                    }
                    Ok(None) => return, // stream complete
                    Err(e) if e.is_recoverable() => {
                        // A per-sample validation reject: the reader is
                        // still aligned and the stream continues — count
                        // it and keep pulling. One bad sample must not
                        // kill the serving run.
                        rejects_ref.fetch_add(1, Ordering::SeqCst);
                        // Attribute it when the source knows the tenant
                        // (socket packets) or when there is only one.
                        let t = e.tenant().or((tenants_ref.len() == 1).then_some(0));
                        if let Some(tc) = t.and_then(|t| tenants_ref.get(t)) {
                            tc.ingest_rejects.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    Err(e) => {
                        // Fatal: a latched byte-stream failure. Record it
                        // and end the stream; the stages downstream drain
                        // what was already admitted and exit cleanly.
                        error_ref
                            .lock()
                            .unwrap()
                            .get_or_insert_with(|| format!("event source: {e}"));
                        return;
                    }
                }
            }
        });

        // Stage 2: representation builder + admission control, including
        // the ingress deadline check.
        let (clip, slo) = (cfg.clip, cfg.slo);
        let ingress_ref = &ingress;
        let offered_ref = &deadline_offered;
        let ingress_exp_ref = &deadline_ingress;
        let quota_drops_ref = &quota_drops;
        let repr = s.spawn(move || {
            for sr in rx_ev.iter() {
                // Clamp out-of-range tenant ids (a socket source whose
                // tenant table disagrees with the server's) to the last
                // tenant rather than panicking mid-spine.
                let t = sr.tenant.min(tenants_ref.len() - 1);
                let tc = &tenants_ref[t];
                // The tenant's own SLO wins over the global one.
                let deadline = tc.slo.or(slo).map(|d| sr.arrival + d);
                if deadline.is_some() {
                    offered_ref.fetch_add(1, Ordering::SeqCst);
                    tc.deadline_offered.fetch_add(1, Ordering::SeqCst);
                }
                // Drop already-expired requests before paying for their
                // representation — the cheapest possible shed.
                if deadline.is_some_and(|dl| Instant::now() >= dl) {
                    ingress_exp_ref.fetch_add(1, Ordering::SeqCst);
                    tc.deadline_ingress.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                // Weighted fair admission: a tenant at its ingress quota
                // is shed *before* the repr is built — it can saturate
                // only its own share of the queue, never starve siblings.
                if multi_tenant && tc.in_queue.load(Ordering::SeqCst) >= tc.quota {
                    quota_drops_ref.fetch_add(1, Ordering::SeqCst);
                    tc.dropped.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                let map = histogram2_norm(&sr.events, w, h, clip);
                let req = Routed {
                    label: sr.label,
                    tenant: t,
                    bucket: CostModel::bucket_of(map.nnz()),
                    map,
                    arrival: sr.arrival,
                    deadline,
                    predicted_s: f64::NAN,
                    stream: sr.stream,
                    sticky: false,
                };
                if multi_tenant {
                    tc.in_queue.fetch_add(1, Ordering::SeqCst);
                }
                match ingress_ref.push_evicting(req) {
                    Ok(Some(victim)) => {
                        // Drop-oldest made room: charge the eviction to
                        // the victim's tenant and free its quota slot.
                        let vt = &tenants_ref[victim.tenant];
                        vt.dropped.fetch_add(1, Ordering::SeqCst);
                        if multi_tenant {
                            vt.in_queue.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    Ok(None) => {}
                    Err(_) => break, // queue closed by an aborting worker
                }
            }
            ingress_ref.close();
        });

        // Stage 3: the cost-aware router — admitted requests to class
        // sub-queues by predicted completion time, shedding requests no
        // class can finish in time. Only spawned when there is a routing
        // decision to make.
        let classes_ref: &[ClassCtx<'_>] = &classes;
        let router = has_router.then(|| {
            s.spawn(move || {
                while let Some(mut req) = ingress_ref.pop() {
                    // Out of the ingress queue: the tenant's quota slot is
                    // free again whatever happens downstream.
                    if multi_tenant {
                        tenants_ref[req.tenant].in_queue.fetch_sub(1, Ordering::SeqCst);
                    }
                    // Sticky fast path: land a live stream back on the
                    // worker holding its delta cache. Expired requests
                    // skip it (the cost path below sheds and counts
                    // them); any miss falls through to cost routing.
                    if let Some(sc) = sticky_ref {
                        if !req.expired(Instant::now()) {
                            match sc.try_route(req, classes_ref) {
                                None => continue,
                                Some(back) => req = back,
                            }
                        }
                    }
                    let d = route(classes_ref, req.bucket);
                    if let Some(dl) = req.deadline {
                        let now = Instant::now();
                        // Shed when the deadline has passed, or when even
                        // the *best* class's predicted completion misses
                        // it. An unknown completion (probe traffic, cold
                        // pool) is never shed predictively — the probe's
                        // value is the cost observation itself.
                        let predicted_done = d.completion_s.is_finite().then(|| {
                            // Clamp: any sane SLO is far under 1e6 s, and
                            // `from_secs_f64` must not overflow on a
                            // pathological EWMA.
                            now + Duration::from_secs_f64(d.completion_s.clamp(0.0, 1e6))
                        });
                        if now >= dl || predicted_done.is_some_and(|t| t > dl) {
                            classes_ref[d.class]
                                .deadline_drops
                                .fetch_add(1, Ordering::SeqCst);
                            tenants_ref[req.tenant]
                                .deadline_router
                                .fetch_add(1, Ordering::SeqCst);
                            continue;
                        }
                    }
                    let class = &classes_ref[d.class];
                    req.predicted_s = d.predicted_s;
                    class.backlog.fetch_add(1, Ordering::SeqCst);
                    if class.queue.push(req).is_err() {
                        break; // aborted downstream
                    }
                }
                for c in classes_ref {
                    c.queue.close();
                }
            })
        });

        // Stage 4: per-class accelerator worker pools — the base (min)
        // replicas; the autoscaler below may spawn more into this scope.
        let outputs_ref = &outputs_mx;
        let mut handles = Vec::new();
        let mut base_wid = 0usize;
        for (ci, class) in classes.iter().enumerate() {
            let base: Vec<BackendRef<'_>> = class.slots.lock().unwrap().clone();
            for backend in base {
                let wid = base_wid;
                base_wid += 1;
                // Delta-capable workers under a router own a bounded side
                // queue for requests pinned to them by stream affinity.
                let side = sticky_ref.and_then(|sc| {
                    backend.get().supports_delta().then(|| {
                        let q = Arc::new(AdmissionQueue::new(depth, DropPolicy::Block));
                        sc.enroll(wid, ci, &q);
                        q
                    })
                });
                handles.push(s.spawn(move || {
                    let queue = if has_router { &class.queue } else { ingress_ref };
                    let out = worker_loop(
                        wid, ci, class, queue, has_router, backend.get(), classes_ref,
                        ingress_ref, tenants_ref, sticky_ref, side, error_ref,
                    );
                    outputs_ref.lock().unwrap().push(out);
                }));
            }
        }

        // Stage 5: the autoscaler controller. Spawned only when it could
        // ever act — autoscaling requested AND some class has headroom.
        let stop_ref = &scaler_stop;
        let events_ref = &scaling_events;
        let next_wid_ref = &next_wid;
        let scalable = classes.iter().any(|c| c.max > c.min);
        let controller = cfg.autoscale.clone().filter(|_| scalable).map(|auto| {
            s.spawn(move || {
                run_autoscaler(
                    &auto, s, classes_ref, tenants_ref, has_router, ingress_ref, t_start,
                    stop_ref, events_ref, next_wid_ref, outputs_ref, sticky_ref, depth,
                    error_ref,
                )
            })
        });

        for h in handles {
            join_noting(h.join(), "worker", error_ref);
        }
        if let Some(h) = router {
            join_noting(h.join(), "router", error_ref);
        }
        join_noting(repr.join(), "repr", error_ref);
        join_noting(src_thread.join(), "source", error_ref);
        // The stream has drained: stop the controller. Workers it spawned
        // exit on their own (queues are closed) and are joined by the
        // scope before `outputs_mx` is read below.
        {
            let (lock, cv) = &scaler_stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(h) = controller {
            join_noting(h.join(), "autoscaler", error_ref);
        }
    });

    // Poisoning is survivable here: a panicking worker was already noted
    // in `first_error` by `join_noting`, so take whatever was recorded.
    let mut outputs = outputs_mx.into_inner().unwrap_or_else(|e| e.into_inner());
    outputs.sort_by_key(|o| o.wid);
    let (submitted, dropped, _still_queued) = ingress.stats();
    let processed: usize = outputs.iter().map(|o| o.records.len()).sum();
    // Deadline sheds past admission (router + worker pop) — these were
    // submitted but intentionally never classified.
    let deadline_shed: usize =
        classes.iter().map(|c| c.deadline_drops.load(Ordering::SeqCst)).sum();
    let in_flight = submitted.saturating_sub(dropped + processed + deadline_shed);
    // Admission sheds: queue evictions plus over-quota drops (the latter
    // never occupied a slot, so they are outside the queue's own books).
    let shed = dropped + quota_drops.load(Ordering::SeqCst);

    if let Some(msg) = first_error.into_inner().unwrap_or_else(|e| e.into_inner()) {
        return Err(PipelineError { msg, completed: processed, in_flight, dropped: shed });
    }
    // Clean completion conserves requests: everything admitted was either
    // served, dropped, or shed on deadline (stranded requests only exist
    // on the Err path).
    debug_assert_eq!(in_flight, 0, "completed run stranded {in_flight} request(s)");

    let wall_s = t_start.elapsed().as_secs_f64();
    let mut metrics = Metrics {
        started: t_start,
        dropped: shed,
        wall_s,
        deadline_offered: deadline_offered.load(Ordering::SeqCst),
        deadline_ingress: deadline_ingress.load(Ordering::SeqCst),
        deadline_router: deadline_shed,
        ingest_rejects: ingest_rejects.load(Ordering::SeqCst),
        scaling_events: scaling_events.into_inner().unwrap_or_else(|e| e.into_inner()),
        // What `--cost-profile` rewrites at shutdown: every class's final
        // EWMA state (seeded knowledge + everything learned this run).
        cost_profile: CostProfile {
            classes: classes.iter().map(|c| (c.name.clone(), c.cost.snapshot())).collect(),
            // Stamped by `CostProfile::save` at write time, not here.
            saved_unix: None,
        },
        ..Metrics::default()
    };
    // Delta/sticky books: per-worker tallies merge; the router's sticky
    // counters come straight from the shared context.
    for o in &outputs {
        metrics.delta.merge(&o.delta);
    }
    if let Some(sc) = &sticky_ctx {
        metrics.delta.sticky_hits = sc.hits.load(Ordering::SeqCst);
        metrics.delta.sticky_cold = sc.miss_cold.load(Ordering::SeqCst);
        metrics.delta.sticky_retired = sc.miss_retired.load(Ordering::SeqCst);
        metrics.delta.sticky_capacity = sc.miss_capacity.load(Ordering::SeqCst);
    }
    let mut predictions = Vec::with_capacity(processed);
    let mut t_served = vec![0usize; tenants.len()];
    let mut t_met = vec![0usize; tenants.len()];
    let mut t_missed = vec![0usize; tenants.len()];
    for o in &outputs {
        let service: Vec<f64> = o.records.iter().map(|r| r.timing.service_s).collect();
        let e2e: Vec<f64> = o.records.iter().map(|r| r.timing.e2e_s).collect();
        let batches: Vec<f64> = o.batch_sizes.iter().map(|&b| b as f64).collect();
        metrics.per_worker.push(WorkerStats {
            worker: o.wid,
            class: classes[o.class].name.clone(),
            served: o.records.len(),
            batches: o.batch_sizes.len(),
            busy_s: o.busy_s,
            service: PercentileReport::from_samples(&service),
            e2e: PercentileReport::from_samples(&e2e),
            batch: PercentileReport::from_samples(&batches),
        });
        metrics.batch_sizes.extend_from_slice(&o.batch_sizes);
        for r in &o.records {
            metrics.record(r.timing, r.pred == r.label);
            t_served[r.tenant] += 1;
            match r.met_deadline {
                Some(true) => {
                    metrics.deadline_met += 1;
                    t_met[r.tenant] += 1;
                }
                Some(false) => {
                    metrics.deadline_missed += 1;
                    t_missed[r.tenant] += 1;
                }
                None => {}
            }
            predictions.push(Prediction { label: r.label, pred: r.pred, worker: o.wid });
        }
    }
    // Per-tenant rollup: the books the stage threads kept, plus served /
    // met / missed tallied from the records above.
    metrics.per_tenant = tenants
        .iter()
        .enumerate()
        .map(|(i, tc)| TenantStats {
            tenant: tc.name.clone(),
            weight: tc.weight,
            quota: tc.quota,
            served: t_served[i],
            dropped: tc.dropped.load(Ordering::SeqCst),
            deadline_offered: tc.deadline_offered.load(Ordering::SeqCst),
            deadline_ingress: tc.deadline_ingress.load(Ordering::SeqCst),
            deadline_router: tc.deadline_router.load(Ordering::SeqCst),
            deadline_met: t_met[i],
            deadline_missed: t_missed[i],
            ingest_rejects: tc.ingest_rejects.load(Ordering::SeqCst),
        })
        .collect();
    // Integrated active-replica seconds per class, reconstructed from the
    // scaling log: the truthful utilization denominator when the
    // autoscaler moved the count mid-run (a run that mostly served at 4
    // replicas but ended at 1 must not divide by 1 × wall).
    let replica_secs: Vec<f64> = classes
        .iter()
        .map(|class| {
            let mut level = class.min as f64;
            let mut t_prev = 0.0f64;
            let mut integral = 0.0f64;
            for e in metrics.scaling_events.iter().filter(|e| e.class == class.name) {
                let t = e.at_s.clamp(0.0, wall_s);
                integral += level * (t - t_prev).max(0.0);
                t_prev = t;
                level = e.to as f64;
            }
            integral + level * (wall_s - t_prev).max(0.0)
        })
        .collect();
    // Per-class rollup: served/visit/busy books plus how well the routing
    // predictor tracked observed service times.
    for (ci, class) in classes.iter().enumerate() {
        let mut served = 0usize;
        let mut batches = 0usize;
        let mut busy_s = 0.0f64;
        let mut service: Vec<f64> = Vec::new();
        let mut batch_f: Vec<f64> = Vec::new();
        let mut err_sum = 0.0f64;
        let mut err_n = 0usize;
        let mut unseeded = 0usize;
        for o in outputs.iter().filter(|o| o.class == ci) {
            served += o.records.len();
            batches += o.batch_sizes.len();
            busy_s += o.busy_s;
            batch_f.extend(o.batch_sizes.iter().map(|&b| b as f64));
            for r in &o.records {
                service.push(r.timing.service_s);
                if r.predicted_s.is_finite() {
                    err_sum += (r.predicted_s - r.timing.service_s).abs()
                        / r.timing.service_s.max(1e-9);
                    err_n += 1;
                } else if has_router && !r.sticky {
                    // Probe traffic: routed before this class's cost model
                    // had an observation. (Without a router no prediction
                    // is ever attempted, and a sticky delivery's NaN is by
                    // design — neither counts as a probe.)
                    unseeded += 1;
                }
            }
        }
        metrics.per_class.push(ClassStats {
            class: class.name.clone(),
            replicas: class.active.load(Ordering::SeqCst),
            replicas_min: class.min,
            replicas_max: class.max,
            replicas_peak: class.peak.load(Ordering::SeqCst),
            replica_s: replica_secs[ci],
            served,
            batches,
            busy_s,
            batch: PercentileReport::from_samples(&batch_f),
            service: PercentileReport::from_samples(&service),
            cost_err: if err_n > 0 { err_sum / err_n as f64 } else { f64::NAN },
            unseeded,
            deadline_drops: class.deadline_drops.load(Ordering::SeqCst),
        });
    }
    Ok(ServerResult { metrics, predictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;
    use crate::coordinator::backend::{
        BackendError, Classification, Functional, ReplicaSpec, Simulator,
    };
    use crate::coordinator::testutil::qnet_for;

    #[test]
    fn pool_processes_all_requests() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig { n_requests: 12, seed: 4, workers: 3, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 12);
        assert_eq!(r.predictions.len(), 12);
        assert_eq!(r.metrics.dropped, 0);
        assert_eq!(r.metrics.per_worker.len(), 3);
        assert_eq!(r.metrics.per_worker.iter().map(|w| w.served).sum::<usize>(), 12);
        assert!(r.metrics.throughput() > 0.0);
        // The homogeneous path reports a single routing class.
        assert_eq!(r.metrics.per_class.len(), 1);
        assert_eq!(r.metrics.per_class[0].served, 12);
        assert_eq!(r.metrics.per_class[0].replicas, 3);
        // No SLO: the deadline books stay empty and attainment is N/A.
        assert_eq!(r.metrics.deadline_offered, 0);
        assert_eq!(r.metrics.deadline_drops(), 0);
        assert_eq!(r.metrics.slo_attainment(), None);
    }

    /// Micro-batching is a scheduling detail: every request is still served
    /// exactly once, and the batch-size books stay consistent.
    #[test]
    fn batched_pool_serves_every_request_once() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig {
            n_requests: 20,
            seed: 6,
            workers: 2,
            queue_depth: 8,
            batch: 4,
            ..Default::default()
        };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 20);
        assert_eq!(r.predictions.len(), 20);
        let visits: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(visits, 20, "batch sizes must partition the request stream");
        assert!(r.metrics.batch_sizes.iter().all(|&b| (1..=4).contains(&b)));
        assert!(r.metrics.mean_batch() >= 1.0);
        let per_worker: usize = r.metrics.per_worker.iter().map(|w| w.batches).sum();
        assert_eq!(per_worker, r.metrics.batch_sizes.len());
    }

    #[test]
    fn simulator_replicas_report_cycles() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
        let cfg = ServerConfig { n_requests: 4, seed: 5, workers: 2, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 4);
        let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
        assert!(lat > 0.0);
    }

    /// A two-class heterogeneous pool serves every request exactly once,
    /// respects each class's batch affinity, and reports a per-class
    /// breakdown whose books balance.
    #[test]
    fn heterogeneous_pool_keeps_class_books_balanced() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let qnet2 = qnet.clone();
        let pool = ReplicaPool::build(vec![
            ReplicaSpec::functional(2, qnet),
            ReplicaSpec::new("func-b", 1, 2, move |_| {
                Ok(Box::new(Functional::new(qnet2.clone())))
            }),
        ])
        .unwrap();
        assert_eq!(pool.n_replicas(), 3);
        let cfg = ServerConfig { n_requests: 16, seed: 9, queue_depth: 4, ..Default::default() };
        let r = run_pool(&profile, &pool, &cfg).unwrap();
        assert_eq!(r.metrics.total, 16);
        assert_eq!(r.metrics.per_worker.len(), 3);
        assert_eq!(r.metrics.per_class.len(), 2);
        assert_eq!(r.metrics.per_class.iter().map(|c| c.served).sum::<usize>(), 16);
        let class_batches: usize = r.metrics.per_class.iter().map(|c| c.batches).sum();
        assert_eq!(class_batches, r.metrics.batch_sizes.len());
        let visits: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(visits, 16, "batch sizes must partition the request stream");
        for c in &r.metrics.per_class {
            let cap = if c.class == "func" { 4.0 } else { 2.0 };
            assert!(
                c.batches == 0 || c.batch.max <= cap,
                "class {} exceeded its batch affinity: {:?}",
                c.class,
                c.batch
            );
            assert_eq!(c.deadline_drops, 0, "no SLO ⇒ no deadline sheds");
        }
        // Worker stats carry their class name for the report.
        for w in &r.metrics.per_worker {
            assert!(w.class == "func" || w.class == "func-b", "class: {}", w.class);
        }
    }

    /// A zero SLO expires every request at the ingress: nothing reaches a
    /// worker, the drop is accounted as an ingress deadline drop, and
    /// attainment is 0.
    #[test]
    fn zero_slo_expires_everything_at_ingress() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig {
            n_requests: 8,
            seed: 4,
            workers: 2,
            slo: Some(Duration::ZERO),
            ..Default::default()
        };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 0, "an expired request must never be served");
        assert!(r.predictions.is_empty());
        assert_eq!(r.metrics.deadline_offered, 8);
        assert_eq!(r.metrics.deadline_ingress, 8);
        assert_eq!(r.metrics.deadline_router, 0);
        assert_eq!(r.metrics.dropped, 0, "deadline drops are not queue-full drops");
        assert_eq!(r.metrics.offered(), 8);
        assert_eq!(r.metrics.slo_attainment(), Some(0.0));
    }

    /// A generous SLO on an unloaded pool changes nothing: everything is
    /// served, everything meets its deadline, attainment is 1.
    #[test]
    fn generous_slo_serves_everything_in_deadline() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig {
            n_requests: 10,
            seed: 4,
            workers: 2,
            slo: Some(Duration::from_secs(60)),
            ..Default::default()
        };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 10);
        assert_eq!(r.metrics.deadline_offered, 10);
        assert_eq!(r.metrics.deadline_met, 10);
        assert_eq!(r.metrics.deadline_drops(), 0);
        assert_eq!(r.metrics.slo_attainment(), Some(1.0));
    }

    /// A backend that errors mid-stream aborts cleanly with in-flight
    /// accounting instead of deadlocking or poisoning joins.
    #[test]
    fn backend_error_aborts_cleanly() {
        struct FailAfter {
            inner: Functional,
            calls: std::sync::atomic::AtomicUsize,
        }
        impl Backend for FailAfter {
            fn name(&self) -> &str {
                "fail-after"
            }
            fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
                let n = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if n >= 5 {
                    return Err(BackendError("injected fault".into()));
                }
                self.inner.classify(map)
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = FailAfter {
            inner: Functional::new(qnet_for(&profile)),
            calls: std::sync::atomic::AtomicUsize::new(0),
        };
        let cfg = ServerConfig { n_requests: 16, seed: 2, workers: 2, ..Default::default() };
        let err = run_server(&profile, &backend, &cfg).unwrap_err();
        assert!(err.msg.contains("injected fault"), "msg: {}", err.msg);
        assert!(err.completed < 16);
    }

    /// An erroring event source surfaces as a `PipelineError` naming the
    /// source, after the already-admitted prefix was served.
    #[test]
    fn source_error_surfaces_as_pipeline_error() {
        use crate::coordinator::ingest::{IngestError, SourcedRequest};
        struct FailingSource {
            inner: SyntheticSource,
            after: usize,
            emitted: usize,
        }
        impl EventSource for FailingSource {
            fn name(&self) -> &str {
                "failing"
            }
            fn geometry(&self) -> (usize, usize) {
                self.inner.geometry()
            }
            fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
                if self.emitted >= self.after {
                    return Err(IngestError::fatal("sensor unplugged"));
                }
                self.emitted += 1;
                self.inner.next_request()
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let source = FailingSource {
            inner: SyntheticSource::new(profile, 100, 3),
            after: 4,
            emitted: 0,
        };
        let cfg = ServerConfig { workers: 2, ..Default::default() };
        let err = run_server_source(Box::new(source), &backend, &cfg).unwrap_err();
        assert!(err.msg.contains("sensor unplugged"), "msg: {}", err.msg);
        assert_eq!(err.completed, 4, "the admitted prefix is served before the abort");
        assert_eq!(err.in_flight, 0);
    }

    /// Regression (one bad sample must not kill the run): recoverable
    /// source rejects are skipped and counted — globally and on the
    /// default tenant — while every good sample is still served.
    #[test]
    fn recoverable_source_rejects_are_counted_not_fatal() {
        use crate::coordinator::ingest::{IngestError, SourcedRequest};
        struct FlakySource {
            inner: SyntheticSource,
            emitted: usize,
        }
        impl EventSource for FlakySource {
            fn name(&self) -> &str {
                "flaky"
            }
            fn geometry(&self) -> (usize, usize) {
                self.inner.geometry()
            }
            fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
                self.emitted += 1;
                // Every third pull hits a bad sample the reader skipped.
                if self.emitted % 3 == 0 {
                    return Err(IngestError::recoverable("events not sorted"));
                }
                self.inner.next_request()
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let source = FlakySource { inner: SyntheticSource::new(profile, 8, 3), emitted: 0 };
        let cfg = ServerConfig { workers: 2, ..Default::default() };
        let r = run_server_source(Box::new(source), &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 8, "every good sample is still served");
        assert_eq!(r.metrics.ingest_rejects, 4, "8 good pulls + terminal None ⇒ 4 rejects");
        assert_eq!(r.metrics.per_tenant.len(), 1, "implicit default tenant");
        let t = &r.metrics.per_tenant[0];
        assert_eq!(t.tenant, "default");
        assert_eq!(t.ingest_rejects, 4, "single-tenant rejects land on the default tenant");
        assert_eq!(t.served, 8);
        assert_eq!(t.offered(), 12, "served + rejects reconstruct the stream");
    }

    /// Two tenants with distinct SLOs: each request's deadline follows its
    /// tenant's override, and the per-tenant books balance independently.
    #[test]
    fn per_tenant_slo_overrides_global() {
        use crate::coordinator::ingest::{IngestError, SourcedRequest};
        // Tenant 0 gets an impossible (zero) SLO, tenant 1 a generous one;
        // no global SLO at all.
        struct TwoTenantSource {
            inner: SyntheticSource,
            emitted: usize,
            n: usize,
        }
        impl EventSource for TwoTenantSource {
            fn name(&self) -> &str {
                "two-tenant"
            }
            fn geometry(&self) -> (usize, usize) {
                self.inner.geometry()
            }
            fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
                if self.emitted >= self.n {
                    return Ok(None);
                }
                let tenant = self.emitted % 2;
                self.emitted += 1;
                Ok(self.inner.next_request()?.map(|mut sr| {
                    sr.tenant = tenant;
                    sr
                }))
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let source =
            TwoTenantSource { inner: SyntheticSource::new(profile, 100, 7), emitted: 0, n: 10 };
        let cfg = ServerConfig {
            workers: 2,
            // Deep enough that each tenant's quota (depth/2) exceeds its 5
            // requests — no quota drop can race the assertions below.
            queue_depth: 16,
            tenants: vec![
                TenantConfig::new("strict", 1).with_slo(Duration::ZERO),
                TenantConfig::new("lax", 1).with_slo(Duration::from_secs(60)),
            ],
            ..Default::default()
        };
        let r = run_server_source(Box::new(source), &backend, &cfg).unwrap();
        assert_eq!(r.metrics.per_tenant.len(), 2);
        let strict = &r.metrics.per_tenant[0];
        let lax = &r.metrics.per_tenant[1];
        assert_eq!(strict.served, 0, "zero SLO expires everything at the ingress");
        assert_eq!(strict.deadline_ingress, 5);
        assert_eq!(strict.slo_attainment(), Some(0.0));
        assert_eq!(lax.served, 5);
        assert_eq!(lax.slo_attainment(), Some(1.0));
        for t in [strict, lax] {
            assert_eq!(t.offered(), 5, "each tenant's books reconstruct its stream");
        }
        // Global books are the per-tenant sums.
        assert_eq!(r.metrics.total, 5);
        assert_eq!(r.metrics.deadline_ingress, 5);
        assert_eq!(r.metrics.deadline_offered, 10);
    }
}
