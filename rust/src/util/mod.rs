//! Small in-tree utilities.
//!
//! The build environment is offline and only vendors the `xla` crate
//! closure, so the usual ecosystem crates (rand, serde, proptest, clap,
//! criterion) are unavailable. This module provides the minimal, tested
//! replacements the rest of the crate needs:
//!
//! - [`rng`]: a splitmix64/xoshiro256** PRNG (deterministic, seedable, and
//!   implemented identically in `python/compile/data.py` so the two halves
//!   of the build generate the same synthetic datasets).
//! - [`propcheck`]: a tiny property-based testing harness with case
//!   generation and failure reporting.
//! - [`json`]: a minimal JSON value model + parser + writer, used for the
//!   artifact metadata exchanged with the python compile path.
//! - [`cli`]: flag parsing for the `esda` binary and the examples.
//! - [`stats`]: summary statistics and timing helpers shared by the benches.
//! - [`alloc`]: a counting global-allocator wrapper that proves the
//!   zero-allocation steady state of the arena execution engine.
//! - [`lockcheck`]: debug-build ranked mutex/condvar wrappers asserting
//!   per-thread lock-rank monotonicity (the dynamic half of the static
//!   `lock-order` lint).
pub mod rng;
pub mod propcheck;
pub mod json;
pub mod cli;
pub mod stats;
pub mod alloc;
pub mod lockcheck;

pub use rng::Rng;

/// Best-effort extraction of a caught panic payload's message (the
/// `String`/`&str` cases `panic!` produces). Shared by the propcheck
/// harness and the serving runtime's worker-panic surfacing.
pub fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    e.downcast_ref::<String>()
        .cloned()
        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}
