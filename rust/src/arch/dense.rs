//! Dense sliding-window dataflow baseline — the comparator of Fig. 13.
//!
//! The paper's dense baseline "eliminates all token stream interfaces and
//! dynamic logic components, maintaining identical parallel factors,
//! bitwidths, and other design parameters". A dense line-buffer pipeline
//! is *deterministic*: every spatial position is processed, every kernel
//! offset is multiplied, so its latency has a closed form — which is what
//! this module provides (cycle-exact for a deterministic pipeline; no
//! discrete-event simulation needed).
//!
//! Per-module initiation intervals (cycles per output position), mirroring
//! the sparse modules' PE models with S_s = S_k = 1:
//! - 1×1 conv: `ceil(cin·cout / pf)`
//! - k×k depthwise: `k² · ceil(c / pf)`
//! - k×k full: `k² · ceil(cin·cout / pf)`
//! - fork/add: 1
//!
//! A pipelined dataflow block processes `H·W` positions at the II of its
//! slowest module, plus a fill latency of the sum of the others.

use super::module::pe_cycles;
use crate::model::graph::Op;

/// Cycles per *output position* for a dense implementation of `op` at
/// parallel factor `pf`.
pub fn dense_ii(op: &Op, pf: usize) -> u64 {
    match *op {
        Op::Conv1x1 { cin, cout, .. } => pe_cycles(cin * cout, pf).max(1),
        Op::ConvKxK { k, cin, cout, .. } => (k * k) as u64 * pe_cycles(cin * cout, pf).max(1),
        Op::DwConv { k, c, .. } => (k * k) as u64 * pe_cycles(c, pf).max(1),
        Op::ResFork | Op::ResAdd => 1,
        Op::GlobalPool { .. } => 1,
        Op::Fc { cin, cout } => pe_cycles(cin * cout, pf).max(1),
    }
}

/// Dense-pipeline latency for a chain of ops over an input of `w × h`
/// (each op sees the resolution after upstream strides):
/// `positions(bottleneck) · II(bottleneck) + Σ_other II` (fill).
pub fn dense_chain_latency(ops: &[Op], pfs: &[usize], w: usize, h: usize) -> u64 {
    assert_eq!(ops.len(), pfs.len());
    let (mut cw, mut ch) = (w, h);
    let mut stage: Vec<u64> = Vec::new(); // total cycles per module
    let mut fill: u64 = 0;
    for (op, &pf) in ops.iter().zip(pfs) {
        let ii = dense_ii(op, pf);
        fill += ii;
        // A strided line buffer consumes every input position (1 beat/cycle)
        // but *computes* only at output positions — the module is bound by
        // the slower of ingest and compute.
        let (ow, oh) = if op.stride() == 2 { ((cw + 1) / 2, (ch + 1) / 2) } else { (cw, ch) };
        let compute = match op {
            Op::Fc { .. } => ii,
            _ => (ow * oh) as u64 * ii,
        };
        let ingest = (cw * ch) as u64;
        stage.push(compute.max(ingest));
        if op.stride() == 2 {
            cw = ow;
            ch = oh;
        }
    }
    let total_max = stage.iter().copied().max().unwrap_or(0);
    total_max + fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::Act;

    #[test]
    fn ii_matches_hand_calc() {
        let dw = Op::DwConv { k: 3, c: 16, stride: 1, act: Act::Relu6 };
        assert_eq!(dense_ii(&dw, 4), 9 * 4); // 9 offsets × ceil(16/4)
        assert_eq!(dense_ii(&dw, 16), 9);
        let pw = Op::Conv1x1 { cin: 16, cout: 32, act: Act::Relu6 };
        assert_eq!(dense_ii(&pw, 16), 32);
    }

    #[test]
    fn chain_latency_bottleneck_dominated() {
        let ops = vec![
            Op::Conv1x1 { cin: 8, cout: 16, act: Act::Relu6 }, // II 8 @pf16
            Op::DwConv { k: 3, c: 16, stride: 1, act: Act::Relu6 }, // II 9 @pf16
            Op::Conv1x1 { cin: 16, cout: 8, act: Act::None },  // II 8 @pf16
        ];
        let pfs = vec![16, 16, 16];
        let lat = dense_chain_latency(&ops, &pfs, 10, 10);
        // bottleneck: dw 100 pos × 9 = 900; fill 8+9+8 = 25
        assert_eq!(lat, 925);
    }

    #[test]
    fn stride_halves_downstream_positions() {
        let ops = vec![
            Op::DwConv { k: 3, c: 8, stride: 2, act: Act::Relu6 }, // 25 compute pos
            Op::Conv1x1 { cin: 8, cout: 8, act: Act::None },       // 25 pos
        ];
        let pfs = vec![8, 1];
        let lat = dense_chain_latency(&ops, &pfs, 10, 10);
        // dw: max(25·9, 100 ingest)=225 ; 1x1: 25·64=1600 → 1600 + fill (9+64)
        assert_eq!(lat, 1600 + 73);
    }

    #[test]
    fn ingest_bound_when_compute_cheap() {
        // Stride-2 with huge PF: compute per output is 9 cycles over 25
        // outputs (225) but the line buffer still ingests 400 inputs.
        let ops = vec![Op::DwConv { k: 3, c: 8, stride: 2, act: Act::Relu6 }];
        let lat = dense_chain_latency(&ops, &[8], 20, 20);
        assert_eq!(lat, 400.max(100 * 9) + 9);
    }
}
