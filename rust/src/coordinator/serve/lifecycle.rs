//! The serving spine: build the run-wide state (tenant, model, and class
//! tables, sticky context, shadow writer), spawn the stage threads,
//! join them in dependency order, and roll every book into the merged
//! [`Metrics`].

use super::ingress::{pump_source, repr_stage};
use super::router::router_stage;
use super::scaler::run_autoscaler;
use super::state::{
    join_noting, BackendRef, ClassCtx, ClassSlots, IngressBooks, ModelCtx, Routed, ShadowCtx,
    ShadowWriter, SharedCtx, StickyCtx, TenantCtx, WorkerOutput,
};
use super::workers::worker_loop;
use super::{PipelineError, Prediction, ServerConfig, ServerResult};
use crate::coordinator::ingest::{EventSource, SourcedRequest};
use crate::coordinator::metrics::{
    ClassStats, CostModel, CostProfile, Metrics, ModelStats, PercentileReport, ScalingEvent,
    TenantStats, WorkerStats,
};
use crate::coordinator::lock_ranks;
use crate::coordinator::queue::{AdmissionQueue, DropPolicy};
use crate::util::lockcheck::{RankedCondvar, RankedMutex};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::time::Instant;

/// The shared serving spine behind every entry point.
pub(super) fn serve_classes(
    source: Box<dyn EventSource>,
    slots: Vec<ClassSlots<'_>>,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!slots.is_empty(), "need at least one replica class");
    assert!(
        slots.iter().all(|c| !c.backends.is_empty()),
        "every replica class needs at least one worker"
    );
    let t_start = Instant::now();
    // With a single class there is nothing to route: workers drain the
    // ingress directly (no router thread, no cost-model locks), which also
    // preserves the exact drop-oldest semantics the homogeneous runtime
    // always had — the stalest *queued* request is the one evicted.
    let has_router = slots.len() > 1;
    let ingress: AdmissionQueue<Routed> = AdmissionQueue::new(cfg.queue_depth, cfg.drop_policy);
    // Tenant table: the configured tenants, or a single implicit default
    // whose quota is the whole queue — the front door stays inert and
    // single-tenant admission semantics are exactly the pre-tenant ones.
    let depth = cfg.queue_depth.max(1);
    let multi_tenant = cfg.tenants.len() > 1;
    let total_weight: usize =
        cfg.tenants.iter().map(|t| t.weight.max(1)).sum::<usize>().max(1);
    let tenants: Vec<TenantCtx> = if cfg.tenants.is_empty() {
        vec![TenantCtx::new("default".to_string(), 1, None, depth)]
    } else {
        cfg.tenants
            .iter()
            .map(|t| {
                let weight = t.weight.max(1);
                // Floor-share quotas keep Σ quotas ≤ depth (short of the
                // min-1 floor with many tiny tenants), so an under-quota
                // arrival finds a free slot instead of blocking on other
                // tenants' traffic.
                let quota =
                    if multi_tenant { (depth * weight / total_weight).max(1) } else { depth };
                TenantCtx::new(t.name.clone(), weight, t.slo, quota)
            })
            .collect()
    };
    // Model table: one entry per distinct class model tag, in order of
    // first appearance (the fleet CLI builds one class per `--model`
    // entry, so model id i is entry i). Single-model runs get exactly one
    // implicit entry under the default tag, and every per-model book
    // degenerates to the global one.
    let mut model_names: Vec<String> = Vec::new();
    for c in &slots {
        if !model_names.iter().any(|n| *n == c.model) {
            model_names.push(c.model.clone());
        }
    }
    let (w, h) = source.geometry();
    // Shadow capture: one shared writer across every shadowed model (a
    // single `--shadow-capture` path per run), created only when some
    // shadow exists to feed it. A writer that cannot even be created is
    // a configuration error worth failing the run for — silently
    // dropping every capture would defeat the point of asking for one.
    let capture = match (&cfg.shadow_capture, cfg.shadows.is_empty()) {
        (Some(sc), false) => match ShadowWriter::create(&sc.path, w, h, sc.max_samples) {
            Ok(wtr) => {
                let mx =
                    RankedMutex::new(lock_ranks::SHADOW_CAPTURE, "shadow-capture", Some(wtr));
                Some(Arc::new(mx))
            }
            Err(e) => {
                return Err(PipelineError {
                    msg: format!("shadow capture {}: {e}", sc.path.display()),
                    completed: 0,
                    in_flight: 0,
                    dropped: 0,
                })
            }
        },
        _ => None,
    };
    let models: Vec<ModelCtx> = model_names
        .iter()
        .map(|name| {
            let shadow = cfg.shadows.iter().find(|s| s.model == *name).map(|s| ShadowCtx {
                candidate: Arc::clone(&s.candidate),
                fraction: s.fraction.clamp(0.0, 1.0),
                counter: AtomicUsize::new(0),
                mirrored: AtomicUsize::new(0),
                disagreements: AtomicUsize::new(0),
                capture_drops: AtomicUsize::new(0),
                capture: capture.clone(),
            });
            ModelCtx::new(name.clone(), shadow)
        })
        .collect();
    // Raw events ride along to the worker only for models whose shadow
    // can land them in the capture file.
    let capture_armed: Vec<bool> = models
        .iter()
        .map(|m| m.shadow.as_ref().is_some_and(|s| s.capture.is_some()))
        .collect();
    let classes: Vec<ClassCtx<'_>> = slots
        .into_iter()
        .map(|c| {
            let min = c.backends.len();
            let cost = CostModel::new();
            // Seed the predictor from a previous run's persisted profile:
            // the class routes and SLO-sheds from its first request
            // instead of burning probe traffic, and replicas the
            // autoscaler grows later join a class that already knows its
            // costs.
            if let Some(profile) = &cfg.cost_profile {
                if let Some(snap) = profile.classes.get(&c.name) {
                    // Aged knowledge decays before it seeds: stale buckets
                    // (and, much later, the global mean) are dropped so a
                    // profile from last week cannot mis-route or mis-shed
                    // today's traffic (see [`CostSnapshot::decayed`]).
                    cost.seed(&snap.decayed(profile.age_secs()));
                }
            }
            let model = model_names.iter().position(|n| *n == c.model).unwrap_or(0);
            ClassCtx {
                // Sub-queues always block: admission control (and its drop
                // accounting) lives at the global ingress only. A full
                // sub-queue back-pressures the router, which lets the ingress
                // saturate, where the shedding decision is made and counted.
                // (Trade-off vs the single-class path: requests already routed
                // into a sub-queue are no longer evictable by drop-oldest —
                // though a deadline can still expire them at the worker pop.)
                queue: AdmissionQueue::new(cfg.queue_depth, DropPolicy::Block),
                backlog: AtomicUsize::new(0),
                cost,
                deadline_drops: AtomicUsize::new(0),
                busy_us: AtomicU64::new(0),
                active: AtomicUsize::new(min),
                peak: AtomicUsize::new(min),
                retire: AtomicUsize::new(0),
                min,
                max: c.max.max(min),
                grow: c.grow,
                slots: RankedMutex::new(lock_ranks::CLASS_SLOTS, "class-slots", c.backends),
                name: c.name,
                model,
                batch: c.batch.max(1),
            }
        })
        .collect();
    // Sticky (cache-affinity) routing exists only when a router makes
    // placement decisions AND some class can actually reuse per-stream
    // state. Declared before the thread scope so the router, workers,
    // and autoscaler all borrow one context.
    let any_delta = classes
        .iter()
        .any(|c| c.slots.lock().unwrap().iter().any(|b| b.get().supports_delta()));
    let sticky_ctx = (has_router && any_delta).then(StickyCtx::new);
    // lint: lock-rank(10): first-error
    let first_error = RankedMutex::new(lock_ranks::FIRST_ERROR, "first-error", None);
    let books = IngressBooks::new();
    // Worker outputs land here (workers push at exit rather than being
    // joined for a return value, because the autoscaler spawns workers
    // the spine never held handles for).
    // lint: lock-rank(45): worker-outputs
    let outputs_mx =
        RankedMutex::new(lock_ranks::WORKER_OUTPUTS, "worker-outputs", Vec::new());
    // lint: lock-rank(41): scaling-events
    let scaling_events =
        RankedMutex::new(lock_ranks::SCALING_EVENTS, "scaling-events", Vec::new());
    // Autoscaler shutdown latch: flag + condvar so the controller can be
    // woken mid-sleep once the stream has fully drained.
    // lint: lock-rank(50): scaler-stop
    let scaler_stop = (
        RankedMutex::new(lock_ranks::SCALER_STOP, "scaler-stop", false),
        RankedCondvar::new(),
    );
    // lint: atomic(relaxed): fetch_add id mint — uniqueness needs no order
    let next_wid = AtomicUsize::new(classes.iter().map(|c| c.min).sum());
    let (tx_ev, rx_ev) = sync_channel::<SourcedRequest>(cfg.queue_depth.max(1));
    // Every stage borrows the same run-wide context.
    let shared = SharedCtx {
        classes: &classes,
        tenants: &tenants,
        models: &models,
        ingress: &ingress,
        sticky: sticky_ctx.as_ref(),
        first_error: &first_error,
    };

    std::thread::scope(|s| {
        let sx = &shared;
        let books_ref = &books;
        let armed_ref: &[bool] = &capture_armed;

        // Stage 1: the event source.
        let src_thread = s.spawn(move || pump_source(source, tx_ev, books_ref, sx));

        // Stage 2: representation builder + admission control.
        let (clip, slo) = (cfg.clip, cfg.slo);
        let repr =
            s.spawn(move || repr_stage(rx_ev, (w, h), clip, slo, armed_ref, books_ref, sx));

        // Stage 3: the cost-aware router — only spawned when there is a
        // routing decision to make.
        let router = has_router.then(|| s.spawn(move || router_stage(sx)));

        // Stage 4: per-class accelerator worker pools — the base (min)
        // replicas; the autoscaler below may spawn more into this scope.
        let outputs_mx = &outputs_mx;
        let mut handles = Vec::new();
        let mut base_wid = 0usize;
        for (ci, class) in classes.iter().enumerate() {
            let base: Vec<BackendRef<'_>> = class.slots.lock().unwrap().clone();
            for backend in base {
                let wid = base_wid;
                base_wid += 1;
                // Delta-capable workers under a router own a bounded side
                // queue for requests pinned to them by stream affinity.
                let side = sx.sticky.and_then(|sc| {
                    backend.get().supports_delta().then(|| {
                        let q = Arc::new(AdmissionQueue::new(depth, DropPolicy::Block));
                        sc.enroll(wid, ci, &q);
                        q
                    })
                });
                handles.push(s.spawn(move || {
                    let queue = if has_router { &class.queue } else { sx.ingress };
                    let out =
                        worker_loop(wid, ci, class, queue, has_router, backend.get(), side, sx);
                    outputs_mx.lock().unwrap().push(out);
                }));
            }
        }

        // Stage 5: the autoscaler controller. Spawned only when it could
        // ever act — autoscaling requested AND some class has headroom.
        let stop_ref = &scaler_stop;
        let events_ref = &scaling_events;
        let next_wid_ref = &next_wid;
        let scalable = classes.iter().any(|c| c.max > c.min);
        let controller = cfg.autoscale.clone().filter(|_| scalable).map(|auto| {
            s.spawn(move || {
                run_autoscaler(
                    &auto, s, sx, has_router, t_start, stop_ref, events_ref, next_wid_ref,
                    outputs_mx, depth,
                )
            })
        });

        for h in handles {
            join_noting(h.join(), "worker", &first_error);
        }
        if let Some(h) = router {
            join_noting(h.join(), "router", &first_error);
        }
        join_noting(repr.join(), "repr", &first_error);
        join_noting(src_thread.join(), "source", &first_error);
        // The stream has drained: stop the controller. Workers it spawned
        // exit on their own (queues are closed) and are joined by the
        // scope before `outputs_mx` is read below.
        {
            // lint: lock-rank(50): scaler-stop
            let (stop_mx, stop_cv) = &scaler_stop;
            *stop_mx.lock().unwrap() = true;
            stop_cv.notify_all();
        }
        if let Some(h) = controller {
            join_noting(h.join(), "autoscaler", &first_error);
        }
    });

    // Finalize the shadow capture: rewrite the header's sample count with
    // what was actually appended. Best-effort — a capture that cannot
    // update its header still holds its samples, and the run result (and
    // its disagreement books) stand either way.
    if let Some(capture) = &capture {
        if let Some(wtr) = capture.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = wtr.finalize();
        }
    }

    // Poisoning is survivable here: a panicking worker was already noted
    // in `first_error` by `join_noting`, so take whatever was recorded.
    let mut outputs = outputs_mx.into_inner().unwrap_or_else(|e| e.into_inner());
    outputs.sort_by_key(|o| o.wid);
    let (submitted, dropped, _still_queued) = ingress.stats();
    let processed: usize = outputs.iter().map(|o| o.records.len()).sum();
    // Deadline sheds past admission (router + worker pop) — these were
    // submitted but intentionally never classified.
    // Relaxed loads throughout finalization: the thread scope has joined,
    // so every stage write happens-before these reads regardless of order.
    let deadline_shed: usize =
        classes.iter().map(|c| c.deadline_drops.load(Ordering::Relaxed)).sum();
    let in_flight = submitted.saturating_sub(dropped + processed + deadline_shed);
    // Admission sheds: queue evictions plus over-quota drops (the latter
    // never occupied a slot, so they are outside the queue's own books).
    let shed = dropped + books.quota_drops.load(Ordering::Relaxed);

    if let Some(msg) = first_error.into_inner().unwrap_or_else(|e| e.into_inner()) {
        return Err(PipelineError { msg, completed: processed, in_flight, dropped: shed });
    }
    // Clean completion conserves requests: everything admitted was either
    // served, dropped, or shed on deadline (stranded requests only exist
    // on the Err path).
    debug_assert_eq!(in_flight, 0, "completed run stranded {in_flight} request(s)");

    let wall_s = t_start.elapsed().as_secs_f64();
    let mut metrics = Metrics {
        started: t_start,
        dropped: shed,
        wall_s,
        deadline_offered: books.deadline_offered.load(Ordering::Relaxed),
        deadline_ingress: books.deadline_ingress.load(Ordering::Relaxed),
        deadline_router: deadline_shed,
        ingest_rejects: books.ingest_rejects.load(Ordering::Relaxed),
        scaling_events: scaling_events.into_inner().unwrap_or_else(|e| e.into_inner()),
        // What `--cost-profile` rewrites at shutdown: every class's final
        // EWMA state (seeded knowledge + everything learned this run).
        cost_profile: CostProfile {
            classes: classes.iter().map(|c| (c.name.clone(), c.cost.snapshot())).collect(),
            // Stamped by `CostProfile::save` at write time, not here.
            saved_unix: None,
        },
        ..Metrics::default()
    };
    // Delta/sticky books: per-worker tallies merge; the router's sticky
    // counters come straight from the shared context.
    for o in &outputs {
        metrics.delta.merge(&o.delta);
    }
    if let Some(sc) = &sticky_ctx {
        metrics.delta.sticky_hits = sc.hits.load(Ordering::Relaxed);
        metrics.delta.sticky_cold = sc.miss_cold.load(Ordering::Relaxed);
        metrics.delta.sticky_retired = sc.miss_retired.load(Ordering::Relaxed);
        metrics.delta.sticky_capacity = sc.miss_capacity.load(Ordering::Relaxed);
    }
    let mut predictions = Vec::with_capacity(processed);
    let mut t_served = vec![0usize; tenants.len()];
    let mut t_met = vec![0usize; tenants.len()];
    let mut t_missed = vec![0usize; tenants.len()];
    let mut m_served = vec![0usize; models.len()];
    let mut m_correct = vec![0usize; models.len()];
    for o in &outputs {
        let service: Vec<f64> = o.records.iter().map(|r| r.timing.service_s).collect();
        let e2e: Vec<f64> = o.records.iter().map(|r| r.timing.e2e_s).collect();
        let batches: Vec<f64> = o.batch_sizes.iter().map(|&b| b as f64).collect();
        metrics.per_worker.push(WorkerStats {
            worker: o.wid,
            class: classes[o.class].name.clone(),
            served: o.records.len(),
            batches: o.batch_sizes.len(),
            busy_s: o.busy_s,
            service: PercentileReport::from_samples(&service),
            e2e: PercentileReport::from_samples(&e2e),
            batch: PercentileReport::from_samples(&batches),
        });
        metrics.batch_sizes.extend_from_slice(&o.batch_sizes);
        for r in &o.records {
            let correct = r.pred == r.label;
            metrics.record(r.timing, correct);
            t_served[r.tenant] += 1;
            m_served[r.model] += 1;
            if correct {
                m_correct[r.model] += 1;
            }
            match r.met_deadline {
                Some(true) => {
                    metrics.deadline_met += 1;
                    t_met[r.tenant] += 1;
                }
                Some(false) => {
                    metrics.deadline_missed += 1;
                    t_missed[r.tenant] += 1;
                }
                None => {}
            }
            predictions.push(Prediction { label: r.label, pred: r.pred, worker: o.wid });
        }
    }
    // Per-tenant rollup: the books the stage threads kept, plus served /
    // met / missed tallied from the records above.
    metrics.per_tenant = tenants
        .iter()
        .enumerate()
        .map(|(i, tc)| TenantStats {
            tenant: tc.name.clone(),
            weight: tc.weight,
            quota: tc.quota,
            served: t_served[i],
            dropped: tc.dropped.load(Ordering::Relaxed),
            deadline_offered: tc.deadline_offered.load(Ordering::Relaxed),
            deadline_ingress: tc.deadline_ingress.load(Ordering::Relaxed),
            deadline_router: tc.deadline_router.load(Ordering::Relaxed),
            deadline_met: t_met[i],
            deadline_missed: t_missed[i],
            ingest_rejects: tc.ingest_rejects.load(Ordering::Relaxed),
        })
        .collect();
    // Per-model rollup: the fleet books. Every run gets one (a
    // single-model run's row restates the global books); each row
    // satisfies offered = served + dropped + deadline drops, the same
    // conservation identity the tenant books carry. Shadow mirrors are
    // deliberately absent from `served` — mirrored traffic is an
    // observation, not service.
    metrics.per_model = models
        .iter()
        .enumerate()
        .map(|(i, mc)| ModelStats {
            model: mc.name.clone(),
            classes: classes.iter().filter(|c| c.model == i).count(),
            served: m_served[i],
            correct: m_correct[i],
            dropped: mc.dropped.load(Ordering::Relaxed),
            deadline_offered: mc.deadline_offered.load(Ordering::Relaxed),
            deadline_ingress: mc.deadline_ingress.load(Ordering::Relaxed),
            deadline_router: mc.deadline_router.load(Ordering::Relaxed),
            shadow_mirrored: mc.shadow.as_ref().map_or(0, |s| s.mirrored.load(Ordering::Relaxed)),
            shadow_disagreements: mc
                .shadow
                .as_ref()
                .map_or(0, |s| s.disagreements.load(Ordering::Relaxed)),
            shadow_capture_drops: mc
                .shadow
                .as_ref()
                .map_or(0, |s| s.capture_drops.load(Ordering::Relaxed)),
        })
        .collect();
    // Integrated active-replica seconds per class, reconstructed from the
    // scaling log: the truthful utilization denominator when the
    // autoscaler moved the count mid-run (a run that mostly served at 4
    // replicas but ended at 1 must not divide by 1 × wall).
    let replica_secs: Vec<f64> = classes
        .iter()
        .map(|class| {
            let mut level = class.min as f64;
            let mut t_prev = 0.0f64;
            let mut integral = 0.0f64;
            for e in metrics.scaling_events.iter().filter(|e| e.class == class.name) {
                let t = e.at_s.clamp(0.0, wall_s);
                integral += level * (t - t_prev).max(0.0);
                t_prev = t;
                level = e.to as f64;
            }
            integral + level * (wall_s - t_prev).max(0.0)
        })
        .collect();
    // Per-class rollup: served/visit/busy books plus how well the routing
    // predictor tracked observed service times.
    for (ci, class) in classes.iter().enumerate() {
        let mut served = 0usize;
        let mut batches = 0usize;
        let mut busy_s = 0.0f64;
        let mut service: Vec<f64> = Vec::new();
        let mut batch_f: Vec<f64> = Vec::new();
        let mut err_sum = 0.0f64;
        let mut err_n = 0usize;
        let mut unseeded = 0usize;
        for o in outputs.iter().filter(|o| o.class == ci) {
            served += o.records.len();
            batches += o.batch_sizes.len();
            busy_s += o.busy_s;
            batch_f.extend(o.batch_sizes.iter().map(|&b| b as f64));
            for r in &o.records {
                service.push(r.timing.service_s);
                if r.predicted_s.is_finite() {
                    err_sum += (r.predicted_s - r.timing.service_s).abs()
                        / r.timing.service_s.max(1e-9);
                    err_n += 1;
                } else if has_router && !r.sticky {
                    // Probe traffic: routed before this class's cost model
                    // had an observation. (Without a router no prediction
                    // is ever attempted, and a sticky delivery's NaN is by
                    // design — neither counts as a probe.)
                    unseeded += 1;
                }
            }
        }
        metrics.per_class.push(ClassStats {
            class: class.name.clone(),
            replicas: class.active.load(Ordering::SeqCst),
            replicas_min: class.min,
            replicas_max: class.max,
            replicas_peak: class.peak.load(Ordering::Relaxed),
            replica_s: replica_secs[ci],
            served,
            batches,
            busy_s,
            batch: PercentileReport::from_samples(&batch_f),
            service: PercentileReport::from_samples(&service),
            cost_err: if err_n > 0 { err_sum / err_n as f64 } else { f64::NAN },
            unseeded,
            deadline_drops: class.deadline_drops.load(Ordering::Relaxed),
        });
    }
    Ok(ServerResult { metrics, predictions })
}
