//! Small in-tree utilities.
//!
//! The build environment is offline and only vendors the `xla` crate
//! closure, so the usual ecosystem crates (rand, serde, proptest, clap,
//! criterion) are unavailable. This module provides the minimal, tested
//! replacements the rest of the crate needs:
//!
//! - [`rng`]: a splitmix64/xoshiro256** PRNG (deterministic, seedable, and
//!   implemented identically in `python/compile/data.py` so the two halves
//!   of the build generate the same synthetic datasets).
//! - [`propcheck`]: a tiny property-based testing harness with case
//!   generation and failure reporting.
//! - [`json`]: a minimal JSON value model + parser + writer, used for the
//!   artifact metadata exchanged with the python compile path.
//! - [`cli`]: flag parsing for the `esda` binary and the examples.
//! - [`stats`]: summary statistics and timing helpers shared by the benches.
pub mod rng;
pub mod propcheck;
pub mod json;
pub mod cli;
pub mod stats;

pub use rng::Rng;
