//! Network architecture description: blocks → primitive op program.

pub use crate::sparse::conv::Act;

/// High-level building blocks (what the NAS samples and the paper's Fig. 10
/// chains together).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Block {
    /// Stem: full k×k convolution on the 2-channel input representation.
    Stem { k: usize, cout: usize, stride: usize },
    /// Inverted residual (MobileNetV2 MBConv): 1×1 expand (ReLU6) →
    /// k×k depthwise (ReLU6, stride s) → 1×1 project (linear);
    /// identity shortcut iff `stride == 1 && cin == cout`.
    MBConv { cout: usize, expand: usize, k: usize, stride: usize },
    /// Plain 1×1 conv (channel mixer, e.g. before the head).
    Conv1x1 { cout: usize, act: Act },
    /// Global average pool over tokens + fully-connected classifier.
    PoolFc,
}

/// Primitive ops — the flat program the executor / simulator / optimizer
/// all consume. Channel sizes are resolved (no "expand ratios" here).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Conv1x1 { cin: usize, cout: usize, act: Act },
    /// Full k×k conv (stride 1 = submanifold; stride 2 = sparse downsample).
    ConvKxK { k: usize, cin: usize, cout: usize, stride: usize, act: Act },
    /// Depthwise k×k conv.
    DwConv { k: usize, c: usize, stride: usize, act: Act },
    /// Fork the stream for an identity shortcut (pushes a copy).
    ResFork,
    /// Join: add the top two streams (tokens identical by submanifold
    /// construction).
    ResAdd,
    GlobalPool { c: usize },
    Fc { cin: usize, cout: usize },
}

impl Op {
    /// Does this op carry weights?
    pub fn has_weights(&self) -> bool {
        matches!(self, Op::Conv1x1 { .. } | Op::ConvKxK { .. } | Op::DwConv { .. } | Op::Fc { .. })
    }

    /// Output channels given input channels (for shape checking).
    pub fn cout(&self) -> Option<usize> {
        match self {
            Op::Conv1x1 { cout, .. } | Op::ConvKxK { cout, .. } | Op::Fc { cout, .. } => {
                Some(*cout)
            }
            Op::DwConv { c, .. } | Op::GlobalPool { c } => Some(*c),
            Op::ResFork | Op::ResAdd => None,
        }
    }

    /// Spatial stride of the op (1 for non-spatial ops).
    pub fn stride(&self) -> usize {
        match self {
            Op::ConvKxK { stride, .. } | Op::DwConv { stride, .. } => *stride,
            _ => 1,
        }
    }

    /// Weight element count (int8 path; bias excluded).
    pub fn weight_count(&self) -> usize {
        match self {
            Op::Conv1x1 { cin, cout, .. } => cin * cout,
            Op::ConvKxK { k, cin, cout, .. } => k * k * cin * cout,
            Op::DwConv { k, c, .. } => k * k * c,
            Op::Fc { cin, cout } => cin * cout,
            _ => 0,
        }
    }
}

/// A complete network: input geometry + blocks + classifier width.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    pub w: usize,
    pub h: usize,
    pub cin: usize,
    pub n_classes: usize,
    pub blocks: Vec<Block>,
}

impl NetworkSpec {
    /// Expand blocks into the primitive op program, checking shapes.
    pub fn ops(&self) -> Vec<Op> {
        let mut ops = Vec::new();
        let mut c = self.cin;
        for b in &self.blocks {
            match *b {
                Block::Stem { k, cout, stride } => {
                    ops.push(Op::ConvKxK { k, cin: c, cout, stride, act: Act::Relu6 });
                    c = cout;
                }
                Block::MBConv { cout, expand, k, stride } => {
                    let residual = stride == 1 && c == cout;
                    let ce = c * expand;
                    if residual {
                        ops.push(Op::ResFork);
                    }
                    if expand != 1 {
                        ops.push(Op::Conv1x1 { cin: c, cout: ce, act: Act::Relu6 });
                    }
                    ops.push(Op::DwConv { k, c: ce, stride, act: Act::Relu6 });
                    ops.push(Op::Conv1x1 { cin: ce, cout, act: Act::None });
                    if residual {
                        ops.push(Op::ResAdd);
                    }
                    c = cout;
                }
                Block::Conv1x1 { cout, act } => {
                    ops.push(Op::Conv1x1 { cin: c, cout, act });
                    c = cout;
                }
                Block::PoolFc => {
                    ops.push(Op::GlobalPool { c });
                    ops.push(Op::Fc { cin: c, cout: self.n_classes });
                }
            }
        }
        ops
    }

    /// Per-op input spatial size (w, h), following stride-2 downsamples.
    pub fn op_resolutions(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let (mut w, mut h) = (self.w, self.h);
        for op in self.ops() {
            out.push((w, h));
            if op.stride() == 2 {
                w = (w + 1) / 2;
                h = (h + 1) / 2;
            }
        }
        out
    }

    /// Total downsampling factor (product of strides).
    pub fn total_downsample(&self) -> usize {
        self.ops().iter().map(|o| o.stride()).product()
    }

    /// Total weight parameters (conv + fc).
    pub fn param_count(&self) -> usize {
        let ops = self.ops();
        let mut n = 0;
        for op in &ops {
            n += op.weight_count();
            if let Some(co) = op.cout() {
                if op.has_weights() {
                    n += co; // bias
                }
            }
        }
        n
    }

    /// MobileNetV2 with width multiplier 0.5 — the paper's fixed baseline
    /// model (§4.4, Table 1). Channel ladder follows the MobileNetV2 paper
    /// scaled by 0.5 (min 8, multiples of 8 where possible); the 34×34-class
    /// datasets use [`NetworkSpec::compact`] instead, as the paper does.
    pub fn mobilenet_v2_05(name: &str, w: usize, h: usize, n_classes: usize) -> NetworkSpec {
        // (cout, expand, stride, repeats) per MobileNetV2 stage, width ×0.5.
        let stages: &[(usize, usize, usize, usize)] = &[
            (8, 1, 1, 1),   // 16→8
            (12, 6, 2, 2),  // 24→12
            (16, 6, 2, 3),  // 32→16
            (32, 6, 2, 4),  // 64→32
            (48, 6, 1, 3),  // 96→48
            (80, 6, 2, 3),  // 160→80
            (160, 6, 1, 1), // 320→160
        ];
        let mut blocks = vec![Block::Stem { k: 3, cout: 16, stride: 2 }];
        for &(cout, expand, stride, repeats) in stages {
            for r in 0..repeats {
                blocks.push(Block::MBConv {
                    cout,
                    expand,
                    k: 3,
                    stride: if r == 0 { stride } else { 1 },
                });
            }
        }
        blocks.push(Block::Conv1x1 { cout: 640, act: Act::Relu6 });
        blocks.push(Block::PoolFc);
        NetworkSpec {
            name: name.to_string(),
            w,
            h,
            cin: 2,
            n_classes,
            blocks,
        }
    }

    /// Compact net for small-resolution datasets (N-MNIST 34×34,
    /// RoShamBo17 64×64) — the "customized network architecture" of §4.2.
    pub fn compact(name: &str, w: usize, h: usize, n_classes: usize) -> NetworkSpec {
        NetworkSpec {
            name: name.to_string(),
            w,
            h,
            cin: 2,
            n_classes,
            blocks: vec![
                Block::Stem { k: 3, cout: 8, stride: 1 },
                Block::MBConv { cout: 12, expand: 2, k: 3, stride: 2 },
                Block::MBConv { cout: 12, expand: 2, k: 3, stride: 1 },
                Block::MBConv { cout: 24, expand: 2, k: 3, stride: 2 },
                Block::MBConv { cout: 24, expand: 2, k: 3, stride: 1 },
                Block::MBConv { cout: 48, expand: 2, k: 3, stride: 2 },
                Block::Conv1x1 { cout: 96, act: Act::Relu6 },
                Block::PoolFc,
            ],
        }
    }

    /// Tiny net for unit tests and the quickstart example.
    pub fn tiny(w: usize, h: usize, n_classes: usize) -> NetworkSpec {
        NetworkSpec {
            name: "tiny".to_string(),
            w,
            h,
            cin: 2,
            n_classes,
            blocks: vec![
                Block::Stem { k: 3, cout: 4, stride: 1 },
                Block::MBConv { cout: 4, expand: 2, k: 3, stride: 1 }, // residual
                Block::MBConv { cout: 8, expand: 2, k: 3, stride: 2 },
                Block::PoolFc,
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mbconv_expansion_shapes() {
        let net = NetworkSpec::tiny(16, 16, 3);
        let ops = net.ops();
        // Stem, [fork, 1x1, dw, 1x1, add], [1x1, dw s2, 1x1], pool, fc
        assert!(matches!(ops[0], Op::ConvKxK { k: 3, cin: 2, cout: 4, stride: 1, .. }));
        assert!(matches!(ops[1], Op::ResFork));
        assert!(matches!(ops[2], Op::Conv1x1 { cin: 4, cout: 8, .. }));
        assert!(matches!(ops[3], Op::DwConv { c: 8, stride: 1, .. }));
        assert!(matches!(ops[4], Op::Conv1x1 { cin: 8, cout: 4, act: Act::None }));
        assert!(matches!(ops[5], Op::ResAdd));
        assert!(matches!(ops[6], Op::Conv1x1 { cin: 4, cout: 8, .. }));
        assert!(matches!(ops[7], Op::DwConv { c: 8, stride: 2, .. }));
        assert!(matches!(ops[8], Op::Conv1x1 { cin: 8, cout: 8, act: Act::None }));
        assert!(matches!(ops[9], Op::GlobalPool { c: 8 }));
        assert!(matches!(ops[10], Op::Fc { cin: 8, cout: 3 }));
    }

    #[test]
    fn no_residual_when_channels_change_or_stride2() {
        let net = NetworkSpec {
            name: "t".into(),
            w: 8,
            h: 8,
            cin: 2,
            n_classes: 2,
            blocks: vec![
                Block::Stem { k: 3, cout: 4, stride: 1 },
                Block::MBConv { cout: 6, expand: 2, k: 3, stride: 1 }, // cin!=cout
                Block::MBConv { cout: 6, expand: 2, k: 3, stride: 2 }, // stride 2
                Block::PoolFc,
            ],
        };
        let ops = net.ops();
        assert!(!ops.iter().any(|o| matches!(o, Op::ResFork | Op::ResAdd)));
    }

    #[test]
    fn resolutions_follow_strides() {
        let net = NetworkSpec::tiny(16, 16, 3);
        let res = net.op_resolutions();
        let ops = net.ops();
        assert_eq!(res.len(), ops.len());
        assert_eq!(res[0], (16, 16));
        // After the stride-2 dw (op index 7), resolution halves for op 8.
        assert_eq!(res[7], (16, 16));
        assert_eq!(res[8], (8, 8));
        assert_eq!(net.total_downsample(), 2);
    }

    #[test]
    fn mobilenet_has_expected_structure() {
        let net = NetworkSpec::mobilenet_v2_05("mbv2", 128, 128, 10);
        let ops = net.ops();
        assert_eq!(net.total_downsample(), 32);
        let n_dw = ops.iter().filter(|o| matches!(o, Op::DwConv { .. })).count();
        assert_eq!(n_dw, 17); // 17 MBConv blocks
        let n_res = ops.iter().filter(|o| matches!(o, Op::ResAdd)).count();
        assert_eq!(n_res, 10); // repeats with stride 1 and equal channels
        assert!(net.param_count() > 100_000 && net.param_count() < 2_000_000);
    }

    #[test]
    fn param_count_small_for_tiny() {
        let net = NetworkSpec::tiny(8, 8, 2);
        assert!(net.param_count() < 1000, "{}", net.param_count());
    }
}
