//! Tiny CLI flag parser (clap is not vendored).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (usually `std::env::args().skip(1)`).
    /// `bool_flags` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if bool_flags.contains(&stripped) {
                    out.bools.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{stripped} expects a value"))?;
                    out.flags.insert(stripped.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], bools: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), bools).unwrap()
    }

    #[test]
    fn parses_flag_styles() {
        let a = parse(
            &["simulate", "--model=mbv2", "--steps", "100", "--verbose", "extra"],
            &["verbose"],
        );
        assert_eq!(a.positional(), &["simulate".to_string(), "extra".to_string()]);
        assert_eq!(a.get("model"), Some("mbv2"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("name", "d"), "d");
    }

    #[test]
    fn bad_int_reports_flag() {
        let a = parse(&["--steps", "abc"], &[]);
        let e = a.get_usize("steps", 0).unwrap_err();
        assert!(e.contains("steps"));
    }
}
