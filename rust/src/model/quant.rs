//! Post-training quantization: calibrated float weights → dyadic int8
//! network (HAWQ-V3-style, matching the paper's 8-bit deployment, §4.1).
//!
//! Procedure:
//! 1. Run the f32 network over calibration inputs, recording the max
//!    absolute activation at every op output (symmetric per-tensor scales).
//! 2. For residual blocks, pin the project-conv output scale to the block
//!    input scale so the int8 identity add is scale-consistent (shared-
//!    scale residuals, as integer-only inference frameworks do).
//! 3. Quantize weights per-tensor symmetric; fold biases into the
//!    accumulator domain; derive each op's dyadic requantizer
//!    `s_in · s_w / s_out` with the activation clamp folded in.

use super::exec::{forward_f32_observed, Observed};
use super::graph::{Act, NetworkSpec, Op};
use super::weights::{FloatWeights, QuantOpWeights};
use crate::sparse::quant::{quantize_symmetric, Requant, QMAX, QMIN};
use crate::sparse::SparseMap;

/// Fully quantized network, aligned to `spec.ops()`.
#[derive(Clone, Debug)]
pub struct QuantizedNet {
    pub spec: NetworkSpec,
    /// Scale mapping f32 input → int8.
    pub input_scale: f32,
    /// Per-op quantized weights (None for weightless ops).
    pub per_op: Vec<Option<QuantOpWeights>>,
    /// Per-op output activation scale (for debugging / staging).
    pub out_scales: Vec<f32>,
}

/// Calibrate and quantize. `calib` should be a handful of representative
/// inputs (the paper's flow calibrates on the training set).
pub fn quantize_network(
    spec: &NetworkSpec,
    weights: &FloatWeights,
    calib: &[SparseMap<f32>],
) -> QuantizedNet {
    assert!(!calib.is_empty(), "need at least one calibration sample");
    let ops = spec.ops();
    // 1. Collect amax per op output and for the input.
    let mut amax_out = vec![0f32; ops.len()];
    let mut amax_in = 0f32;
    for input in calib {
        amax_in = input.feats.iter().fold(amax_in, |m, &v| m.max(v.abs()));
        forward_f32_observed(spec, weights, input, &mut |i, obs| {
            let a = match obs {
                Observed::MapF32(m) => m.feats.iter().fold(0f32, |mm, &v| mm.max(v.abs())),
                Observed::VecF32(v) => v.iter().fold(0f32, |mm, &x| mm.max(x.abs())),
                _ => 0.0,
            };
            amax_out[i] = amax_out[i].max(a);
        });
    }
    let input_scale = (amax_in.max(1e-6)) / 127.0;

    // 2. Output scale per op, with input-scale propagation for weightless ops.
    let mut s_out = vec![0f32; ops.len()];
    let mut s_in = vec![0f32; ops.len()];
    let mut cur_scale = input_scale;
    let mut fork_stack: Vec<f32> = Vec::new();
    // Map from ResAdd index to the index of the conv op feeding it (the
    // project conv right before), so we can pin scales.
    for (i, op) in ops.iter().enumerate() {
        s_in[i] = cur_scale;
        match op {
            Op::ResFork => {
                fork_stack.push(cur_scale);
                s_out[i] = cur_scale;
            }
            Op::ResAdd => {
                let fork_scale = fork_stack.pop().expect("unbalanced fork/add");
                // Pin the producing conv's output scale (handled below via
                // `pinned`), add output keeps the shared scale.
                s_out[i] = fork_scale;
                // Rewrite the previous op's output scale.
                s_out[i - 1] = fork_scale;
                s_in[i] = fork_scale;
            }
            Op::GlobalPool { .. } => {
                // Average preserves scale.
                s_out[i] = cur_scale;
            }
            Op::Fc { .. } => {
                // Logits stay int32; nominal scale for bookkeeping.
                s_out[i] = cur_scale;
            }
            _ => {
                s_out[i] = (amax_out[i].max(1e-6)) / 127.0;
            }
        }
        cur_scale = s_out[i];
    }
    // Recompute s_in after the ResAdd rewrites (a second forward pass over
    // the scale chain keeps everything consistent).
    let mut cur_scale = input_scale;
    let mut fork_stack: Vec<f32> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        s_in[i] = cur_scale;
        match op {
            Op::ResFork => fork_stack.push(cur_scale),
            Op::ResAdd => {
                fork_stack.pop();
            }
            _ => {}
        }
        cur_scale = s_out[i];
    }

    // 3. Quantize weights, fold biases, build requantizers.
    let mut per_op = Vec::with_capacity(ops.len());
    for (i, op) in ops.iter().enumerate() {
        if !op.has_weights() {
            per_op.push(None);
            continue;
        }
        let ow = &weights.per_op[i];
        let (sw, qw) = quantize_symmetric(&ow.w);
        let acc_scale = s_in[i] * sw;
        let b: Vec<i32> = ow
            .b
            .iter()
            .map(|&v| (v / acc_scale).round().clamp(i32::MIN as f32, i32::MAX as f32) as i32)
            .collect();
        let act = match *op {
            Op::Conv1x1 { act, .. } | Op::ConvKxK { act, .. } | Op::DwConv { act, .. } => act,
            _ => Act::None,
        };
        let (lo, hi) = match act {
            Act::None => (QMIN, QMAX),
            Act::Relu => (0, QMAX),
            Act::Relu6 => (0, ((6.0 / s_out[i]).round() as i32).clamp(1, QMAX)),
        };
        let rq = if matches!(op, Op::Fc { .. }) {
            // Logits stay in the accumulator domain; unit requant unused.
            Requant::unit()
        } else {
            Requant::from_scale((acc_scale / s_out[i]) as f64, lo, hi)
        };
        per_op.push(Some(QuantOpWeights {
            w: qw,
            b,
            rq,
            s_in: s_in[i],
            s_out: s_out[i],
        }));
    }

    QuantizedNet {
        spec: spec.clone(),
        input_scale,
        per_op,
        out_scales: s_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::exec::{forward_f32, forward_i8};
    use crate::util::Rng;

    fn inputs(n: usize) -> Vec<SparseMap<f32>> {
        let p = DatasetProfile::n_mnist();
        let mut rng = Rng::new(99);
        (0..n)
            .map(|i| {
                let es = p.sample(i % p.n_classes, &mut rng);
                histogram2_norm(&es, p.w, p.h, 8.0)
            })
            .collect()
    }

    #[test]
    fn quantized_net_shape_aligned() {
        let spec = NetworkSpec::tiny(34, 34, 4);
        let w = FloatWeights::random(&spec, 5);
        let qnet = quantize_network(&spec, &w, &inputs(2));
        let ops = spec.ops();
        assert_eq!(qnet.per_op.len(), ops.len());
        for (q, op) in qnet.per_op.iter().zip(&ops) {
            assert_eq!(q.is_some(), op.has_weights());
            if let Some(q) = q {
                assert_eq!(q.w.len(), op.weight_count());
            }
        }
    }

    #[test]
    fn residual_scales_are_shared() {
        let spec = NetworkSpec::tiny(34, 34, 4);
        let w = FloatWeights::random(&spec, 5);
        let qnet = quantize_network(&spec, &w, &inputs(2));
        let ops = spec.ops();
        // Find fork/add pair in the tiny net.
        let fork = ops.iter().position(|o| matches!(o, Op::ResFork)).unwrap();
        let add = ops.iter().position(|o| matches!(o, Op::ResAdd)).unwrap();
        let fork_in_scale = qnet.out_scales[fork];
        assert_eq!(qnet.out_scales[add - 1], fork_in_scale);
        assert_eq!(qnet.out_scales[add], fork_in_scale);
    }

    #[test]
    fn relu6_clamp_in_quantized_domain() {
        let spec = NetworkSpec::tiny(34, 34, 4);
        let w = FloatWeights::random(&spec, 6);
        let qnet = quantize_network(&spec, &w, &inputs(2));
        for (q, op) in qnet.per_op.iter().zip(&spec.ops()) {
            if let (Some(q), true) = (q, op.has_weights()) {
                let act = match *op {
                    Op::Conv1x1 { act, .. } | Op::ConvKxK { act, .. } | Op::DwConv { act, .. } => {
                        act
                    }
                    _ => Act::None,
                };
                if matches!(act, Act::Relu6) {
                    assert_eq!(q.rq.lo, 0);
                    let q6 = (6.0 / q.s_out).round() as i32;
                    assert_eq!(q.rq.hi, q6.clamp(1, 127));
                }
            }
        }
    }

    /// int8 logits must correlate strongly with f32 logits (rank-level
    /// agreement tested in exec; here check magnitude tracking).
    #[test]
    fn logit_scale_tracks_f32() {
        let spec = NetworkSpec::tiny(34, 34, 4);
        let w = FloatWeights::random(&spec, 8);
        let calib = inputs(4);
        let qnet = quantize_network(&spec, &w, &calib);
        let input = &calib[0];
        let lf = forward_f32(&spec, &w, input);
        let li = forward_i8(&qnet, input);
        // Dequantize logits: li · (s_pool · s_wfc)
        let fc_idx = spec.ops().len() - 1;
        let q = qnet.per_op[fc_idx].as_ref().unwrap();
        let (sw, _) = crate::sparse::quant::quantize_symmetric(&w.per_op[fc_idx].w);
        let s_logit = q.s_in * sw;
        for (a, &b) in lf.iter().zip(&li) {
            let deq = b as f32 * s_logit;
            assert!(
                (a - deq).abs() < 0.25 * lf.iter().fold(0f32, |m, &v| m.max(v.abs())).max(0.5),
                "f32 {a} vs dequantized {deq}"
            );
        }
    }
}
