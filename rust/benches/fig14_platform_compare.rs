// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Figure 14: ESDA vs platform baselines on N-Caltech101, DvsGesture,
//! ASL-DVS — latency, throughput, energy.
//!
//! Two comparisons reproduce the paper's two findings (stand-ins per
//! DESIGN.md §2):
//!
//! 1. **ESDA vs dense accelerator** (the paper's GPU-dense row): the same
//!    network on a dense sliding-window dataflow at identical PF/bitwidth,
//!    in *cycles* — an architecture-level, host-independent ratio. Paper
//!    shape: 3.3–23× (MobileNetV2), 9.4–54.8× (ESDA-Net).
//! 2. **Sparse gather–scatter vs dense tensor engine at batch 1** (the
//!    paper's GPU-sparse observation): the MinkowskiEngine-style rulebook
//!    executor vs the XLA/PJRT dense engine, wall time on this host.
//!    Paper shape: sparse *slower* than dense at batch 1 (per-offset
//!    launches + coordinate hashing dominate).

use esda::arch::dense::dense_chain_latency;
use esda::arch::{simulate_inference, HwConfig};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::power::{PowerModel, CLOCK_HZ};
use esda::hwopt::{allocate, stats::collect_stats, Budget};
use esda::model::graph::Op;
use esda::model::quant::quantize_network;
use esda::model::weights::{load_float_weights, FloatWeights};
use esda::model::NetworkSpec;
use esda::report::Table;
use esda::runtime::{artifact_available, artifacts_dir, Engine};
use esda::sparse::rulebook::{build_rulebook_s1, conv_s2_rulebook, RulebookStats};
use esda::sparse::SparseMap;
use esda::util::stats::{bench, fmt_secs};
use esda::util::Rng;

/// Sparse gather–scatter forward (MinkowskiEngine stand-in) — wall time.
fn rulebook_forward(
    spec: &NetworkSpec,
    w: &esda::model::weights::FloatWeights,
    input: &SparseMap<f32>,
) {
    let ops = spec.ops();
    let mut cur = input.clone();
    let mut stack: Vec<SparseMap<f32>> = Vec::new();
    let mut pooled: Vec<f32> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        let ow = &w.per_op[i];
        match *op {
            Op::Conv1x1 { cout, act, .. } => {
                cur = esda::sparse::conv::conv1x1_f32(&cur, &ow.w, &ow.b, cout, act);
            }
            Op::ConvKxK { k, cout, stride, act, .. } => {
                cur = if stride == 1 {
                    let mut rb = build_rulebook_s1(&cur, k);
                    esda::sparse::rulebook::execute_s1(&cur, &mut rb, &ow.w, &ow.b, cout)
                } else {
                    let mut st = RulebookStats::default();
                    conv_s2_rulebook(&cur, k, &ow.w, &ow.b, cout, &mut st)
                };
                cur.feats.iter_mut().for_each(|v| *v = act.apply(*v));
            }
            Op::DwConv { k, stride, act, c } => {
                let mut full = vec![0f32; k * k * c * c];
                for off in 0..k * k {
                    for ch_ in 0..c {
                        full[(off * c + ch_) * c + ch_] = ow.w[off * c + ch_];
                    }
                }
                cur = if stride == 1 {
                    let mut rb = build_rulebook_s1(&cur, k);
                    esda::sparse::rulebook::execute_s1(&cur, &mut rb, &full, &ow.b, c)
                } else {
                    let mut st = RulebookStats::default();
                    conv_s2_rulebook(&cur, k, &full, &ow.b, c, &mut st)
                };
                cur.feats.iter_mut().for_each(|v| *v = act.apply(*v));
            }
            Op::ResFork => stack.push(cur.clone()),
            Op::ResAdd => {
                let sc = stack.pop().unwrap();
                cur = esda::sparse::conv::residual_add_f32(&cur, &sc);
            }
            Op::GlobalPool { .. } => pooled = esda::sparse::conv::global_avg_pool_f32(&cur),
            Op::Fc { cout, .. } => pooled = esda::sparse::conv::fc_f32(&pooled, &ow.w, &ow.b, cout),
        }
    }
    std::hint::black_box(&pooled);
}

fn main() {
    println!("# Fig. 14 — ESDA vs platform baselines (batch 1)\n");
    let datasets = ["n_caltech101", "dvs_gesture", "asl_dvs"];
    let pm = PowerModel::calibrated();

    // -----------------------------------------------------------------
    // 1. Architecture-level: ESDA sparse dataflow vs dense dataflow.
    // -----------------------------------------------------------------
    let mut t = Table::new(
        "ESDA vs dense dataflow (identical PF/bitwidth; simulated cycles @187 MHz)",
        &["dataset", "model", "ESDA (ms)", "dense (ms)", "speedup", "fps", "mJ/inf"],
    );
    for ds in datasets {
        let profile = DatasetProfile::by_name(ds).unwrap();
        for model in ["esda_net", "mbv2"] {
            let spec = match model {
                "mbv2" => {
                    NetworkSpec::mobilenet_v2_05("mbv2", profile.w, profile.h, profile.n_classes)
                }
                _ => NetworkSpec::compact("esda_net", profile.w, profile.h, profile.n_classes),
            };
            let weights = FloatWeights::random(&spec, 1);
            let mut rng = Rng::new(0xF16_14);
            let mk = |rng: &mut Rng, i: usize| {
                let es = profile.sample(i % profile.n_classes, rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            };
            let calib: Vec<_> = (0..3).map(|i| mk(&mut rng, i)).collect();
            let qnet = quantize_network(&spec, &weights, &calib);
            let bms: Vec<_> = calib.iter().map(|m| m.bitmap()).collect();
            let stats = collect_stats(&spec, &bms);
            let Some(alloc) = allocate(&spec, &stats, &Budget::zcu102()) else {
                continue;
            };
            let cfg = HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
            let input = mk(&mut rng, 5);
            let (_, report) = simulate_inference(&qnet, &cfg, &input, 50_000_000_000).unwrap();
            let esda_ms = report.cycles as f64 / CLOCK_HZ * 1e3;
            let dense_cycles = dense_chain_latency(&spec.ops(), &alloc.pf, spec.w, spec.h) as f64;
            let dense_ms = dense_cycles / CLOCK_HZ * 1e3;
            let energy = pm.energy_mj(&alloc.resources, report.cycles as f64, CLOCK_HZ);
            t.row(vec![
                ds.to_string(),
                model.to_string(),
                format!("{esda_ms:.3}"),
                format!("{dense_ms:.3}"),
                format!("{:.1}×", dense_ms / esda_ms),
                format!("{:.0}", CLOCK_HZ / report.cycles as f64),
                format!("{energy:.2}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("paper shape: 3.3–23× (MobileNetV2), 9.4–54.8× (customized ESDA-Net)\n");

    // -----------------------------------------------------------------
    // 2. Platform-level: sparse gather–scatter vs dense tensor engine.
    // -----------------------------------------------------------------
    println!("== sparse (rulebook/MinkowskiEngine-style) vs dense (XLA/PJRT) at batch 1 ==");
    let mut any = false;
    for ds in datasets.iter().chain(["n_mnist", "roshambo17"].iter()) {
        let stem = format!("compact_{ds}");
        if !esda::runtime::pjrt_enabled() || !artifact_available(&stem) {
            continue;
        }
        any = true;
        let profile = DatasetProfile::by_name(ds).unwrap();
        let spec = NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes);
        let fw = load_float_weights(
            &artifacts_dir().join(format!("{stem}_weights.esdw")),
            &spec,
        )
        .unwrap();
        let engine = Engine::load(&artifacts_dir().join(format!("{stem}.hlo.txt"))).unwrap();
        let mut rng = Rng::new(3);
        let es = profile.sample(0, &mut rng);
        let input = histogram2_norm(&es, profile.w, profile.h, 8.0);
        let s_dense = bench(2, 8, || {
            let _ = engine.infer_sparse(&input).unwrap();
        });
        let s_sparse = bench(2, 8, || {
            rulebook_forward(&spec, &fw, &input);
        });
        println!(
            "  {ds}: dense engine {} | gather-scatter {} | sparse/dense {:.2}× (paper: >1 at batch 1)",
            fmt_secs(s_dense.median()),
            fmt_secs(s_sparse.median()),
            s_sparse.median() / s_dense.median()
        );
    }
    if !any {
        println!(
            "  (needs AOT artifacts and the `pjrt` feature — run `make artifacts`, add \
             the vendored `xla` dependency in rust/Cargo.toml, build with --features pjrt)"
        );
    }
}
