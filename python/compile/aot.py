"""AOT lowering: jit the Pallas-kernel inference function, lower to HLO
**text**, and write ``artifacts/<stem>.hlo.txt`` for the rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the published xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage (driven by `make artifacts`):
    python -m compile.aot --out ../artifacts --stems compact_n_mnist,...
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import tensorio


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides big literals as `constant({...})`, which the xla_extension
    # 0.5.1 text parser silently zero-fills — the baked-in weights would
    # all become zeros on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(spec, params, use_pallas=True):
    """Lower `forward` (with weights baked in as constants — the all-on-chip
    deployment: weights live in the artifact like they live in BRAM)."""

    def infer(x):
        return (M.forward(spec, params, x, use_pallas=use_pallas),)

    example = jax.ShapeDtypeStruct((spec["h"], spec["w"], spec["cin"]), jnp.float32)
    return jax.jit(infer).lower(example)


def export_stem(out_dir, stem, use_pallas=True):
    """Read <stem>_weights.esdw + <stem>.meta.json (written by train.py),
    lower, and write <stem>.hlo.txt."""
    meta_path = os.path.join(out_dir, f"{stem}.meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    tensors = tensorio.read_tensors(os.path.join(out_dir, f"{stem}_weights.esdw"))
    params = {k: jnp.asarray(v) for k, v in tensors.items() if k.startswith("op")}
    spec = M.BUILDERS[meta["model"]](meta["w"], meta["h"], meta["n_classes"])
    lowered = lower_model(spec, params, use_pallas=use_pallas)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{stem}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    print(f"wrote {hlo_path} ({len(text)} chars, pallas={use_pallas})")
    return hlo_path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--stems", default=None,
                    help="comma-separated; default: every stem in train_summary.json")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead of the Pallas kernels")
    args = ap.parse_args()

    stems = []
    if args.stems:
        stems = [s.strip() for s in args.stems.split(",")]
    else:
        with open(os.path.join(args.out, "train_summary.json")) as f:
            stems = [v["stem"] for v in json.load(f).values()]
    for stem in stems:
        export_stem(args.out, stem, use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()
