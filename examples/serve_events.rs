//! Serving demo: the threaded coordinator pipeline (event source →
//! representation builder → accelerator) under sustained load, comparing
//! the cycle-simulator backend against the functional int8 backend, with
//! backpressure through bounded queues.
//!
//! Run: `cargo run --release --example serve_events -- --dataset n_mnist --requests 64`

use esda::arch::HwConfig;
use esda::coordinator::{run_pipeline, Backend, PipelineConfig};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::power::CLOCK_HZ;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::util::cli::Args;
use esda::util::stats::fmt_secs;
use esda::util::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let name = args.get_or("dataset", "n_mnist");
    let n_requests = args.get_usize("requests", 64).unwrap();
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);
    let n_ops = spec.ops().len();

    for (label, backend) in [
        ("functional int8", Backend::Functional { qnet: qnet.clone() }),
        (
            "cycle simulator",
            Backend::Simulator { qnet: qnet.clone(), cfg: HwConfig::uniform(n_ops, 16) },
        ),
    ] {
        let cfg = PipelineConfig { n_requests, seed: 3, queue_depth: 4, clip: 8.0 };
        let r = run_pipeline(&profile, &backend, &cfg);
        let m = &r.metrics;
        println!("== backend: {label} ==");
        println!(
            "  {} requests | e2e p50 {} p99 {} | service mean {} | {:.0} req/s",
            m.total,
            fmt_secs(m.e2e_summary().percentile(50.0)),
            fmt_secs(m.e2e_summary().percentile(99.0)),
            fmt_secs(m.service_summary().mean()),
            m.throughput(),
        );
        if let Some(ms) = m.mean_sim_latency_ms(CLOCK_HZ) {
            println!("  simulated hardware latency: {ms:.3} ms/inf @187 MHz");
        }
    }
}
