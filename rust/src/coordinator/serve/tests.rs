use super::*;
use crate::arch::HwConfig;
use crate::coordinator::backend::{
    BackendError, Classification, Functional, ReplicaSpec, Simulator,
};
use crate::coordinator::testutil::qnet_for;
use crate::sparse::SparseMap;

#[test]
fn pool_processes_all_requests() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = ServerConfig { n_requests: 12, seed: 4, workers: 3, ..Default::default() };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 12);
    assert_eq!(r.predictions.len(), 12);
    assert_eq!(r.metrics.dropped, 0);
    assert_eq!(r.metrics.per_worker.len(), 3);
    assert_eq!(r.metrics.per_worker.iter().map(|w| w.served).sum::<usize>(), 12);
    assert!(r.metrics.throughput() > 0.0);
    // The homogeneous path reports a single routing class.
    assert_eq!(r.metrics.per_class.len(), 1);
    assert_eq!(r.metrics.per_class[0].served, 12);
    assert_eq!(r.metrics.per_class[0].replicas, 3);
    // No SLO: the deadline books stay empty and attainment is N/A.
    assert_eq!(r.metrics.deadline_offered, 0);
    assert_eq!(r.metrics.deadline_drops(), 0);
    assert_eq!(r.metrics.slo_attainment(), None);
    // Every run carries a per-model rollup; a single-model run's one row
    // restates the global books under the default tag.
    assert_eq!(r.metrics.per_model.len(), 1);
    assert_eq!(r.metrics.per_model[0].model, DEFAULT_MODEL);
    assert_eq!(r.metrics.per_model[0].served, 12);
    assert_eq!(r.metrics.per_model[0].offered(), 12);
}

/// Micro-batching is a scheduling detail: every request is still served
/// exactly once, and the batch-size books stay consistent.
#[test]
fn batched_pool_serves_every_request_once() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = ServerConfig {
        n_requests: 20,
        seed: 6,
        workers: 2,
        queue_depth: 8,
        batch: 4,
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 20);
    assert_eq!(r.predictions.len(), 20);
    let visits: usize = r.metrics.batch_sizes.iter().sum();
    assert_eq!(visits, 20, "batch sizes must partition the request stream");
    assert!(r.metrics.batch_sizes.iter().all(|&b| (1..=4).contains(&b)));
    assert!(r.metrics.mean_batch() >= 1.0);
    let per_worker: usize = r.metrics.per_worker.iter().map(|w| w.batches).sum();
    assert_eq!(per_worker, r.metrics.batch_sizes.len());
}

#[test]
fn simulator_replicas_report_cycles() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let n_ops = qnet.spec.ops().len();
    let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
    let cfg = ServerConfig { n_requests: 4, seed: 5, workers: 2, ..Default::default() };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 4);
    let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
    assert!(lat > 0.0);
}

/// A two-class heterogeneous pool serves every request exactly once,
/// respects each class's batch affinity, and reports a per-class
/// breakdown whose books balance.
#[test]
fn heterogeneous_pool_keeps_class_books_balanced() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let qnet2 = qnet.clone();
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::functional(2, qnet),
        ReplicaSpec::new("func-b", 1, 2, move |_| Ok(Box::new(Functional::new(qnet2.clone())))),
    ])
    .unwrap();
    assert_eq!(pool.n_replicas(), 3);
    let cfg = ServerConfig { n_requests: 16, seed: 9, queue_depth: 4, ..Default::default() };
    let r = run_pool(&profile, &pool, &cfg).unwrap();
    assert_eq!(r.metrics.total, 16);
    assert_eq!(r.metrics.per_worker.len(), 3);
    assert_eq!(r.metrics.per_class.len(), 2);
    assert_eq!(r.metrics.per_class.iter().map(|c| c.served).sum::<usize>(), 16);
    let class_batches: usize = r.metrics.per_class.iter().map(|c| c.batches).sum();
    assert_eq!(class_batches, r.metrics.batch_sizes.len());
    let visits: usize = r.metrics.batch_sizes.iter().sum();
    assert_eq!(visits, 16, "batch sizes must partition the request stream");
    for c in &r.metrics.per_class {
        let cap = if c.class == "func" { 4.0 } else { 2.0 };
        assert!(
            c.batches == 0 || c.batch.max <= cap,
            "class {} exceeded its batch affinity: {:?}",
            c.class,
            c.batch
        );
        assert_eq!(c.deadline_drops, 0, "no SLO ⇒ no deadline sheds");
    }
    // Worker stats carry their class name for the report.
    for w in &r.metrics.per_worker {
        assert!(w.class == "func" || w.class == "func-b", "class: {}", w.class);
    }
    // Both classes serve the same (default) model: one fleet row.
    assert_eq!(r.metrics.per_model.len(), 1);
    assert_eq!(r.metrics.per_model[0].classes, 2);
    assert_eq!(r.metrics.per_model[0].served, 16);
}

/// A zero SLO expires every request at the ingress: nothing reaches a
/// worker, the drop is accounted as an ingress deadline drop, and
/// attainment is 0.
#[test]
fn zero_slo_expires_everything_at_ingress() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = ServerConfig {
        n_requests: 8,
        seed: 4,
        workers: 2,
        slo: Some(Duration::ZERO),
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 0, "an expired request must never be served");
    assert!(r.predictions.is_empty());
    assert_eq!(r.metrics.deadline_offered, 8);
    assert_eq!(r.metrics.deadline_ingress, 8);
    assert_eq!(r.metrics.deadline_router, 0);
    assert_eq!(r.metrics.dropped, 0, "deadline drops are not queue-full drops");
    assert_eq!(r.metrics.offered(), 8);
    assert_eq!(r.metrics.slo_attainment(), Some(0.0));
    // The ingress sheds land on the model's books too.
    assert_eq!(r.metrics.per_model[0].deadline_ingress, 8);
    assert_eq!(r.metrics.per_model[0].offered(), 8);
}

/// A generous SLO on an unloaded pool changes nothing: everything is
/// served, everything meets its deadline, attainment is 1.
#[test]
fn generous_slo_serves_everything_in_deadline() {
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = ServerConfig {
        n_requests: 10,
        seed: 4,
        workers: 2,
        slo: Some(Duration::from_secs(60)),
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 10);
    assert_eq!(r.metrics.deadline_offered, 10);
    assert_eq!(r.metrics.deadline_met, 10);
    assert_eq!(r.metrics.deadline_drops(), 0);
    assert_eq!(r.metrics.slo_attainment(), Some(1.0));
}

/// A backend that errors mid-stream aborts cleanly with in-flight
/// accounting instead of deadlocking or poisoning joins.
#[test]
fn backend_error_aborts_cleanly() {
    struct FailAfter {
        inner: Functional,
        calls: std::sync::atomic::AtomicUsize,
    }
    impl Backend for FailAfter {
        fn name(&self) -> &str {
            "fail-after"
        }
        fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            let n = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if n >= 5 {
                return Err(BackendError("injected fault".into()));
            }
            self.inner.classify(map)
        }
    }
    let profile = DatasetProfile::n_mnist();
    let backend = FailAfter {
        inner: Functional::new(qnet_for(&profile)),
        calls: std::sync::atomic::AtomicUsize::new(0),
    };
    let cfg = ServerConfig { n_requests: 16, seed: 2, workers: 2, ..Default::default() };
    let err = run_server(&profile, &backend, &cfg).unwrap_err();
    assert!(err.msg.contains("injected fault"), "msg: {}", err.msg);
    assert!(err.completed < 16);
}

/// An erroring event source surfaces as a `PipelineError` naming the
/// source, after the already-admitted prefix was served.
#[test]
fn source_error_surfaces_as_pipeline_error() {
    use crate::coordinator::ingest::{IngestError, SourcedRequest};
    struct FailingSource {
        inner: SyntheticSource,
        after: usize,
        emitted: usize,
    }
    impl EventSource for FailingSource {
        fn name(&self) -> &str {
            "failing"
        }
        fn geometry(&self) -> (usize, usize) {
            self.inner.geometry()
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            if self.emitted >= self.after {
                return Err(IngestError::fatal("sensor unplugged"));
            }
            self.emitted += 1;
            self.inner.next_request()
        }
    }
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let source =
        FailingSource { inner: SyntheticSource::new(profile, 100, 3), after: 4, emitted: 0 };
    let cfg = ServerConfig { workers: 2, ..Default::default() };
    let err = run_server_source(Box::new(source), &backend, &cfg).unwrap_err();
    assert!(err.msg.contains("sensor unplugged"), "msg: {}", err.msg);
    assert_eq!(err.completed, 4, "the admitted prefix is served before the abort");
    assert_eq!(err.in_flight, 0);
}

/// Regression (one bad sample must not kill the run): recoverable
/// source rejects are skipped and counted — globally and on the
/// default tenant — while every good sample is still served.
#[test]
fn recoverable_source_rejects_are_counted_not_fatal() {
    use crate::coordinator::ingest::{IngestError, SourcedRequest};
    struct FlakySource {
        inner: SyntheticSource,
        emitted: usize,
    }
    impl EventSource for FlakySource {
        fn name(&self) -> &str {
            "flaky"
        }
        fn geometry(&self) -> (usize, usize) {
            self.inner.geometry()
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            self.emitted += 1;
            // Every third pull hits a bad sample the reader skipped.
            if self.emitted % 3 == 0 {
                return Err(IngestError::recoverable("events not sorted"));
            }
            self.inner.next_request()
        }
    }
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let source = FlakySource { inner: SyntheticSource::new(profile, 8, 3), emitted: 0 };
    let cfg = ServerConfig { workers: 2, ..Default::default() };
    let r = run_server_source(Box::new(source), &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 8, "every good sample is still served");
    assert_eq!(r.metrics.ingest_rejects, 4, "8 good pulls + terminal None ⇒ 4 rejects");
    assert_eq!(r.metrics.per_tenant.len(), 1, "implicit default tenant");
    let t = &r.metrics.per_tenant[0];
    assert_eq!(t.tenant, "default");
    assert_eq!(t.ingest_rejects, 4, "single-tenant rejects land on the default tenant");
    assert_eq!(t.served, 8);
    assert_eq!(t.offered(), 12, "served + rejects reconstruct the stream");
}

/// Two tenants with distinct SLOs: each request's deadline follows its
/// tenant's override, and the per-tenant books balance independently.
#[test]
fn per_tenant_slo_overrides_global() {
    use crate::coordinator::ingest::{IngestError, SourcedRequest};
    // Tenant 0 gets an impossible (zero) SLO, tenant 1 a generous one;
    // no global SLO at all.
    struct TwoTenantSource {
        inner: SyntheticSource,
        emitted: usize,
        n: usize,
    }
    impl EventSource for TwoTenantSource {
        fn name(&self) -> &str {
            "two-tenant"
        }
        fn geometry(&self) -> (usize, usize) {
            self.inner.geometry()
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            if self.emitted >= self.n {
                return Ok(None);
            }
            let tenant = self.emitted % 2;
            self.emitted += 1;
            Ok(self.inner.next_request()?.map(|mut sr| {
                sr.tenant = tenant;
                sr
            }))
        }
    }
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let source =
        TwoTenantSource { inner: SyntheticSource::new(profile, 100, 7), emitted: 0, n: 10 };
    let cfg = ServerConfig {
        workers: 2,
        // Deep enough that each tenant's quota (depth/2) exceeds its 5
        // requests — no quota drop can race the assertions below.
        queue_depth: 16,
        tenants: vec![
            TenantConfig::new("strict", 1).with_slo(Duration::ZERO),
            TenantConfig::new("lax", 1).with_slo(Duration::from_secs(60)),
        ],
        ..Default::default()
    };
    let r = run_server_source(Box::new(source), &backend, &cfg).unwrap();
    assert_eq!(r.metrics.per_tenant.len(), 2);
    let strict = &r.metrics.per_tenant[0];
    let lax = &r.metrics.per_tenant[1];
    assert_eq!(strict.served, 0, "zero SLO expires everything at the ingress");
    assert_eq!(strict.deadline_ingress, 5);
    assert_eq!(strict.slo_attainment(), Some(0.0));
    assert_eq!(lax.served, 5);
    assert_eq!(lax.slo_attainment(), Some(1.0));
    for t in [strict, lax] {
        assert_eq!(t.offered(), 5, "each tenant's books reconstruct its stream");
    }
    // Global books are the per-tenant sums.
    assert_eq!(r.metrics.total, 5);
    assert_eq!(r.metrics.deadline_ingress, 5);
    assert_eq!(r.metrics.deadline_offered, 10);
}

/// Two models behind one front door: each request lands only on a class
/// serving its model, and each model's books independently conserve
/// (offered = served + dropped + deadline sheds — here all served).
#[test]
fn fleet_serves_each_model_on_its_own_class() {
    use crate::coordinator::ingest::{IngestError, SourcedRequest};
    struct TwoModelSource {
        inner: SyntheticSource,
        emitted: usize,
        n: usize,
    }
    impl EventSource for TwoModelSource {
        fn name(&self) -> &str {
            "two-model"
        }
        fn geometry(&self) -> (usize, usize) {
            self.inner.geometry()
        }
        fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
            if self.emitted >= self.n {
                return Ok(None);
            }
            let model = self.emitted % 2;
            self.emitted += 1;
            Ok(self.inner.next_request()?.map(|mut sr| {
                sr.model = model;
                sr
            }))
        }
    }
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let (qa, qb) = (qnet.clone(), qnet);
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::new("alpha-c", 1, 4, move |_| Ok(Box::new(Functional::new(qa.clone()))))
            .for_model("alpha"),
        ReplicaSpec::new("beta-c", 1, 4, move |_| Ok(Box::new(Functional::new(qb.clone()))))
            .for_model("beta"),
    ])
    .unwrap();
    let source =
        TwoModelSource { inner: SyntheticSource::new(profile, 100, 11), emitted: 0, n: 12 };
    let cfg = ServerConfig { queue_depth: 16, ..Default::default() };
    let r = run_pool_source(Box::new(source), &pool, &cfg).unwrap();
    assert_eq!(r.metrics.total, 12);
    assert_eq!(r.metrics.per_model.len(), 2);
    let alpha = &r.metrics.per_model[0];
    let beta = &r.metrics.per_model[1];
    assert_eq!(alpha.model, "alpha");
    assert_eq!(beta.model, "beta");
    for m in [alpha, beta] {
        assert_eq!(m.classes, 1);
        assert_eq!(m.served, 6, "the alternating stream splits evenly");
        assert_eq!(m.offered(), 6, "per-model books conserve the stream");
        assert_eq!(m.shadow_mirrored, 0, "no shadow configured");
    }
    // The model filter is hard: each class served exactly its model's half.
    for c in &r.metrics.per_class {
        assert_eq!(c.served, 6, "class {} must only see its own model", c.class);
    }
}

/// A shadow candidate running the identical network agrees on every
/// mirrored request: full mirror coverage, zero disagreements.
#[test]
fn shadow_of_identical_candidate_never_disagrees() {
    let profile = DatasetProfile::n_mnist();
    let qnet = qnet_for(&profile);
    let backend = Functional::new(qnet.clone());
    let cfg = ServerConfig {
        n_requests: 10,
        seed: 4,
        workers: 2,
        shadows: vec![ShadowConfig {
            model: DEFAULT_MODEL.to_string(),
            candidate: Arc::new(Functional::new(qnet)),
            fraction: 1.0,
        }],
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 10);
    let m = &r.metrics.per_model[0];
    assert_eq!(m.shadow_mirrored, 10, "fraction 1.0 mirrors every served request");
    assert_eq!(m.shadow_disagreements, 0);
    assert_eq!(m.shadow_capture_drops, 0);
    assert_eq!(m.disagreement_rate(), Some(0.0));
}

/// A candidate that always disagrees: every mirror is a disagreement,
/// the capture file keeps the first `max_samples` of them (with their
/// raw events and true labels), and the overflow is counted as drops.
#[test]
fn shadow_disagreements_hit_the_capture_cap() {
    struct Fixed(usize);
    impl Backend for Fixed {
        fn name(&self) -> &str {
            "fixed"
        }
        fn classify(&self, _map: &SparseMap<f32>) -> Result<Classification, BackendError> {
            Ok(Classification { pred: self.0, sim_cycles: None })
        }
    }
    let dir = std::env::temp_dir().join(format!("esda-shadow-cap-{}", std::process::id()));
    let path = dir.join("disagreements.esda");
    let profile = DatasetProfile::n_mnist();
    let backend = Functional::new(qnet_for(&profile));
    let cfg = ServerConfig {
        n_requests: 8,
        seed: 3,
        workers: 1,
        shadows: vec![ShadowConfig {
            model: DEFAULT_MODEL.to_string(),
            // Class 99 does not exist: the primary can never agree.
            candidate: Arc::new(Fixed(99)),
            fraction: 1.0,
        }],
        shadow_capture: Some(ShadowCaptureConfig { path: path.clone(), max_samples: 2 }),
        ..Default::default()
    };
    let r = run_server(&profile, &backend, &cfg).unwrap();
    assert_eq!(r.metrics.total, 8);
    let m = &r.metrics.per_model[0];
    assert_eq!(m.shadow_mirrored, 8);
    assert_eq!(m.shadow_disagreements, 8);
    assert_eq!(m.disagreement_rate(), Some(1.0));
    assert_eq!(m.shadow_capture_drops, 6, "everything past the cap is a counted drop");
    // The capture is a valid .esda dataset holding the capped sample set.
    let (w, h, samples) = crate::events::io::read_dataset(&path).unwrap();
    assert_eq!((w, h), (profile.w, profile.h));
    assert_eq!(samples.len(), 2);
    for s in &samples {
        assert!(!s.events.is_empty(), "captured samples keep their raw events");
        assert!((s.label as usize) < profile.n_classes);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
