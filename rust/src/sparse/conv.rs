// lint:allow(module-size): one kernel family over one arena discipline; split tracked
//! Functional (non-cycle-level) reference implementations of the paper's
//! layer algebra, in both f32 (training-parity) and int8 (hardware-exact)
//! arithmetic:
//!
//! - 1×1 (pointwise) convolution — submanifold by construction,
//! - k×k submanifold convolution, stride 1 (full and depthwise),
//! - k×k sparse convolution, stride 2 (full and depthwise),
//! - global average pooling over nonzero tokens + fully connected head,
//! - standard dense convolution on the materialized map (oracle for the
//!   submanifold implementations and for Fig. 12's standard-conv twin).
//!
//! The cycle-level `arch` modules must reproduce the int8 results here
//! *exactly*; the python JAX model reproduces the f32 results (golden
//! vectors), and int8 vs f32 agree to quantization tolerance.

use super::map::SparseMap;
use super::quant::Requant;
use super::rulebook::NeighborIndex;
use super::token::Token;
use super::Bitmap;

/// Activation applied inside the float layers (int8 layers fold activation
/// clamps into their [`Requant`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Relu6,
}

impl Act {
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Act::None => x,
            Act::Relu => x.max(0.0),
            Act::Relu6 => x.clamp(0.0, 6.0),
        }
    }
}

// ---------------------------------------------------------------------------
// f32 reference path
// ---------------------------------------------------------------------------

/// 1×1 convolution: tokens relayed unchanged, features mapped through a
/// `cin × cout` matrix (row-major `w[ci * cout + co]`) plus bias.
pub fn conv1x1_f32(
    input: &SparseMap<f32>,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    act: Act,
) -> SparseMap<f32> {
    let cin = input.c;
    assert_eq!(w.len(), cin * cout);
    assert_eq!(bias.len(), cout);
    let mut out = SparseMap::empty(input.w, input.h, cout);
    out.tokens = input.tokens.clone();
    out.feats.reserve(out.tokens.len() * cout);
    for i in 0..input.nnz() {
        let f = input.feat(i);
        for co in 0..cout {
            let mut acc = bias[co];
            for ci in 0..cin {
                acc += f[ci] * w[ci * cout + co];
            }
            out.feats.push(act.apply(acc));
        }
    }
    out
}

/// k×k **submanifold** convolution, stride 1, pad (k−1)/2.
/// Full conv weights: `w[off][ci][co]` flattened as `w[(off*cin + ci)*cout + co]`.
pub fn conv_kxk_s1_f32(
    input: &SparseMap<f32>,
    k: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    act: Act,
) -> SparseMap<f32> {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    let u = (k - 1) / 2;
    let bm = input.bitmap();
    let mut out = SparseMap::empty(input.w, input.h, cout);
    out.tokens = input.tokens.clone();
    out.feats.reserve(out.tokens.len() * cout);
    let mut acc = vec![0f32; cout];
    for t in &input.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let (ix, iy) = (ix as usize, iy as usize);
                if !bm.get(ix, iy) {
                    continue;
                }
                // lint:allow(panic): bitmap set => token present (same map built them)
                let ni = input.find(ix as u16, iy as u16).expect("bitmap/token mismatch");
                let nf = input.feat(ni);
                let off = dy * k + dx;
                let wbase = off * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci];
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co];
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(act.apply(acc[co]));
        }
    }
    out
}

/// k×k **depthwise submanifold** convolution, stride 1.
/// Weights `w[off][c]` flattened as `w[off * c + ch]`.
pub fn dwconv_kxk_s1_f32(
    input: &SparseMap<f32>,
    k: usize,
    w: &[f32],
    bias: &[f32],
    act: Act,
) -> SparseMap<f32> {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    let u = (k - 1) / 2;
    let bm = input.bitmap();
    let mut out = SparseMap::empty(input.w, input.h, c);
    out.tokens = input.tokens.clone();
    out.feats.reserve(out.tokens.len() * c);
    let mut acc = vec![0f32; c];
    for t in &input.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let (ix, iy) = (ix as usize, iy as usize);
                if !bm.get(ix, iy) {
                    continue;
                }
                // lint:allow(panic): bitmap set => token present (same map built them)
                let ni = input.find(ix as u16, iy as u16).unwrap();
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] * w[off * c + ch];
                }
            }
        }
        for ch in 0..c {
            out.feats.push(act.apply(acc[ch]));
        }
    }
    out
}

/// Output tokens of a stride-2 sparse conv (paper Fig. 3b / Eqn. 4): an
/// output coordinate exists iff its 2×2 input grid contains any nonzero.
pub fn downsample_tokens(input_bitmap: &super::Bitmap) -> Vec<Token> {
    let ds = input_bitmap.downsample_sparse(2);
    ds.iter_set()
        .map(|(x, y)| Token::new(x as u16, y as u16))
        .collect()
}

/// k×k sparse convolution, stride 2, pad (k−1)/2 (full weights as in
/// [`conv_kxk_s1_f32`]). Output is `ceil(w/2) × ceil(h/2)`.
pub fn conv_kxk_s2_f32(
    input: &SparseMap<f32>,
    k: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    act: Act,
) -> SparseMap<f32> {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    let pad = (k - 1) / 2;
    let bm = input.bitmap();
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    let mut out = SparseMap::empty(ow, oh, cout);
    out.tokens = downsample_tokens(&bm);
    out.feats.reserve(out.tokens.len() * cout);
    let mut acc = vec![0f32; cout];
    for t in &out.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let (ix, iy) = (ix as usize, iy as usize);
                if !bm.get(ix, iy) {
                    continue;
                }
                // lint:allow(panic): bitmap set => token present (same map built them)
                let ni = input.find(ix as u16, iy as u16).unwrap();
                let nf = input.feat(ni);
                let off = dy * k + dx;
                let wbase = off * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci];
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co];
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(act.apply(acc[co]));
        }
    }
    out
}

/// Depthwise variant of [`conv_kxk_s2_f32`].
pub fn dwconv_kxk_s2_f32(
    input: &SparseMap<f32>,
    k: usize,
    w: &[f32],
    bias: &[f32],
    act: Act,
) -> SparseMap<f32> {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    let pad = (k - 1) / 2;
    let bm = input.bitmap();
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    let mut out = SparseMap::empty(ow, oh, c);
    out.tokens = downsample_tokens(&bm);
    out.feats.reserve(out.tokens.len() * c);
    let mut acc = vec![0f32; c];
    for t in &out.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let (ix, iy) = (ix as usize, iy as usize);
                if !bm.get(ix, iy) {
                    continue;
                }
                // lint:allow(panic): bitmap set => token present (same map built them)
                let ni = input.find(ix as u16, iy as u16).unwrap();
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] * w[off * c + ch];
                }
            }
        }
        for ch in 0..c {
            out.feats.push(act.apply(acc[ch]));
        }
    }
    out
}

/// Residual add: tokens must be identical (submanifold block, Fig. 10).
pub fn residual_add_f32(a: &SparseMap<f32>, b: &SparseMap<f32>) -> SparseMap<f32> {
    assert_eq!(a.tokens, b.tokens, "residual branches must share tokens");
    assert_eq!(a.c, b.c);
    let mut out = a.clone();
    for (o, r) in out.feats.iter_mut().zip(&b.feats) {
        *o += r;
    }
    out
}

/// Global average pooling over nonzero tokens (MinkowskiEngine semantics:
/// divide by the number of nonzero coordinates, not H·W).
pub fn global_avg_pool_f32(input: &SparseMap<f32>) -> Vec<f32> {
    let n = input.nnz().max(1);
    let mut acc = vec![0f32; input.c];
    for i in 0..input.nnz() {
        for (a, &v) in acc.iter_mut().zip(input.feat(i)) {
            *a += v;
        }
    }
    for a in acc.iter_mut() {
        *a /= n as f32;
    }
    acc
}

/// Fully connected head: `out[co] = Σ_ci in[ci]·w[ci*cout+co] + bias[co]`.
pub fn fc_f32(input: &[f32], w: &[f32], bias: &[f32], cout: usize) -> Vec<f32> {
    let cin = input.len();
    assert_eq!(w.len(), cin * cout);
    (0..cout)
        .map(|co| {
            let mut acc = bias[co];
            for ci in 0..cin {
                acc += input[ci] * w[ci * cout + co];
            }
            acc
        })
        .collect()
}

/// **Standard** dense convolution on the materialized dense tensor — the
/// oracle for submanifold implementations and the Fig. 12 standard twin.
/// Returns a dense `oh × ow × cout` array; `stride ∈ {1, 2}`, pad (k−1)/2.
pub fn standard_conv_dense_f32(
    dense: &[f32],
    w_in: usize,
    h_in: usize,
    cin: usize,
    k: usize,
    stride: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
) -> (Vec<f32>, usize, usize) {
    assert_eq!(dense.len(), h_in * w_in * cin);
    let pad = (k - 1) / 2;
    let ow = (w_in + stride - 1) / stride;
    let oh = (h_in + stride - 1) / stride;
    let mut out = vec![0f32; oh * ow * cout];
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..cout {
                let mut acc = bias[co];
                for dy in 0..k {
                    for dx in 0..k {
                        let ix = ox as isize * stride as isize + dx as isize - pad as isize;
                        let iy = oy as isize * stride as isize + dy as isize - pad as isize;
                        if ix < 0 || iy < 0 || ix as usize >= w_in || iy as usize >= h_in {
                            continue;
                        }
                        let base = (iy as usize * w_in + ix as usize) * cin;
                        let wbase = (dy * k + dx) * cin * cout;
                        for ci in 0..cin {
                            acc += dense[base + ci] * w[wbase + ci * cout + co];
                        }
                    }
                }
                out[(oy * ow + ox) * cout + co] = acc;
            }
        }
    }
    (out, ow, oh)
}

// ---------------------------------------------------------------------------
// int8 hardware-exact path — arena (`_into`) kernels
//
// The compile-once/execute-many engine (`model::plan`) calls these with
// buffers owned by a per-worker `ExecCtx`, so steady-state inference does
// zero per-layer heap allocation: outputs are `reset` (capacity kept),
// neighbor lookups go through a reusable `NeighborIndex` grid, and the
// int32 accumulator is caller-provided. The classic allocating functions
// below are thin wrappers over these and remain the numerics oracle the
// cycle-level simulator and the golden tests check against. Integer
// arithmetic makes both paths bit-identical by construction.
// ---------------------------------------------------------------------------
// lint: hot-path — arena kernels below must not heap-allocate per call

/// Arena variant of [`conv1x1_i8`]: pointwise loop runs ci-outer/co-inner
/// so the `[ci][co]` weight rows are walked contiguously.
pub fn conv1x1_i8_into(
    input: &SparseMap<i8>,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) {
    let cin = input.c;
    assert_eq!(w.len(), cin * cout);
    assert_eq!(bias.len(), cout);
    out.reset(input.w, input.h, cout);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * cout);
    acc.clear();
    acc.resize(cout, 0);
    for i in 0..input.nnz() {
        let f = input.feat(i);
        acc.copy_from_slice(bias);
        for ci in 0..cin {
            let a = f[ci] as i32;
            let wrow = ci * cout;
            for co in 0..cout {
                acc[co] += a * w[wrow + co] as i32;
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
}

/// Arena variant of [`conv_kxk_s1_i8`] (full k×k submanifold, stride 1).
#[allow(clippy::too_many_arguments)]
pub fn conv_kxk_s1_i8_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    idx: &mut NeighborIndex,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    assert_eq!(bias.len(), cout);
    let u = (k - 1) / 2;
    idx.build(input);
    out.reset(input.w, input.h, cout);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * cout);
    acc.clear();
    acc.resize(cout, 0);
    for t in &input.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let wbase = (dy * k + dx) * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci] as i32;
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co] as i32;
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
}

/// Arena variant of [`dwconv_kxk_s1_i8`].
#[allow(clippy::too_many_arguments)]
pub fn dwconv_kxk_s1_i8_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
    idx: &mut NeighborIndex,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    assert_eq!(bias.len(), c);
    let u = (k - 1) / 2;
    idx.build(input);
    out.reset(input.w, input.h, c);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * c);
    acc.clear();
    acc.resize(c, 0);
    for t in &input.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] as i32 * w[off * c + ch] as i32;
                }
            }
        }
        for ch in 0..c {
            out.feats.push(rq.apply(acc[ch]));
        }
    }
}

/// Derive the stride-2 output tokens of `input` into `out_tokens`, using
/// `ds` as bitmap scratch — the arena equivalent of
/// [`downsample_tokens`]`(&input.bitmap())`.
fn downsample_tokens_from_map<T>(
    input: &SparseMap<T>,
    ds: &mut Bitmap,
    out_tokens: &mut Vec<Token>,
) {
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    ds.reset(ow, oh);
    for t in &input.tokens {
        ds.set(t.x as usize / 2, t.y as usize / 2);
    }
    out_tokens.clear();
    for (x, y) in ds.iter_set() {
        out_tokens.push(Token::new(x as u16, y as u16));
    }
}

/// Arena variant of [`conv_kxk_s2_i8`] (full k×k sparse conv, stride 2).
#[allow(clippy::too_many_arguments)]
pub fn conv_kxk_s2_i8_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    idx: &mut NeighborIndex,
    ds: &mut Bitmap,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    assert_eq!(bias.len(), cout);
    let pad = (k - 1) / 2;
    idx.build(input);
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    out.reset(ow, oh, cout);
    downsample_tokens_from_map(input, ds, &mut out.tokens);
    out.feats.reserve(out.tokens.len() * cout);
    acc.clear();
    acc.resize(cout, 0);
    for t in &out.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let wbase = (dy * k + dx) * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci] as i32;
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co] as i32;
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
}

/// Arena variant of [`dwconv_kxk_s2_i8`].
#[allow(clippy::too_many_arguments)]
pub fn dwconv_kxk_s2_i8_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
    idx: &mut NeighborIndex,
    ds: &mut Bitmap,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    assert_eq!(bias.len(), c);
    let pad = (k - 1) / 2;
    idx.build(input);
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    out.reset(ow, oh, c);
    downsample_tokens_from_map(input, ds, &mut out.tokens);
    out.feats.reserve(out.tokens.len() * c);
    acc.clear();
    acc.resize(c, 0);
    for t in &out.tokens {
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] as i32 * w[off * c + ch] as i32;
                }
            }
        }
        for ch in 0..c {
            out.feats.push(rq.apply(acc[ch]));
        }
    }
}

/// In-place residual add: `cur += shortcut` with int8 saturation.
pub fn residual_add_i8_inplace(cur: &mut SparseMap<i8>, shortcut: &SparseMap<i8>) {
    assert_eq!(cur.tokens, shortcut.tokens, "residual branches must share tokens");
    assert_eq!(cur.c, shortcut.c);
    for (o, r) in cur.feats.iter_mut().zip(&shortcut.feats) {
        *o = (*o as i32 + *r as i32).clamp(-128, 127) as i8;
    }
}

/// Arena variant of [`global_avg_pool_i8`]; `acc64` is the caller's i64
/// accumulator scratch, `out` receives the pooled int32 vector.
pub fn global_avg_pool_i8_into(input: &SparseMap<i8>, acc64: &mut Vec<i64>, out: &mut Vec<i32>) {
    let n = input.nnz().max(1) as i64;
    acc64.clear();
    acc64.resize(input.c, 0);
    for i in 0..input.nnz() {
        for (a, &v) in acc64.iter_mut().zip(input.feat(i)) {
            *a += v as i64;
        }
    }
    out.clear();
    out.reserve(input.c);
    for &s in acc64.iter() {
        let half = if s >= 0 { n / 2 } else { -(n / 2) };
        out.push(((s + half) / n) as i32);
    }
}

/// Arena FC head over **transposed** weights `wt[co * cin + ci]` (the
/// `ExecPlan` stores the FC matrix transposed so each output's dot product
/// walks a contiguous row). Bit-identical to [`fc_i8`] on the untransposed
/// matrix.
pub fn fc_i8_t_into(input: &[i32], wt: &[i8], bias: &[i32], cout: usize, out: &mut Vec<i32>) {
    let cin = input.len();
    assert_eq!(wt.len(), cin * cout);
    assert_eq!(bias.len(), cout);
    out.clear();
    out.reserve(cout);
    for co in 0..cout {
        let mut acc = bias[co];
        let row = &wt[co * cin..(co + 1) * cin];
        for ci in 0..cin {
            acc += input[ci] * row[ci] as i32;
        }
        out.push(acc);
    }
}

// ---------------------------------------------------------------------------
// int8 hardware-exact path — delta (partial-update) kernels
//
// Incremental inference across overlapping windows: when only a few input
// sites changed since the previous window, each layer only needs to
// recompute the outputs whose receptive field touches a changed site. The
// caller (`model::plan::ExecPlan::execute_delta`) propagates a dirty-site
// frontier layer by layer (`Bitmap::dilate_into` for stride 1,
// `Bitmap::downsample_dirty_into` for stride 2) and hands each kernel:
//
// - `dirty`: the dirty set at **output** resolution — every output site
//   whose value or existence may differ from the previous window,
// - `prev`: this layer's cached output from the previous window.
//
// Clean outputs are copied from `prev` via a monotone merge pointer (both
// token lists are in strictly increasing ravel order); dirty outputs run
// the full window accumulation. A clean token absent from `prev` would
// mean the frontier under-approximated the change set — we recompute it
// defensively so the kernels are bit-exact *unconditionally*, and the
// plan-level property tests check the frontier is in fact sound. Each
// kernel returns the number of recomputed sites for the metrics/report.
// ---------------------------------------------------------------------------

/// Advance `*pi` through `prev`'s ravel-ordered tokens to `(x, y)`;
/// `Some(i)` iff the site existed in the previous window's output.
#[inline]
fn merge_find(prev: &SparseMap<i8>, pi: &mut usize, x: u16, y: u16) -> Option<usize> {
    let target = Token::new(x, y).ravel(prev.w);
    while *pi < prev.tokens.len() && prev.tokens[*pi].ravel(prev.w) < target {
        *pi += 1;
    }
    (*pi < prev.tokens.len() && prev.tokens[*pi].ravel(prev.w) == target).then_some(*pi)
}

/// Delta variant of [`conv1x1_i8_into`]. `dirty` is at input (= output)
/// resolution; returns the number of recomputed sites.
#[allow(clippy::too_many_arguments)]
pub fn conv1x1_i8_delta_into(
    input: &SparseMap<i8>,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    dirty: &Bitmap,
    prev: &SparseMap<i8>,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) -> usize {
    let cin = input.c;
    assert_eq!(w.len(), cin * cout);
    assert_eq!(bias.len(), cout);
    debug_assert_eq!((dirty.w, dirty.h), (input.w, input.h));
    debug_assert_eq!((prev.w, prev.h, prev.c), (input.w, input.h, cout));
    out.reset(input.w, input.h, cout);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * cout);
    acc.clear();
    acc.resize(cout, 0);
    let mut pi = 0usize;
    let mut recomputed = 0usize;
    for i in 0..input.nnz() {
        let t = input.tokens[i];
        if !dirty.get(t.x as usize, t.y as usize) {
            if let Some(p) = merge_find(prev, &mut pi, t.x, t.y) {
                out.feats.extend_from_slice(prev.feat(p));
                continue;
            }
        }
        recomputed += 1;
        let f = input.feat(i);
        acc.copy_from_slice(bias);
        for ci in 0..cin {
            let a = f[ci] as i32;
            let wrow = ci * cout;
            for co in 0..cout {
                acc[co] += a * w[wrow + co] as i32;
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
    recomputed
}

/// Delta variant of [`conv_kxk_s1_i8_into`]. `dirty` is at input (= output)
/// resolution, already dilated by the kernel's receptive radius; returns
/// the number of recomputed sites.
#[allow(clippy::too_many_arguments)]
pub fn conv_kxk_s1_i8_delta_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    dirty: &Bitmap,
    prev: &SparseMap<i8>,
    idx: &mut NeighborIndex,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) -> usize {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    assert_eq!(bias.len(), cout);
    debug_assert_eq!((dirty.w, dirty.h), (input.w, input.h));
    debug_assert_eq!((prev.w, prev.h, prev.c), (input.w, input.h, cout));
    let u = (k - 1) / 2;
    idx.build(input);
    out.reset(input.w, input.h, cout);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * cout);
    acc.clear();
    acc.resize(cout, 0);
    let mut pi = 0usize;
    let mut recomputed = 0usize;
    for t in &input.tokens {
        if !dirty.get(t.x as usize, t.y as usize) {
            if let Some(p) = merge_find(prev, &mut pi, t.x, t.y) {
                out.feats.extend_from_slice(prev.feat(p));
                continue;
            }
        }
        recomputed += 1;
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let wbase = (dy * k + dx) * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci] as i32;
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co] as i32;
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
    recomputed
}

/// Delta variant of [`dwconv_kxk_s1_i8_into`].
#[allow(clippy::too_many_arguments)]
pub fn dwconv_kxk_s1_i8_delta_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
    dirty: &Bitmap,
    prev: &SparseMap<i8>,
    idx: &mut NeighborIndex,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) -> usize {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    assert_eq!(bias.len(), c);
    debug_assert_eq!((dirty.w, dirty.h), (input.w, input.h));
    debug_assert_eq!((prev.w, prev.h, prev.c), (input.w, input.h, c));
    let u = (k - 1) / 2;
    idx.build(input);
    out.reset(input.w, input.h, c);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.nnz() * c);
    acc.clear();
    acc.resize(c, 0);
    let mut pi = 0usize;
    let mut recomputed = 0usize;
    for t in &input.tokens {
        if !dirty.get(t.x as usize, t.y as usize) {
            if let Some(p) = merge_find(prev, &mut pi, t.x, t.y) {
                out.feats.extend_from_slice(prev.feat(p));
                continue;
            }
        }
        recomputed += 1;
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize + dx as isize - u as isize;
                let iy = t.y as isize + dy as isize - u as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] as i32 * w[off * c + ch] as i32;
                }
            }
        }
        for ch in 0..c {
            out.feats.push(rq.apply(acc[ch]));
        }
    }
    recomputed
}

/// Delta variant of [`conv_kxk_s2_i8_into`]. `dirty` is at **output**
/// (downsampled) resolution, per [`Bitmap::downsample_dirty_into`];
/// returns the number of recomputed sites.
#[allow(clippy::too_many_arguments)]
pub fn conv_kxk_s2_i8_delta_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
    dirty: &Bitmap,
    prev: &SparseMap<i8>,
    idx: &mut NeighborIndex,
    ds: &mut Bitmap,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) -> usize {
    let cin = input.c;
    assert_eq!(w.len(), k * k * cin * cout);
    assert_eq!(bias.len(), cout);
    let pad = (k - 1) / 2;
    idx.build(input);
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    debug_assert_eq!((dirty.w, dirty.h), (ow, oh));
    debug_assert_eq!((prev.w, prev.h, prev.c), (ow, oh, cout));
    out.reset(ow, oh, cout);
    downsample_tokens_from_map(input, ds, &mut out.tokens);
    out.feats.reserve(out.tokens.len() * cout);
    acc.clear();
    acc.resize(cout, 0);
    let mut pi = 0usize;
    let mut recomputed = 0usize;
    for ti in 0..out.tokens.len() {
        let t = out.tokens[ti];
        if !dirty.get(t.x as usize, t.y as usize) {
            if let Some(p) = merge_find(prev, &mut pi, t.x, t.y) {
                out.feats.extend_from_slice(prev.feat(p));
                continue;
            }
        }
        recomputed += 1;
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let wbase = (dy * k + dx) * cin * cout;
                for ci in 0..cin {
                    let a = nf[ci] as i32;
                    let wrow = wbase + ci * cout;
                    for co in 0..cout {
                        acc[co] += a * w[wrow + co] as i32;
                    }
                }
            }
        }
        for co in 0..cout {
            out.feats.push(rq.apply(acc[co]));
        }
    }
    recomputed
}

/// Delta variant of [`dwconv_kxk_s2_i8_into`].
#[allow(clippy::too_many_arguments)]
pub fn dwconv_kxk_s2_i8_delta_into(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
    dirty: &Bitmap,
    prev: &SparseMap<i8>,
    idx: &mut NeighborIndex,
    ds: &mut Bitmap,
    acc: &mut Vec<i32>,
    out: &mut SparseMap<i8>,
) -> usize {
    let c = input.c;
    assert_eq!(w.len(), k * k * c);
    assert_eq!(bias.len(), c);
    let pad = (k - 1) / 2;
    idx.build(input);
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    debug_assert_eq!((dirty.w, dirty.h), (ow, oh));
    debug_assert_eq!((prev.w, prev.h, prev.c), (ow, oh, c));
    out.reset(ow, oh, c);
    downsample_tokens_from_map(input, ds, &mut out.tokens);
    out.feats.reserve(out.tokens.len() * c);
    acc.clear();
    acc.resize(c, 0);
    let mut pi = 0usize;
    let mut recomputed = 0usize;
    for ti in 0..out.tokens.len() {
        let t = out.tokens[ti];
        if !dirty.get(t.x as usize, t.y as usize) {
            if let Some(p) = merge_find(prev, &mut pi, t.x, t.y) {
                out.feats.extend_from_slice(prev.feat(p));
                continue;
            }
        }
        recomputed += 1;
        acc.copy_from_slice(bias);
        for dy in 0..k {
            for dx in 0..k {
                let ix = t.x as isize * 2 + dx as isize - pad as isize;
                let iy = t.y as isize * 2 + dy as isize - pad as isize;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                let ni = match idx.find(ix as usize, iy as usize) {
                    Some(i) => i,
                    None => continue,
                };
                let nf = input.feat(ni);
                let off = dy * k + dx;
                for ch in 0..c {
                    acc[ch] += nf[ch] as i32 * w[off * c + ch] as i32;
                }
            }
        }
        for ch in 0..c {
            out.feats.push(rq.apply(acc[ch]));
        }
    }
    recomputed
}
// lint: hot-path end

// ---------------------------------------------------------------------------
// int8 hardware-exact path — classic allocating API (thin wrappers)
// ---------------------------------------------------------------------------

/// 1×1 convolution, int8 in / int8 out, int32 accumulate, dyadic requant.
/// Weights `w[ci * cout + co]` int8, `bias[co]` int32 (input-scale · w-scale).
pub fn conv1x1_i8(
    input: &SparseMap<i8>,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
) -> SparseMap<i8> {
    let mut out = SparseMap::empty(input.w, input.h, cout);
    let mut acc = Vec::new();
    conv1x1_i8_into(input, w, bias, cout, rq, &mut acc, &mut out);
    out
}

/// Full k×k submanifold convolution, stride 1, int8 (the stem layer).
pub fn conv_kxk_s1_i8(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
) -> SparseMap<i8> {
    let mut out = SparseMap::empty(input.w, input.h, cout);
    let mut idx = NeighborIndex::new();
    let mut acc = Vec::new();
    conv_kxk_s1_i8_into(input, k, w, bias, cout, rq, &mut idx, &mut acc, &mut out);
    out
}

/// k×k depthwise submanifold convolution, stride 1, int8.
pub fn dwconv_kxk_s1_i8(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
) -> SparseMap<i8> {
    let mut out = SparseMap::empty(input.w, input.h, input.c);
    let mut idx = NeighborIndex::new();
    let mut acc = Vec::new();
    dwconv_kxk_s1_i8_into(input, k, w, bias, rq, &mut idx, &mut acc, &mut out);
    out
}

/// k×k full sparse convolution, stride 2, int8.
pub fn conv_kxk_s2_i8(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    cout: usize,
    rq: &Requant,
) -> SparseMap<i8> {
    let mut out = SparseMap::empty((input.w + 1) / 2, (input.h + 1) / 2, cout);
    let mut idx = NeighborIndex::new();
    let mut ds = Bitmap::new(0, 0);
    let mut acc = Vec::new();
    conv_kxk_s2_i8_into(input, k, w, bias, cout, rq, &mut idx, &mut ds, &mut acc, &mut out);
    out
}

/// k×k depthwise sparse convolution, stride 2, int8.
pub fn dwconv_kxk_s2_i8(
    input: &SparseMap<i8>,
    k: usize,
    w: &[i8],
    bias: &[i32],
    rq: &Requant,
) -> SparseMap<i8> {
    let mut out = SparseMap::empty((input.w + 1) / 2, (input.h + 1) / 2, input.c);
    let mut idx = NeighborIndex::new();
    let mut ds = Bitmap::new(0, 0);
    let mut acc = Vec::new();
    dwconv_kxk_s2_i8_into(input, k, w, bias, rq, &mut idx, &mut ds, &mut acc, &mut out);
    out
}

/// Residual add in int8: saturating add of requantized branches (both
/// branches must already be at the same output scale — the quantizer
/// arranges this, matching HAWQ-V3's shared-scale residual handling).
pub fn residual_add_i8(a: &SparseMap<i8>, b: &SparseMap<i8>) -> SparseMap<i8> {
    let mut out = a.clone();
    residual_add_i8_inplace(&mut out, b);
    out
}

/// Global average pooling, int8 → int32 sum with hardware-style division:
/// multiply by the reciprocal in fixed point (the pooling module divides by
/// the *token count*, known only at `.end`; hardware uses one int divide —
/// we model exact integer division with round-half-up).
pub fn global_avg_pool_i8(input: &SparseMap<i8>) -> Vec<i32> {
    let mut acc64 = Vec::new();
    let mut out = Vec::new();
    global_avg_pool_i8_into(input, &mut acc64, &mut out);
    out
}

/// Fully connected head, int8 weights on int32 pooled input; returns raw
/// int32 logits (the classifier output needs no requantization).
pub fn fc_i8(input: &[i32], w: &[i8], bias: &[i32], cout: usize) -> Vec<i32> {
    let cin = input.len();
    assert_eq!(w.len(), cin * cout);
    (0..cout)
        .map(|co| {
            let mut acc = bias[co];
            for ci in 0..cin {
                acc += input[ci] * w[ci * cout + co] as i32;
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::map::random_map;
    use crate::util::propcheck::{check, Gen};

    fn rand_vec(g: &mut Gen, n: usize) -> Vec<f32> {
        (0..n).map(|_| (g.f64() as f32 - 0.5) * 2.0).collect()
    }

    /// Submanifold s1 conv must equal standard conv *at the nonzero input
    /// locations* (that is its definition).
    #[test]
    fn submanifold_s1_matches_dense_at_tokens() {
        check("kxk s1 submanifold == dense conv at tokens", 48, |g| {
            let w = g.usize(3, 12);
            let h = g.usize(3, 12);
            let cin = g.usize(1, 3);
            let cout = g.usize(1, 3);
            let k = 3;
            let m = random_map(g.rng(), w, h, cin, 0.3);
            let wt = rand_vec(g, k * k * cin * cout);
            let b = rand_vec(g, cout);
            let sub = conv_kxk_s1_f32(&m, k, &wt, &b, cout, Act::None);
            let (dense_out, ow, _oh) =
                standard_conv_dense_f32(&m.to_dense(), w, h, cin, k, 1, &wt, &b, cout);
            assert_eq!(sub.tokens, m.tokens);
            for (i, t) in sub.tokens.iter().enumerate() {
                let base = (t.y as usize * ow + t.x as usize) * cout;
                for co in 0..cout {
                    let d = dense_out[base + co];
                    let s = sub.feat(i)[co];
                    assert!((d - s).abs() < 1e-4, "({},{})[{co}]: dense {d} sub {s}", t.x, t.y);
                }
            }
        });
    }

    #[test]
    fn dwconv_s1_matches_full_with_diagonal_weights() {
        check("depthwise == full conv with diagonal kernel", 48, |g| {
            let w = g.usize(3, 10);
            let h = g.usize(3, 10);
            let c = g.usize(1, 4);
            let k = 3;
            let m = random_map(g.rng(), w, h, c, 0.35);
            let dwt = rand_vec(g, k * k * c);
            let b = rand_vec(g, c);
            // Embed depthwise weights into a full conv kernel with zeros
            // off-diagonal.
            let mut full = vec![0f32; k * k * c * c];
            for off in 0..k * k {
                for ch in 0..c {
                    full[(off * c + ch) * c + ch] = dwt[off * c + ch];
                }
            }
            let a = dwconv_kxk_s1_f32(&m, k, &dwt, &b, Act::None);
            let e = conv_kxk_s1_f32(&m, k, &full, &b, c, Act::None);
            assert_eq!(a.tokens, e.tokens);
            for (x, y) in a.feats.iter().zip(&e.feats) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn conv1x1_is_kxk_with_k1() {
        check("1x1 module == k=1 conv", 48, |g| {
            let w = g.usize(2, 10);
            let h = g.usize(2, 10);
            let cin = g.usize(1, 4);
            let cout = g.usize(1, 4);
            let m = random_map(g.rng(), w, h, cin, 0.4);
            let wt = rand_vec(g, cin * cout);
            let b = rand_vec(g, cout);
            let a = conv1x1_f32(&m, &wt, &b, cout, Act::Relu);
            let e = conv_kxk_s1_f32(&m, 1, &wt, &b, cout, Act::Relu);
            assert_eq!(a, e);
        });
    }

    #[test]
    fn s2_tokens_follow_grid_rule_and_order() {
        check("stride-2 token rule + ravel order", 64, |g| {
            let w = g.usize(2, 16);
            let h = g.usize(2, 16);
            let m = random_map(g.rng(), w, h, 1, 0.25);
            let wt = rand_vec(g, 9);
            let b = rand_vec(g, 1);
            let out = dwconv_kxk_s2_f32(&m, 3, &wt, &b, Act::None);
            out.validate().unwrap();
            let expect = m.bitmap().downsample_sparse(2);
            assert_eq!(out.bitmap(), expect);
        });
    }

    #[test]
    fn s2_features_match_dense_at_output_tokens() {
        check("kxk s2 sparse == dense strided conv at tokens", 48, |g| {
            let w = g.usize(4, 12);
            let h = g.usize(4, 12);
            let cin = g.usize(1, 3);
            let cout = g.usize(1, 3);
            let k = 3;
            let m = random_map(g.rng(), w, h, cin, 0.3);
            let wt = rand_vec(g, k * k * cin * cout);
            let b = rand_vec(g, cout);
            let sp = conv_kxk_s2_f32(&m, k, &wt, &b, cout, Act::None);
            let (dense_out, ow, _) =
                standard_conv_dense_f32(&m.to_dense(), w, h, cin, k, 2, &wt, &b, cout);
            for (i, t) in sp.tokens.iter().enumerate() {
                let base = (t.y as usize * ow + t.x as usize) * cout;
                for co in 0..cout {
                    let d = dense_out[base + co];
                    let s = sp.feat(i)[co];
                    assert!((d - s).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn residual_requires_matching_tokens() {
        let mut r = crate::util::Rng::new(4);
        let a = random_map(&mut r, 8, 8, 2, 0.3);
        let sum = residual_add_f32(&a, &a);
        for (s, x) in sum.feats.iter().zip(&a.feats) {
            assert_eq!(*s, x * 2.0);
        }
    }

    #[test]
    fn pool_averages_over_tokens_only() {
        let mut m: SparseMap<f32> = SparseMap::empty(4, 4, 2);
        m.push(Token::new(0, 0), &[1.0, 10.0]);
        m.push(Token::new(3, 3), &[3.0, 30.0]);
        let p = global_avg_pool_f32(&m);
        assert_eq!(p, vec![2.0, 20.0]);
    }

    #[test]
    fn fc_basic() {
        let out = fc_f32(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], &[0.5, -0.5], 2);
        assert_eq!(out, vec![1.5, 1.5]);
    }

    /// int8 layers approximate their f32 twins after symmetric quantization.
    #[test]
    fn i8_conv1x1_tracks_f32() {
        check("int8 1x1 ≈ f32 1x1", 32, |g| {
            let w = g.usize(2, 8);
            let h = g.usize(2, 8);
            let cin = g.usize(1, 4);
            let cout = g.usize(1, 4);
            let mf = random_map(g.rng(), w, h, cin, 0.4);
            let wt = rand_vec(g, cin * cout);
            // Quantize activations and weights.
            let (sa, qa) = super::super::quant::quantize_symmetric(&mf.feats);
            let (sw, qw) = super::super::quant::quantize_symmetric(&wt);
            let mut mi: SparseMap<i8> = SparseMap::empty(w, h, cin);
            mi.tokens = mf.tokens.clone();
            mi.feats = qa;
            let so = 0.05f32; // output scale
            let rq = Requant::from_scale((sa * sw / so) as f64, -128, 127);
            let bias = vec![0i32; cout];
            let qi = conv1x1_i8(&mi, &qw, &bias, cout, &rq);
            let bf = vec![0f32; cout];
            let qf = conv1x1_f32(&mf, &wt, &bf, cout, Act::None);
            for i in 0..qf.nnz() {
                for co in 0..cout {
                    let f = qf.feat(i)[co];
                    let fx = qi.feat(i)[co] as f32 * so;
                    // Error budget: activation quant + weight quant + requant.
                    let tol = (cin as f32).sqrt() * (sa + sw) * 2.0 + so;
                    assert!(
                        (f - fx).abs() <= tol.max(0.2),
                        "i={i} co={co}: f32 {f} vs int8 {fx} (tol {tol})"
                    );
                }
            }
        });
    }

    fn random_map_i8(g: &mut Gen, w: usize, h: usize, c: usize, p: f64) -> SparseMap<i8> {
        let mut m: SparseMap<i8> = SparseMap::empty(w, h, c);
        for y in 0..h {
            for x in 0..w {
                if g.chance(p) {
                    let f: Vec<i8> = (0..c).map(|_| g.i64(-128, 127) as i8).collect();
                    m.push(Token::new(x as u16, y as u16), &f);
                }
            }
        }
        m
    }

    fn rand_w_i8(g: &mut Gen, n: usize) -> Vec<i8> {
        (0..n).map(|_| g.i64(-128, 127) as i8).collect()
    }

    /// The arena kernels must produce identical maps when their scratch
    /// buffers are dirty from a *previous, differently-shaped* layer — the
    /// exact reuse pattern of `model::plan`'s steady state.
    #[test]
    fn arena_kernels_match_allocating_with_dirty_buffers() {
        check("i8 _into kernels == allocating kernels under reuse", 32, |g| {
            let rq = Requant::from_scale(0.37, -128, 127);
            let mut idx = NeighborIndex::new();
            let mut ds = Bitmap::new(0, 0);
            let mut acc = Vec::new();
            let mut out: SparseMap<i8> = SparseMap::empty(0, 0, 0);
            for _ in 0..3 {
                let w = g.usize(2, 12);
                let h = g.usize(2, 12);
                let cin = g.usize(1, 4);
                let cout = g.usize(1, 4);
                let k = 3;
                let m = random_map_i8(g, w, h, cin, 0.35);
                let bias: Vec<i32> = (0..cout.max(cin)).map(|_| g.i64(-64, 64) as i32).collect();

                let wt = rand_w_i8(g, cin * cout);
                conv1x1_i8_into(&m, &wt, &bias[..cout], cout, &rq, &mut acc, &mut out);
                assert_eq!(out, conv1x1_i8(&m, &wt, &bias[..cout], cout, &rq));

                let wt = rand_w_i8(g, k * k * cin * cout);
                conv_kxk_s1_i8_into(
                    &m,
                    k,
                    &wt,
                    &bias[..cout],
                    cout,
                    &rq,
                    &mut idx,
                    &mut acc,
                    &mut out,
                );
                assert_eq!(out, conv_kxk_s1_i8(&m, k, &wt, &bias[..cout], cout, &rq));
                conv_kxk_s2_i8_into(
                    &m,
                    k,
                    &wt,
                    &bias[..cout],
                    cout,
                    &rq,
                    &mut idx,
                    &mut ds,
                    &mut acc,
                    &mut out,
                );
                assert_eq!(out, conv_kxk_s2_i8(&m, k, &wt, &bias[..cout], cout, &rq));

                let wt = rand_w_i8(g, k * k * cin);
                dwconv_kxk_s1_i8_into(&m, k, &wt, &bias[..cin], &rq, &mut idx, &mut acc, &mut out);
                assert_eq!(out, dwconv_kxk_s1_i8(&m, k, &wt, &bias[..cin], &rq));
                dwconv_kxk_s2_i8_into(
                    &m,
                    k,
                    &wt,
                    &bias[..cin],
                    &rq,
                    &mut idx,
                    &mut ds,
                    &mut acc,
                    &mut out,
                );
                assert_eq!(out, dwconv_kxk_s2_i8(&m, k, &wt, &bias[..cin], &rq));
            }
        });
    }

    /// FC over transposed weights must equal the classic FC bit-for-bit.
    #[test]
    fn fc_transposed_matches_classic() {
        check("fc_i8_t_into == fc_i8", 48, |g| {
            let cin = g.usize(1, 8);
            let cout = g.usize(1, 6);
            let input: Vec<i32> = (0..cin).map(|_| g.i64(-1000, 1000) as i32).collect();
            let w = rand_w_i8(g, cin * cout);
            let bias: Vec<i32> = (0..cout).map(|_| g.i64(-100, 100) as i32).collect();
            let mut wt = vec![0i8; cin * cout];
            for ci in 0..cin {
                for co in 0..cout {
                    wt[co * cin + ci] = w[ci * cout + co];
                }
            }
            let mut got = Vec::new();
            fc_i8_t_into(&input, &wt, &bias, cout, &mut got);
            assert_eq!(got, fc_i8(&input, &w, &bias, cout));
        });
    }

    /// Input-diff bitmap: sites where token presence or features differ.
    fn diff_bitmap(prev: &SparseMap<i8>, new: &SparseMap<i8>) -> Bitmap {
        let mut d = Bitmap::new(new.w, new.h);
        for (i, t) in new.tokens.iter().enumerate() {
            match prev.find(t.x, t.y) {
                Some(p) if prev.feat(p) == new.feat(i) => {}
                _ => d.set(t.x as usize, t.y as usize),
            }
        }
        for t in &prev.tokens {
            if new.find(t.x, t.y).is_none() {
                d.set(t.x as usize, t.y as usize);
            }
        }
        d
    }

    /// Perturb `prev` into an overlapping "next window": flip a few sites'
    /// presence and rewrite a few features.
    fn perturb(g: &mut Gen, prev: &SparseMap<i8>) -> SparseMap<i8> {
        let mut m: SparseMap<i8> = SparseMap::empty(prev.w, prev.h, prev.c);
        for y in 0..prev.h {
            for x in 0..prev.w {
                let at = prev.find(x as u16, y as u16);
                let present = if g.chance(0.1) { at.is_none() } else { at.is_some() };
                if !present {
                    continue;
                }
                let f: Vec<i8> = match at {
                    Some(p) if !g.chance(0.15) => prev.feat(p).to_vec(),
                    _ => (0..prev.c).map(|_| g.i64(-128, 127) as i8).collect(),
                };
                m.push(Token::new(x as u16, y as u16), &f);
            }
        }
        m
    }

    /// Delta kernels must be bit-identical to the full kernels when handed
    /// the propagated dirty frontier and the previous window's cached
    /// output — the induction step of `execute_delta`'s exactness proof.
    #[test]
    fn delta_kernels_match_full_kernels() {
        check("i8 delta kernels == full kernels on overlapping windows", 32, |g| {
            let rq = Requant::from_scale(0.37, -128, 127);
            let mut idx = NeighborIndex::new();
            let mut ds = Bitmap::new(0, 0);
            let mut acc = Vec::new();
            let mut out: SparseMap<i8> = SparseMap::empty(0, 0, 0);
            let w = g.usize(2, 12);
            let h = g.usize(2, 12);
            let cin = g.usize(1, 4);
            let cout = g.usize(1, 4);
            let k = [1, 3][g.usize(0, 1)];
            let prev_in = random_map_i8(g, w, h, cin, 0.35);
            let new_in = perturb(g, &prev_in);
            let diff = diff_bitmap(&prev_in, &new_in);
            let bias: Vec<i32> = (0..cout.max(cin)).map(|_| g.i64(-64, 64) as i32).collect();

            // 1×1: dirty = the input diff itself.
            let wt = rand_w_i8(g, cin * cout);
            let prev_out = conv1x1_i8(&prev_in, &wt, &bias[..cout], cout, &rq);
            let n = conv1x1_i8_delta_into(
                &new_in, &wt, &bias[..cout], cout, &rq, &diff, &prev_out, &mut acc, &mut out,
            );
            assert_eq!(out, conv1x1_i8(&new_in, &wt, &bias[..cout], cout, &rq));
            assert!(n <= new_in.nnz());

            // Full k×k stride 1: dirty = diff dilated by the radius.
            let wt = rand_w_i8(g, k * k * cin * cout);
            let dil = diff.dilate(k);
            let prev_out = conv_kxk_s1_i8(&prev_in, k, &wt, &bias[..cout], cout, &rq);
            conv_kxk_s1_i8_delta_into(
                &new_in, k, &wt, &bias[..cout], cout, &rq, &dil, &prev_out, &mut idx, &mut acc,
                &mut out,
            );
            assert_eq!(out, conv_kxk_s1_i8(&new_in, k, &wt, &bias[..cout], cout, &rq));

            // Full k×k stride 2: dirty = downsampled (window ∪ occupancy).
            let mut dd = Bitmap::new(0, 0);
            diff.downsample_dirty_into(k, &mut dd);
            let prev_out = conv_kxk_s2_i8(&prev_in, k, &wt, &bias[..cout], cout, &rq);
            conv_kxk_s2_i8_delta_into(
                &new_in, k, &wt, &bias[..cout], cout, &rq, &dd, &prev_out, &mut idx, &mut ds,
                &mut acc, &mut out,
            );
            assert_eq!(out, conv_kxk_s2_i8(&new_in, k, &wt, &bias[..cout], cout, &rq));

            // Depthwise stride 1 and stride 2.
            let wt = rand_w_i8(g, k * k * cin);
            let prev_out = dwconv_kxk_s1_i8(&prev_in, k, &wt, &bias[..cin], &rq);
            dwconv_kxk_s1_i8_delta_into(
                &new_in, k, &wt, &bias[..cin], &rq, &dil, &prev_out, &mut idx, &mut acc, &mut out,
            );
            assert_eq!(out, dwconv_kxk_s1_i8(&new_in, k, &wt, &bias[..cin], &rq));
            let prev_out = dwconv_kxk_s2_i8(&prev_in, k, &wt, &bias[..cin], &rq);
            dwconv_kxk_s2_i8_delta_into(
                &new_in, k, &wt, &bias[..cin], &rq, &dd, &prev_out, &mut idx, &mut ds, &mut acc,
                &mut out,
            );
            assert_eq!(out, dwconv_kxk_s2_i8(&new_in, k, &wt, &bias[..cin], &rq));
        });
    }

    /// With an identical window the delta kernel recomputes nothing.
    #[test]
    fn delta_kernel_with_empty_diff_recomputes_nothing() {
        let mut g = Gen::new(7, 1.0);
        let m = random_map_i8(&mut g, 10, 10, 3, 0.4);
        let rq = Requant::from_scale(0.5, -128, 127);
        let wt = rand_w_i8(&mut g, 3 * 2);
        let bias = vec![0i32; 2];
        let prev_out = conv1x1_i8(&m, &wt, &bias, 2, &rq);
        let clean = Bitmap::new(m.w, m.h);
        let mut acc = Vec::new();
        let mut out: SparseMap<i8> = SparseMap::empty(0, 0, 0);
        let n = conv1x1_i8_delta_into(&m, &wt, &bias, 2, &rq, &clean, &prev_out, &mut acc, &mut out);
        assert_eq!(n, 0);
        assert_eq!(out, prev_out);
    }

    #[test]
    fn i8_pool_rounds_half_up() {
        let mut m: SparseMap<i8> = SparseMap::empty(4, 1, 1);
        m.push(Token::new(0, 0), &[1]);
        m.push(Token::new(1, 0), &[2]);
        m.push(Token::new(2, 0), &[2]);
        // sum 5, n 3 → 5/3 = 1.67 → rounds to 2
        assert_eq!(global_avg_pool_i8(&m), vec![2]);
    }
}
