//! Comment/string/char-aware Rust source scanner for the lint pass.
//!
//! The rules in [`crate::lint`] match textual tokens (`.unwrap()`,
//! `format!`, ` as u32`, …), so the scanner's job is to make that
//! matching sound: it walks a file character by character tracking
//! comments, string/raw-string/byte-string literals, char literals (as
//! distinct from lifetimes), and nested block comments, and produces per
//! line:
//!
//! - `code`: the line with every comment removed and every string/char
//!   literal reduced to its bare quotes — the only text rules match
//!   tokens against, so `"call .unwrap()"` in a log message or a doc
//!   comment can never trip a rule;
//! - `comment`: the comment text on the line (where `lint:` directives
//!   live);
//! - `in_test`: whether the line sits inside a `#[cfg(test)]` /
//!   `#[test]` item, which every rule skips.
//!
//! String literal *values* are still needed by the CLI-drift rule (the
//! flag names in `args.get_or("dataset", …)`), so the scanner also
//! emits each literal together with the masked code preceding it on its
//! line — enough context to tell a flag lookup from any other string.

/// One scanned source line.
#[derive(Debug, Default)]
pub struct ScannedLine {
    /// Masked code: comments stripped, literal contents dropped (their
    /// delimiting quotes are kept so expression structure survives).
    pub code: String,
    /// Concatenated comment text on this line, without the `//` / `/*`
    /// markers.
    pub comment: String,
    /// True when the line is inside a `#[cfg(test)]` or `#[test]` item.
    pub in_test: bool,
}

/// A string literal, with enough call-site context to classify it.
#[derive(Debug)]
pub struct StrLit {
    /// 1-based line the literal opens on.
    pub line: usize,
    /// Masked code preceding the opening quote on its line.
    pub prefix: String,
    /// The literal's raw content (escapes kept verbatim).
    pub value: String,
}

/// A whole scanned file.
#[derive(Debug, Default)]
pub struct Scanned {
    pub lines: Vec<ScannedLine>,
    pub strings: Vec<StrLit>,
}

enum State {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scan `text` into masked lines plus extracted string literals.
pub fn scan(text: &str) -> Scanned {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Scanned::default();
    let mut code = String::new();
    let mut comment = String::new();
    let mut lit = String::new();
    let mut lit_line = 0usize;
    let mut lit_prefix = String::new();
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            if matches!(state, State::Str | State::RawStr(_)) {
                lit.push('\n');
            }
            out.lines.push(ScannedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let next = chars.get(i + 1).copied();
                let prev_ident = i > 0 && is_ident(chars[i - 1]);
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    lit_prefix = code.clone();
                    lit_line = out.lines.len() + 1;
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Possible raw/byte string or byte-char prefix:
                    // r"…", r#"…"#, b"…", br#"…"#, b'…'.
                    let mut j = i + 1;
                    let mut raw = c == 'r';
                    if c == 'b' && chars.get(j).copied() == Some('r') {
                        raw = true;
                        j += 1;
                    }
                    let mut hashes = 0usize;
                    while raw && chars.get(j).copied() == Some('#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j).copied() == Some('"') {
                        // Raw strings take no escapes (even with zero
                        // hashes), byte strings escape like plain ones.
                        lit_prefix = code.clone();
                        lit_line = out.lines.len() + 1;
                        code.push('"');
                        state = if raw { State::RawStr(hashes) } else { State::Str };
                        i = j + 1;
                    } else if c == 'b' && chars.get(i + 1).copied() == Some('\'') {
                        // Byte char literal: consume `b`, let the char
                        // branch below handle the quote.
                        code.push('b');
                        i += 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal (`'x'`, `'\…'`) vs. lifetime (`'a`).
                    let is_char = next == Some('\\')
                        || (chars.get(i + 2).copied() == Some('\'') && next != Some('\''));
                    code.push('\'');
                    i += 1;
                    if is_char {
                        while i < chars.len() && chars[i] != '\'' {
                            if chars[i] == '\\' {
                                i += 1;
                            }
                            i += 1;
                        }
                        if i < chars.len() {
                            code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    lit.push('\\');
                    if let Some(&e) = chars.get(i + 1) {
                        if e != '\n' {
                            lit.push(e);
                            i += 1;
                        }
                    }
                    i += 1;
                } else if c == '"' {
                    code.push('"');
                    out.strings.push(StrLit {
                        line: lit_line,
                        prefix: std::mem::take(&mut lit_prefix),
                        value: std::mem::take(&mut lit),
                    });
                    state = State::Normal;
                    i += 1;
                } else {
                    lit.push(c);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let closes = c == '"'
                    && (1..=hashes).all(|k| chars.get(i + k).copied() == Some('#'));
                if closes {
                    code.push('"');
                    out.strings.push(StrLit {
                        line: lit_line,
                        prefix: std::mem::take(&mut lit_prefix),
                        value: std::mem::take(&mut lit),
                    });
                    state = State::Normal;
                    i += 1 + hashes;
                } else {
                    lit.push(c);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.lines.push(ScannedLine { code, comment, in_test: false });
    }
    mark_tests(&mut out.lines);
    out
}

/// Mark every line inside a `#[cfg(test)]` / `#[test]` item. The
/// attribute line opens a region at the current brace depth; the region
/// closes when depth returns there after the item's body was entered.
/// Nested test attributes inside an open region (e.g. `#[test]` fns in
/// a `#[cfg(test)] mod`) are already covered by the outer region.
fn mark_tests(lines: &mut [ScannedLine]) {
    let mut depth: i64 = 0;
    let mut region: Option<(i64, bool)> = None;
    for line in lines.iter_mut() {
        if region.is_none()
            && (line.code.contains("#[cfg(test)]") || line.code.contains("#[test]"))
        {
            region = Some((depth, false));
        }
        if region.is_some() {
            line.in_test = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if let Some((base, false)) = region {
                        if depth == base + 1 {
                            region = Some((base, true));
                        }
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some((base, true)) = region {
                        if depth == base {
                            region = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_masked_out_of_code() {
        let s = scan("let x = \"call .unwrap()\"; // then .unwrap()\n");
        assert_eq!(s.lines.len(), 1);
        assert!(!s.lines[0].code.contains("unwrap"), "{:?}", s.lines[0].code);
        assert!(s.lines[0].comment.contains(".unwrap()"));
        assert_eq!(s.strings[0].value, "call .unwrap()");
    }

    #[test]
    fn char_literals_and_lifetimes_are_distinguished() {
        let s = scan("fn f<'a>(x: &'a str) -> char { '\"' }\n");
        // The quote inside the char literal must not open a string.
        assert_eq!(s.strings.len(), 0);
        assert!(s.lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn raw_and_byte_strings_close_correctly() {
        let s = scan("let a = r#\"x \" y\"#; let b = b\"z\"; let c = 'q';\n");
        assert_eq!(s.strings.len(), 2);
        assert_eq!(s.strings[0].value, "x \" y");
        assert_eq!(s.strings[1].value, "z");
    }

    #[test]
    fn nested_block_comments_stay_comments() {
        let s = scan("a /* x /* y */ z */ b\n");
        assert_eq!(s.lines[0].code.replace(' ', ""), "ab");
        assert!(s.lines[0].comment.contains('y'));
    }

    #[test]
    fn test_items_are_marked() {
        let src =
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let s = scan(src);
        let flags: Vec<bool> = s.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn string_literal_prefix_carries_the_call_site() {
        let s = scan("    let v = args.get_or(\"dataset\", \"n_mnist\");\n");
        assert_eq!(s.strings.len(), 2);
        assert!(s.strings[0].prefix.trim_end().ends_with(".get_or("));
        assert_eq!(s.strings[0].value, "dataset");
        assert!(s.strings[1].prefix.trim_end().ends_with(","));
    }
}
