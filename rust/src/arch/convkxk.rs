//! k×k convolution *computation* module — paper §3.3.3, Fig. 6.
//!
//! Consumes the SLB's kernel-offset stream ([`Item::Window`]): for each
//! output token it performs the weighted sum over only the **nonzero**
//! kernel offsets (the kernel-sparsity the Eqn. 5 `9·S_k` term models),
//! then requantizes and emits the output feature. Supports the depthwise
//! organization (per-channel weights, `ceil(C/PF)` cycles per offset) and
//! the full organization (`ceil(Cin·Cout/PF)` cycles per offset).

use super::module::{pe_cycles, Countdown, Module};
use super::stream::{ChanId, Fabric, Item, ModStats};
use crate::sparse::quant::Requant;
use crate::sparse::Token;

/// PE organization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeKind {
    /// Depthwise: weights `w[off * c + ch]`.
    Depthwise { c: usize },
    /// Full conv: weights `w[(off * cin + ci) * cout + co]`.
    Full { cin: usize, cout: usize },
}

impl PeKind {
    fn macs_per_offset(&self) -> usize {
        match *self {
            PeKind::Depthwise { c } => c,
            PeKind::Full { cin, cout } => cin * cout,
        }
    }
    fn cout(&self) -> usize {
        match *self {
            PeKind::Depthwise { c } => c,
            PeKind::Full { cout, .. } => cout,
        }
    }
}

pub struct KxkComputeMod {
    name: String,
    in_ch: ChanId,
    out_ch: ChanId,
    /// Kernel size (retained for reports/debugging).
    #[allow(dead_code)]
    k: usize,
    kind: PeKind,
    pf: usize,
    w: Vec<i8>,
    b: Vec<i32>,
    rq: Requant,
    cd: Countdown,
    cur: Option<(Token, Vec<(u8, Vec<i8>)>)>,
    pending: Option<Item>,
    stats: ModStats,
    done: bool,
}

impl KxkComputeMod {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        in_ch: ChanId,
        out_ch: ChanId,
        k: usize,
        kind: PeKind,
        pf: usize,
        w: Vec<i8>,
        b: Vec<i32>,
        rq: Requant,
    ) -> Self {
        let expect_w = match kind {
            PeKind::Depthwise { c } => k * k * c,
            PeKind::Full { cin, cout } => k * k * cin * cout,
        };
        assert_eq!(w.len(), expect_w);
        assert_eq!(b.len(), kind.cout());
        KxkComputeMod {
            name: name.into(),
            in_ch,
            out_ch,
            k,
            kind,
            pf: pf.max(1),
            w,
            b,
            rq,
            cd: Countdown::default(),
            cur: None,
            pending: None,
            stats: ModStats::default(),
            done: false,
        }
    }

    fn compute(&self, offs: &[(u8, Vec<i8>)]) -> Vec<i8> {
        let _cout = self.kind.cout();
        let mut acc: Vec<i32> = self.b.clone();
        for (off, f) in offs {
            let off = *off as usize;
            match self.kind {
                PeKind::Depthwise { c } => {
                    for ch in 0..c {
                        acc[ch] += f[ch] as i32 * self.w[off * c + ch] as i32;
                    }
                }
                PeKind::Full { cin, cout } => {
                    let wbase = off * cin * cout;
                    for ci in 0..cin {
                        let a = f[ci] as i32;
                        let wrow = wbase + ci * cout;
                        for co in 0..cout {
                            acc[co] += a * self.w[wrow + co] as i32;
                        }
                    }
                }
            }
        }
        acc.iter().map(|&a| self.rq.apply(a)).collect()
    }
}

impl Module for KxkComputeMod {
    fn name(&self) -> &str {
        &self.name
    }

    fn step(&mut self, fab: &mut Fabric) {
        if let Some(item) = self.pending.take() {
            if fab.can_push(self.out_ch) {
                if item.is_end() {
                    self.done = true;
                }
                fab.chan(self.out_ch).push(item);
                self.stats.produced += 1;
            } else {
                self.pending = Some(item);
                self.stats.stall_out += 1;
                return;
            }
        }
        if self.cd.busy() {
            self.stats.busy += 1;
            if self.cd.tick() {
                let (t, offs) = self.cur.take().unwrap();
                self.pending = Some(Item::Feat { t, f: self.compute(&offs) });
            }
            return;
        }
        if self.pending.is_none() {
            match fab.chan(self.in_ch).pop() {
                Some(Item::Window { t, offs }) => {
                    self.stats.consumed += 1;
                    // One `ceil(macs/PF)` pass per nonzero offset — the
                    // kernel-sparsity-proportional latency of Eqn. 5.
                    let cycles: u64 = offs.len() as u64
                        * pe_cycles(self.kind.macs_per_offset(), self.pf).max(1);
                    self.cur = Some((t, offs));
                    self.cd.start(cycles.max(1));
                }
                Some(Item::End) => {
                    self.stats.consumed += 1;
                    self.pending = Some(Item::End);
                }
                Some(other) => panic!("{}: unexpected item {other:?}", self.name),
                None => self.stats.stall_in += 1,
            }
        }
    }

    fn stats(&self) -> &ModStats {
        &self.stats
    }

    fn done(&self) -> bool {
        self.done
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn next_event(&self) -> Option<u64> {
        if self.pending.is_some() {
            // Will attempt the push on the very next step — blocks skipping.
            Some(1)
        } else if self.cd.busy() {
            Some(self.cd.0)
        } else {
            None
        }
    }

    fn fast_forward(&mut self, k: u64) {
        debug_assert!(self.cd.0 > k);
        self.cd.0 -= k;
        self.stats.busy += k;
    }

    fn dsp(&self) -> usize {
        self.pf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::slb::{SlbS1, SlbS2};
    use crate::sparse::conv::{dwconv_kxk_s1_i8, dwconv_kxk_s2_i8};
    use crate::sparse::SparseMap;
    use crate::util::propcheck::check;

    /// SLB + compute chained must equal the functional conv bit-for-bit.
    fn run_chain(
        input: &SparseMap<i8>,
        stride: usize,
        w: &[i8],
        b: &[i32],
        rq: Requant,
    ) -> SparseMap<i8> {
        let c = input.c;
        let mut fab = Fabric::default();
        let ch_in = fab.add_chan(2);
        let ch_win = fab.add_chan(2);
        let ch_out = fab.add_chan(2);
        let mut slb: Box<dyn Module> = if stride == 1 {
            Box::new(SlbS1::new("slb", ch_in, ch_win, 3, input.w, input.h))
        } else {
            Box::new(SlbS2::new("slb", ch_in, ch_win, 3, input.w, input.h))
        };
        let mut pe = KxkComputeMod::new(
            "dw",
            ch_win,
            ch_out,
            3,
            PeKind::Depthwise { c },
            2,
            w.to_vec(),
            b.to_vec(),
            rq,
        );
        let (ow, oh) = if stride == 1 {
            (input.w, input.h)
        } else {
            ((input.w + 1) / 2, (input.h + 1) / 2)
        };
        let mut out: SparseMap<i8> = SparseMap::empty(ow, oh, c);
        let mut feed = input.tokens.iter().enumerate();
        let mut next = feed.next();
        let mut sent_end = false;
        let mut cycles = 0u64;
        while !pe.done() && cycles < 5_000_000 {
            if fab.can_push(ch_in) {
                if let Some((i, t)) = next {
                    fab.chan(ch_in).push(Item::Feat { t: *t, f: input.feat(i).to_vec() });
                    next = feed.next();
                } else if !sent_end {
                    fab.chan(ch_in).push(Item::End);
                    sent_end = true;
                }
            }
            pe.step(&mut fab);
            slb.step(&mut fab);
            while let Some(item) = fab.chan(ch_out).pop() {
                if let Item::Feat { t, f } = item {
                    out.push(t, &f);
                }
            }
            cycles += 1;
        }
        assert!(pe.done(), "chain deadlocked");
        out
    }

    #[test]
    fn dw_s1_chain_matches_functional() {
        check("SLB s1 + DW PE == functional dwconv", 32, |g| {
            let w = g.usize(3, 14);
            let h = g.usize(3, 14);
            let c = g.usize(1, 4);
            let mut m: SparseMap<i8> = SparseMap::empty(w, h, c);
            for y in 0..h {
                for x in 0..w {
                    if g.chance(0.35) {
                        let f: Vec<i8> = (0..c).map(|_| g.i64(-90, 90) as i8).collect();
                        m.push(Token::new(x as u16, y as u16), &f);
                    }
                }
            }
            let wt: Vec<i8> = (0..9 * c).map(|_| g.i64(-40, 40) as i8).collect();
            let b: Vec<i32> = (0..c).map(|_| g.i64(-200, 200) as i32).collect();
            let rq = Requant::from_scale(0.02, 0, 110);
            let got = run_chain(&m, 1, &wt, &b, rq);
            let want = dwconv_kxk_s1_i8(&m, 3, &wt, &b, &rq);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn dw_s2_chain_matches_functional() {
        check("SLB s2 + DW PE == functional dwconv s2", 32, |g| {
            let w = g.usize(4, 14);
            let h = g.usize(4, 14);
            let c = g.usize(1, 4);
            let mut m: SparseMap<i8> = SparseMap::empty(w, h, c);
            for y in 0..h {
                for x in 0..w {
                    if g.chance(0.3) {
                        let f: Vec<i8> = (0..c).map(|_| g.i64(-90, 90) as i8).collect();
                        m.push(Token::new(x as u16, y as u16), &f);
                    }
                }
            }
            let wt: Vec<i8> = (0..9 * c).map(|_| g.i64(-40, 40) as i8).collect();
            let b: Vec<i32> = (0..c).map(|_| g.i64(-200, 200) as i32).collect();
            let rq = Requant::from_scale(0.02, -128, 127);
            let got = run_chain(&m, 2, &wt, &b, rq);
            let want = dwconv_kxk_s2_i8(&m, 3, &wt, &b, &rq);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn latency_scales_with_kernel_sparsity() {
        // A window with 2 offsets must take fewer cycles than one with 9.
        let c = 8usize;
        let pf = 4usize;
        let rq = Requant::unit();
        let mk = |n_offs: usize| {
            let mut fab = Fabric::default();
            let ch_in = fab.add_chan(2);
            let ch_out = fab.add_chan(2);
            let mut pe = KxkComputeMod::new(
                "dw",
                ch_in,
                ch_out,
                3,
                PeKind::Depthwise { c },
                pf,
                vec![1i8; 9 * c],
                vec![0i32; c],
                rq,
            );
            let offs: Vec<(u8, Vec<i8>)> = (0..n_offs).map(|o| (o as u8, vec![1i8; c])).collect();
            fab.chan(ch_in).push(Item::Window { t: Token::new(0, 0), offs });
            fab.chan(ch_in).push(Item::End);
            let mut cycles = 0u64;
            while !pe.done() && cycles < 10_000 {
                pe.step(&mut fab);
                while fab.chan(ch_out).pop().is_some() {}
                cycles += 1;
            }
            pe.stats().busy
        };
        // Busy cycles = n_offs × ceil(C/PF) = n_offs × 2.
        assert_eq!(mk(2), 4);
        assert_eq!(mk(9), 18);
    }
}
