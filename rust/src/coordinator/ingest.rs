//! The ingestion boundary: where timestamped requests enter the serving
//! runtime.
//!
//! The paper's premise (§2.1) is a *live* AER stream: a request is born
//! when its recording window completes at the camera, not when a
//! benchmark loop conjures it — every latency and deadline downstream is
//! measured from that arrival. An [`EventSource`] produces
//! [`SourcedRequest`]s with real arrival times; three implementations
//! cover the deployment spectrum:
//!
//! - [`SyntheticSource`] — the original in-memory scene generator behind
//!   the same trait (benchmarks, tests; arrivals are "now"),
//! - [`ReplaySource`] — replays a recorded `.esda` dataset at wall-clock
//!   rate scaled by a speed factor, assigning each sample the arrival
//!   instant its recording would have completed in the replayed timeline
//!   (so downstream overload shows up as real deadline pressure),
//! - [`TailSource`] — follows a *growing* `.esda` file (a camera-dump
//!   pipeline appending via [`events::io::append_sample`]), emitting each
//!   sample the moment it is fully on disk.
//!
//! Socket-backed sources (UDP/TCP packet ingestion with per-packet
//! tenant identity) live in [`super::net`] behind the same trait.
//!
//! The boundary also **validates** what it admits: every event must lie
//! inside the source's geometry (the representation builder indexes
//! unchecked), and event order is checked with
//! [`is_time_sorted`] under a per-source [`UnsortedPolicy`] — recorded
//! datasets should already be sorted (replay rejects), while a live tail
//! can legitimately observe reordered events (tail sorts).
//!
//! **Error severity.** An [`IngestError`] is either *fatal* or
//! *recoverable* ([`IngestError::is_recoverable`]). Byte-stream failures
//! (truncation, over-claims, IO errors, pacing overflow) latch the
//! source broken and are fatal: the reader position is no longer
//! trustworthy, so the serving run aborts. Per-sample *validation*
//! rejects (out-of-geometry events, unsorted-under-`Reject`) leave the
//! reader aligned at the next sample and are recoverable: the server
//! skips the sample, counts it under the `ingest_rejects` metric, and
//! the stream continues — one bad sample in a capture must not kill a
//! serving run.
//!
//! [`events::io::append_sample`]: crate::events::io::append_sample

use crate::events::aer::{is_time_sorted, EventSlice};
use crate::events::{io, DatasetProfile, Event};
use crate::util::Rng;
use std::fmt;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::time::{Duration, Instant};

/// The tenant id file-backed and synthetic sources stamp on every
/// request: single-owner streams all share the front door's default
/// tenant. Socket sources carry a real per-packet tenant instead.
pub const DEFAULT_TENANT: usize = 0;

/// One request as it crosses the ingestion boundary.
#[derive(Debug, Clone)]
pub struct SourcedRequest {
    /// Ground-truth class when the source knows it (replayed datasets and
    /// the synthetic generator always do; a live pipeline's labels are
    /// whatever the producer wrote).
    pub label: usize,
    /// The recording window's events, time-sorted (enforced here).
    pub events: Vec<Event>,
    /// When this request was *born*: the instant its recording window
    /// completed at the (real or replayed) camera. End-to-end latency and
    /// any deadline are measured from this, not from queue admission.
    pub arrival: Instant,
    /// Which tenant owns this request (index into the server's tenant
    /// table; [`DEFAULT_TENANT`] for single-owner sources). Admission
    /// quotas, per-tenant SLOs, and the per-tenant report key on this.
    pub tenant: usize,
    /// Which model this request targets (index into the server's model
    /// table; 0 — the default model — for single-model sources). The
    /// router restricts placement to classes serving this model, and the
    /// per-model report keys on it. Out-of-range ids are clamped at the
    /// admission gate, exactly like tenant ids.
    pub model: usize,
    /// Stable identity of the event stream this window came from, when the
    /// source has one (a TCP connection, a synthetic per-stream camera).
    /// Consecutive windows of one stream overlap heavily, so the router
    /// sticky-routes on this and delta-capable backends diff against the
    /// stream's cached previous window. `None` (datagram/replay/tail
    /// sources) always takes the full-recompute path.
    pub stream: Option<u64>,
}

/// Ingestion failure: unreadable/corrupt input (fatal), or a sample the
/// boundary validation rejected (recoverable — see the module docs).
#[derive(Debug, Clone)]
pub struct IngestError {
    msg: String,
    recoverable: bool,
    /// Tenant the rejected sample belonged to, when the failure happened
    /// late enough for the tenant id to have parsed (socket sources).
    tenant: Option<usize>,
}

impl IngestError {
    /// A failure the source cannot continue past: the serving run aborts.
    pub fn fatal(msg: impl Into<String>) -> IngestError {
        IngestError { msg: msg.into(), recoverable: false, tenant: None }
    }

    /// A per-sample reject the source *has already skipped*: the server
    /// counts it and keeps pulling.
    pub fn recoverable(msg: impl Into<String>) -> IngestError {
        IngestError { msg: msg.into(), recoverable: true, tenant: None }
    }

    /// Attach the owning tenant (socket sources, where the packet header
    /// parsed before validation rejected the payload).
    pub fn with_tenant(mut self, tenant: usize) -> IngestError {
        self.tenant = Some(tenant);
        self
    }

    /// `true` when the source stays usable and the caller should skip
    /// this sample and retry `next_request`.
    pub fn is_recoverable(&self) -> bool {
        self.recoverable
    }

    /// The tenant whose sample was rejected, when known.
    pub fn tenant(&self) -> Option<usize> {
        self.tenant
    }

    /// The failure message.
    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for IngestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for IngestError {}

/// What to do with a sample whose events are not time-sorted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnsortedPolicy {
    /// Reject the sample as corrupt ([`ReplaySource`] default: a recorded
    /// dataset has no excuse for unsorted events, and the windowing
    /// helpers silently return wrong windows on them).
    Reject,
    /// Stable-sort by timestamp ([`TailSource`] default: a live capture
    /// path can reorder events in flight).
    Sort,
}

/// A producer of timestamped requests — the serving runtime's stage 1.
///
/// Sources are driven from a dedicated thread and may block (pacing
/// sleeps, tail polls). Returning `Ok(None)` ends the stream. A *fatal*
/// `Err` aborts the serving run with the source's message; a
/// *recoverable* one ([`IngestError::is_recoverable`]) marks a sample
/// the source already skipped — the server counts it under
/// `ingest_rejects` and keeps pulling.
pub trait EventSource: Send {
    /// Short display name for reports and errors.
    fn name(&self) -> &str;

    /// `(w, h)` every emitted event is validated against — the geometry
    /// the representation stage builds maps at.
    fn geometry(&self) -> (usize, usize);

    /// Produce the next request, blocking as needed to honor real
    /// arrival times.
    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError>;
}

/// Boundary validation shared by every source: geometry bounds (the
/// representation builder indexes `y*w + x` unchecked) and time order
/// under the source's [`UnsortedPolicy`]. Rejects are *recoverable* —
/// callers advance past the sample before validating, so the stream
/// continues.
pub(crate) fn validate_events(
    events: &mut Vec<Event>,
    w: usize,
    h: usize,
    policy: UnsortedPolicy,
    what: &str,
) -> Result<(), IngestError> {
    if let Some(e) = events.iter().find(|e| e.x as usize >= w || e.y as usize >= h) {
        return Err(IngestError::recoverable(format!(
            "{what}: event at ({}, {}) lies outside the {w}x{h} geometry",
            e.x, e.y
        )));
    }
    if !is_time_sorted(events) {
        match policy {
            UnsortedPolicy::Sort => events.sort_by_key(|e| e.t_us),
            UnsortedPolicy::Reject => {
                return Err(IngestError::recoverable(format!(
                    "{what}: events are not time-sorted (unsorted policy: reject)"
                )))
            }
        }
    }
    Ok(())
}

/// Geometry sanity shared by the file-backed and socket sources: event
/// coordinates are u16, so anything outside [1, 65536] is corrupt — and
/// a bogus huge header must not size the repr stage's dense scratch.
/// Fatal: a source with a broken geometry cannot emit anything.
pub(crate) fn validate_geometry(w: usize, h: usize, what: &str) -> Result<(), IngestError> {
    if !(1..=65536).contains(&w) || !(1..=65536).contains(&h) {
        return Err(IngestError::fatal(format!("{what}: implausible geometry {w}x{h}")));
    }
    Ok(())
}

/// The synthetic event camera behind the [`EventSource`] trait: `n`
/// requests cycling over the profile's classes, identical stream to the
/// pre-ingest serving runtime for a given seed (prediction multisets are
/// unchanged). Arrivals are assigned at generation time.
pub struct SyntheticSource {
    profile: DatasetProfile,
    rng: Rng,
    n: usize,
    emitted: usize,
    /// Fraction of each window's events carried over from the stream's
    /// previous window (0 = independent windows, the classic mode).
    overlap: f64,
    /// Number of interleaved synthetic streams in overlap mode.
    streams: usize,
    /// Previous window per stream (overlap mode only).
    prev: Vec<Vec<Event>>,
}

impl SyntheticSource {
    pub fn new(profile: DatasetProfile, n: usize, seed: u64) -> SyntheticSource {
        SyntheticSource {
            profile,
            rng: Rng::new(seed),
            n,
            emitted: 0,
            overlap: 0.0,
            streams: 1,
            prev: Vec::new(),
        }
    }

    /// Emit `streams` interleaved sliding-window streams instead of
    /// independent windows: each stream keeps a fixed class, and every
    /// window after its first carries over `frac` of the previous window's
    /// events (evenly strided), topped up with fresh ones. Deterministic
    /// per seed; requests are stamped with a synthetic stream id.
    pub fn with_overlap(mut self, frac: f64, streams: usize) -> SyntheticSource {
        self.overlap = frac.clamp(0.0, 1.0);
        self.streams = streams.max(1);
        self.prev = vec![Vec::new(); self.streams];
        self
    }
}

impl EventSource for SyntheticSource {
    fn name(&self) -> &str {
        "synth"
    }

    fn geometry(&self) -> (usize, usize) {
        (self.profile.w, self.profile.h)
    }

    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        if self.emitted >= self.n {
            return Ok(None);
        }
        if self.overlap > 0.0 {
            let s = self.emitted % self.streams;
            // A stream is one camera watching one scene: its class stays
            // fixed so consecutive windows genuinely correlate.
            let label = s % self.profile.n_classes;
            let fresh = self.profile.sample(label, &mut self.rng);
            let events = if self.prev[s].is_empty() {
                fresh
            } else {
                let total = fresh.len().max(1);
                let keep = ((self.overlap * total as f64).round() as usize)
                    .min(self.prev[s].len())
                    .min(total);
                // Evenly strided carry-over keeps the previous window's
                // spatial distribution; both halves are time-sorted, so a
                // linear merge yields a sorted window.
                let prev = &self.prev[s];
                let kept: Vec<Event> =
                    (0..keep).map(|i| prev[i * prev.len() / keep.max(1)]).collect();
                let fresh_n = total - keep;
                let mut merged = Vec::with_capacity(total);
                let (mut a, mut b) = (0, 0);
                while a < kept.len() || b < fresh_n {
                    let take_kept = match (kept.get(a), (b < fresh_n).then(|| fresh[b])) {
                        (Some(ka), Some(fb)) => ka.t_us <= fb.t_us,
                        (Some(_), None) => true,
                        _ => false,
                    };
                    if take_kept {
                        merged.push(kept[a]);
                        a += 1;
                    } else {
                        merged.push(fresh[b]);
                        b += 1;
                    }
                }
                merged
            };
            self.prev[s] = events.clone();
            self.emitted += 1;
            return Ok(Some(SourcedRequest {
                label,
                events,
                arrival: Instant::now(),
                tenant: DEFAULT_TENANT,
                model: 0,
                stream: Some(s as u64),
            }));
        }
        let label = self.emitted % self.profile.n_classes;
        // The scene generator steps time forward, so its events are
        // sorted and in-bounds by construction — no validation pass.
        let events = self.profile.sample(label, &mut self.rng);
        self.emitted += 1;
        Ok(Some(SourcedRequest {
            label,
            events,
            arrival: Instant::now(),
            tenant: DEFAULT_TENANT,
            model: 0,
            stream: None,
        }))
    }
}

/// Largest due-offset (seconds into the replayed timeline, after speed
/// scaling) the pacer will schedule. Beyond this the replay is
/// degenerate — a `@speed` tiny enough, or a capture long enough, to put
/// a sample decades out — and `Duration::from_secs_f64` would eventually
/// panic on overflow; the source reports [`IngestError`] instead.
const MAX_REPLAY_DUE_SECS: f64 = 1e9;

/// Replays a recorded `.esda` dataset as a live stream: sample `i`
/// arrives when its recording window completes in the replayed timeline —
/// `(sum of durations of samples 0..=i) / speed` after the first
/// request was pulled. `speed` > 1 compresses time (stress), < 1 dilates
/// it. If the consumer falls behind, arrivals keep their *scheduled*
/// instants: a real camera would have produced the data on time, so the
/// lag shows up as end-to-end latency and deadline pressure, exactly as
/// in deployment.
///
/// The replay is **streaming**: only the container header is read at
/// open; each sample's bytes are decoded (via the same
/// [`io::read_events`] primitive the tail source uses) just ahead of its
/// due time, so replaying a multi-GB capture holds one sample in memory,
/// not the file. Corruption checks run per sample against a running
/// remaining-bytes budget (the same discipline as [`io::read_dataset`]),
/// so a truncated or over-claiming capture fails at the offending sample
/// with a clear error instead of an allocation blowup.
pub struct ReplaySource {
    name: String,
    w: usize,
    h: usize,
    reader: std::io::BufReader<File>,
    /// Samples the container header promises.
    total: usize,
    /// Unread bytes past the file header — every per-sample claim draws
    /// on this budget before being trusted with an allocation.
    remaining_bytes: u64,
    /// Next sample ordinal (consumed samples, including rejected ones).
    idx: usize,
    /// Requests actually emitted (rejected samples don't count toward
    /// the limit).
    emitted: usize,
    speed: f64,
    policy: UnsortedPolicy,
    limit: Option<usize>,
    started: Option<Instant>,
    /// Replayed-timeline position (µs) after the previous sample.
    offset_us: u64,
    /// Ground-truth override from a `--labels` sidecar: one label per
    /// sample, replacing whatever the container recorded (captures from
    /// live cameras often carry placeholder labels; accuracy against a
    /// post-hoc annotation needs the sidecar's truth). `None` trusts the
    /// container.
    labels: Option<Vec<usize>>,
    /// Latched byte-stream failure (truncation, over-claim, IO error,
    /// pacing overflow): the reader position is no longer trustworthy
    /// after one, so every subsequent call re-reports it instead of
    /// parsing garbage bytes as a sample. Per-sample *validation*
    /// rejects (geometry, unsorted) do not latch — the reader is still
    /// aligned, and the stream continues with the next sample.
    failed: Option<String>,
}

impl ReplaySource {
    /// Open a dataset for replay at `speed`× wall-clock rate. Only the
    /// 20-byte container header is read and validated here; sample bytes
    /// stream out one recording ahead of its due time.
    pub fn open(path: &Path, speed: f64) -> Result<ReplaySource, IngestError> {
        if !(speed.is_finite() && speed > 0.0) {
            return Err(IngestError::fatal(format!(
                "replay speed must be finite and > 0, got {speed}"
            )));
        }
        let name = format!("replay:{}", path.display());
        let file = File::open(path).map_err(|e| IngestError::fatal(format!("{name}: {e}")))?;
        let file_len =
            file.metadata().map_err(|e| IngestError::fatal(format!("{name}: {e}")))?.len();
        let mut reader = std::io::BufReader::new(file);
        let (w, h, total) = io::read_file_header(&mut reader)
            .map_err(|e| IngestError::fatal(format!("{name}: {e}")))?;
        validate_geometry(w, h, &name)?;
        let remaining_bytes = file_len.saturating_sub(io::FILE_HEADER_BYTES);
        // Cheap whole-file sanity before the first sample: every promised
        // sample needs at least its fixed prefix on disk.
        if (total as u64).saturating_mul(io::SAMPLE_HEADER_BYTES) > remaining_bytes {
            return Err(IngestError::fatal(format!(
                "{name}: header claims {total} sample(s) but the file is only {file_len} byte(s)"
            )));
        }
        Ok(ReplaySource {
            name,
            w,
            h,
            reader,
            total,
            remaining_bytes,
            idx: 0,
            emitted: 0,
            speed,
            policy: UnsortedPolicy::Reject,
            limit: None,
            started: None,
            offset_us: 0,
            labels: None,
            failed: None,
        })
    }

    /// Latch and return a byte-stream failure (see the `failed` field).
    /// Always fatal: a misaligned reader cannot continue.
    fn fail(&mut self, msg: String) -> IngestError {
        self.failed = Some(msg.clone());
        IngestError::fatal(msg)
    }

    /// Override the unsorted-events policy (default: reject).
    pub fn with_unsorted_policy(mut self, policy: UnsortedPolicy) -> ReplaySource {
        self.policy = policy;
        self
    }

    /// Cap the number of requests emitted (default: the whole dataset).
    pub fn with_limit(mut self, limit: usize) -> ReplaySource {
        self.limit = Some(limit);
        self
    }

    /// Attach a ground-truth sidecar: a raw little-endian `u32` per
    /// sample, in sample order, overriding the labels recorded in the
    /// container. The sidecar must cover the dataset *exactly* — a count
    /// mismatch means the annotation belongs to some other capture, and
    /// silently scoring against it would corrupt every accuracy number
    /// downstream, so it is a fatal [`IngestError`] up front.
    pub fn with_labels(mut self, path: &Path) -> Result<ReplaySource, IngestError> {
        let name = format!("labels:{}", path.display());
        let bytes =
            std::fs::read(path).map_err(|e| IngestError::fatal(format!("{name}: {e}")))?;
        if bytes.len() % 4 != 0 {
            return Err(IngestError::fatal(format!(
                "{name}: {} byte(s) is not a whole number of u32 labels",
                bytes.len()
            )));
        }
        let labels: Vec<usize> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
            .collect();
        if labels.len() != self.total {
            return Err(IngestError::fatal(format!(
                "{name}: {} label(s) for a dataset of {} sample(s)",
                labels.len(),
                self.total
            )));
        }
        self.labels = Some(labels);
        Ok(self)
    }

    /// Samples left to emit.
    pub fn remaining(&self) -> usize {
        let left = self.total - self.idx;
        match self.limit {
            Some(l) => left.min(l.saturating_sub(self.emitted)),
            None => left,
        }
    }
}

impl EventSource for ReplaySource {
    fn name(&self) -> &str {
        &self.name
    }

    fn geometry(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        // A broken byte stream stays broken: re-report rather than parse
        // garbage from a misaligned reader.
        if let Some(msg) = &self.failed {
            return Err(IngestError::fatal(msg.clone()));
        }
        if self.idx >= self.total || self.limit.is_some_and(|l| self.emitted >= l) {
            return Ok(None);
        }
        let started = *self.started.get_or_insert_with(Instant::now);
        let i = self.idx;
        // Stream the sample off disk: prefix first, with its event claim
        // checked against the running byte budget (later samples' fixed
        // prefixes are spoken for) before any allocation trusts it. Every
        // failure from here to the decoded events latches `failed`.
        if self.remaining_bytes < io::SAMPLE_HEADER_BYTES {
            let msg = format!("{}: file truncated before sample {i}'s prefix", self.name);
            return Err(self.fail(msg));
        }
        self.remaining_bytes -= io::SAMPLE_HEADER_BYTES;
        let mut prefix = [0u8; 8];
        if let Err(e) = self.reader.read_exact(&mut prefix) {
            let msg = format!("{}: sample {i}: {e}", self.name);
            return Err(self.fail(msg));
        }
        let label = u32::from_le_bytes([prefix[0], prefix[1], prefix[2], prefix[3]]) as usize;
        let ne = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]) as usize;
        let need = (ne as u64).saturating_mul(io::EVENT_BYTES);
        let later_prefixes = ((self.total - 1 - i) as u64) * io::SAMPLE_HEADER_BYTES;
        if need.saturating_add(later_prefixes) > self.remaining_bytes {
            let msg = format!(
                "{}: sample {i} claims {ne} event(s) ({need} B) but only {} byte(s) remain \
                 for it and {later_prefixes} B of later sample prefixes",
                self.name, self.remaining_bytes
            );
            return Err(self.fail(msg));
        }
        self.remaining_bytes -= need;
        let mut events = match io::read_events(&mut self.reader, ne) {
            Ok(events) => events,
            Err(e) => {
                let msg = format!("{}: sample {i}: {e}", self.name);
                return Err(self.fail(msg));
            }
        };
        // The sample's bytes are fully consumed and the reader is aligned
        // at the next sample, so a per-sample *validation* reject is
        // recoverable: a caller that retries after this `Err` continues
        // with the next sample instead of receiving the rejected one back.
        self.idx += 1;
        validate_events(&mut events, self.w, self.h, self.policy, &format!("sample {i}"))?;
        // The recording is complete — and the request born — at the end
        // of its window in the replayed timeline.
        self.offset_us += EventSlice(&events).duration_us() as u64;
        let due_secs = self.offset_us as f64 / self.speed / 1e6;
        // Guard the pacer: a tiny-but-valid `@speed` (or an enormous
        // capture) can push the due offset past anything `Duration` can
        // hold — `from_secs_f64` would panic on overflow, so reject the
        // degenerate replay with a diagnosable error instead. Latched:
        // the timeline offset only ever grows, so no later sample can
        // pace either.
        if !(due_secs.is_finite() && due_secs <= MAX_REPLAY_DUE_SECS) {
            let msg = format!(
                "{}: replay pacing overflow at sample {i}: due {due_secs:.3e} s into the \
                 replayed timeline (speed {:.3e} too small or capture too long; cap \
                 {MAX_REPLAY_DUE_SECS:.0e} s)",
                self.name, self.speed
            );
            return Err(self.fail(msg));
        }
        let due = started + Duration::from_secs_f64(due_secs);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        self.emitted += 1;
        // The sidecar's truth wins over the container's recorded label.
        let label = self.labels.as_ref().map_or(label, |l| l[i]);
        Ok(Some(SourcedRequest {
            label,
            events,
            arrival: due,
            tenant: DEFAULT_TENANT,
            model: 0,
            stream: None,
        }))
    }
}

/// Wraps any [`EventSource`] with a deterministic model-mix schedule:
/// emitted request `k` targets model `schedule[k mod len]`, where the
/// schedule is the weights expanded cyclically (weights `[2, 1]` ⇒
/// models `0, 0, 1, 0, 0, 1, …`). This is the `--model-mix` CLI flag:
/// local sources (synthetic, replay, tail) have no model field of their
/// own, so the mix is imposed here; socket sources carry a real model id
/// per packet and don't need the wrapper.
///
/// The schedule keys on *emitted* requests — a recoverable reject does
/// not consume a slot, so the realized mix over served traffic matches
/// the weights exactly.
pub struct MixSource {
    inner: Box<dyn EventSource>,
    schedule: Vec<usize>,
    pos: usize,
}

impl MixSource {
    /// Wrap `inner`, assigning model `i` a share of `weights[i]` slots
    /// per cycle. Zero-weight models get no traffic; an empty (or
    /// all-zero) weight list degenerates to the default model.
    pub fn new(inner: Box<dyn EventSource>, weights: &[usize]) -> MixSource {
        let mut schedule: Vec<usize> = Vec::new();
        for (model, &w) in weights.iter().enumerate() {
            for _ in 0..w {
                schedule.push(model);
            }
        }
        if schedule.is_empty() {
            schedule.push(0);
        }
        MixSource { inner, schedule, pos: 0 }
    }
}

impl EventSource for MixSource {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn geometry(&self) -> (usize, usize) {
        self.inner.geometry()
    }

    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        let r = self.inner.next_request()?;
        Ok(r.map(|mut sr| {
            sr.model = self.schedule[self.pos];
            self.pos = (self.pos + 1) % self.schedule.len();
            sr
        }))
    }
}

/// Per-sample event-count sanity cap for tailed files (a corrupt prefix
/// must not make the tail wait forever for gigabytes that will never
/// arrive): 2^24 events ≈ 160 MB per sample.
const MAX_TAIL_EVENTS: u64 = 1 << 24;

/// Follows a growing `.esda` file — the camera-dump pipeline: a producer
/// writes the container header once ([`io::write_header`], sample count
/// advisory) and appends samples ([`io::append_sample`]); the tail emits
/// each sample the moment its bytes are fully on disk, with the arrival
/// stamped then. After `idle_timeout` without file growth the stream
/// ends — cleanly (`Ok(None)`) when the producer stopped at a sample
/// boundary, with a truncation error when unconsumed trailing bytes
/// never became a whole sample (a producer crash mid-append).
pub struct TailSource {
    name: String,
    file: File,
    w: usize,
    h: usize,
    /// Bytes consumed so far (starts past the file header).
    offset: u64,
    poll: Duration,
    idle_timeout: Duration,
    policy: UnsortedPolicy,
    limit: Option<usize>,
    emitted: usize,
}

impl TailSource {
    /// Open a (possibly not-yet-created) tail file, waiting up to the
    /// default idle timeout for the producer to create it and finish the
    /// header.
    pub fn open(path: &Path) -> Result<TailSource, IngestError> {
        TailSource::open_with(path, Duration::from_millis(2), Duration::from_secs(2))
    }

    /// [`TailSource::open`] with explicit poll interval and idle timeout.
    pub fn open_with(
        path: &Path,
        poll: Duration,
        idle_timeout: Duration,
    ) -> Result<TailSource, IngestError> {
        let name = format!("tail:{}", path.display());
        // Wait for the producer to create the file at all (the consumer
        // is routinely launched a beat before the camera pipeline), then
        // for it to finish the 20-byte header — one shared idle budget,
        // measured against a wall-clock deadline: accumulating the
        // *nominal* poll interval would drift under scheduler jitter
        // (each sleep runs at least `poll`, often longer).
        let deadline = Instant::now() + idle_timeout;
        let mut file = loop {
            match File::open(path) {
                Ok(f) => break f,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(IngestError::fatal(format!(
                            "{name}: {e} (waited {idle_timeout:?} for the producer)"
                        )));
                    }
                    std::thread::sleep(poll);
                }
            }
        };
        loop {
            let len =
                file.metadata().map_err(|e| IngestError::fatal(format!("{name}: {e}")))?.len();
            if len >= io::FILE_HEADER_BYTES {
                break;
            }
            if Instant::now() >= deadline {
                return Err(IngestError::fatal(format!(
                    "{name}: no container header after {idle_timeout:?}"
                )));
            }
            std::thread::sleep(poll);
        }
        let (w, h, _advisory_n) = io::read_file_header(&mut file)
            .map_err(|e| IngestError::fatal(format!("{name}: {e}")))?;
        validate_geometry(w, h, &name)?;
        Ok(TailSource {
            name,
            file,
            w,
            h,
            offset: io::FILE_HEADER_BYTES,
            poll,
            idle_timeout,
            policy: UnsortedPolicy::Sort,
            limit: None,
            emitted: 0,
        })
    }

    /// Override the unsorted-events policy (default: sort — live capture
    /// can reorder events in flight).
    pub fn with_unsorted_policy(mut self, policy: UnsortedPolicy) -> TailSource {
        self.policy = policy;
        self
    }

    /// Cap the number of requests emitted (default: follow forever, until
    /// the idle timeout).
    pub fn with_limit(mut self, limit: usize) -> TailSource {
        self.limit = Some(limit);
        self
    }

    fn io_err(&self, e: std::io::Error) -> IngestError {
        IngestError::fatal(format!("{}: {e}", self.name))
    }
}

impl EventSource for TailSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn geometry(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        if self.limit.is_some_and(|l| self.emitted >= l) {
            return Ok(None);
        }
        // Idle budget against a wall-clock deadline (not `+= poll`
        // accumulation, which under-counts real elapsed time whenever a
        // sleep overshoots its nominal interval).
        let mut deadline = Instant::now() + self.idle_timeout;
        let mut last_len = u64::MAX;
        loop {
            let len = self.file.metadata().map_err(|e| self.io_err(e))?.len();
            if len < self.offset {
                // The file shrank below what we already consumed: it was
                // truncated or rotated out from under the tail. Stale
                // offsets into a replacement file would parse unrelated
                // bytes as samples — fail loudly instead.
                return Err(IngestError::fatal(format!(
                    "{}: file shrank to {len} byte(s) below consumed offset {} — \
                     truncated or rotated mid-tail",
                    self.name, self.offset
                )));
            }
            if len != last_len {
                // The file grew (or this is the first look): the producer
                // is alive, restart the idle clock.
                last_len = len;
                deadline = Instant::now() + self.idle_timeout;
            }
            if len >= self.offset + io::SAMPLE_HEADER_BYTES {
                self.file
                    .seek(SeekFrom::Start(self.offset))
                    .map_err(|e| self.io_err(e))?;
                let mut prefix = [0u8; 8];
                self.file.read_exact(&mut prefix).map_err(|e| self.io_err(e))?;
                let label = u32::from_le_bytes([prefix[0], prefix[1], prefix[2], prefix[3]]);
                let ne = u32::from_le_bytes([prefix[4], prefix[5], prefix[6], prefix[7]]) as u64;
                if ne > MAX_TAIL_EVENTS {
                    return Err(IngestError::fatal(format!(
                        "{}: sample at byte {} claims {ne} events (cap {MAX_TAIL_EVENTS}) — \
                         corrupt tail",
                        self.name, self.offset
                    )));
                }
                let need = io::SAMPLE_HEADER_BYTES + ne * io::EVENT_BYTES;
                if len >= self.offset + need {
                    // The whole sample is on disk (the file only grows,
                    // so the bytes cannot vanish between check and read).
                    let mut events =
                        io::read_events(&mut self.file, ne as usize).map_err(|e| self.io_err(e))?;
                    let what = format!("sample at byte {}", self.offset);
                    self.offset += need;
                    validate_events(&mut events, self.w, self.h, self.policy, &what)?;
                    self.emitted += 1;
                    return Ok(Some(SourcedRequest {
                        label: label as usize,
                        events,
                        arrival: Instant::now(),
                        tenant: DEFAULT_TENANT,
                        model: 0,
                        stream: None,
                    }));
                }
            }
            if Instant::now() >= deadline {
                if len > self.offset {
                    // Trailing bytes that never became a whole sample: a
                    // producer crash mid-append is a truncation error,
                    // not a clean end of stream.
                    return Err(IngestError::fatal(format!(
                        "{}: producer went quiet mid-sample ({} trailing byte(s) past \
                         offset {})",
                        self.name,
                        len - self.offset,
                        self.offset
                    )));
                }
                return Ok(None); // quiet at a sample boundary: end of stream
            }
            std::thread::sleep(self.poll);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::io::{append_sample, write_dataset, write_header, Sample};
    use std::io::Write as _;

    fn ev(t: u32, x: u16, y: u16) -> Event {
        Event { t_us: t, x, y, polarity: true }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("esda_ingest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn synthetic_source_emits_the_classic_stream() {
        let profile = DatasetProfile::n_mnist();
        let (w, h) = (profile.w, profile.h);
        let mut src = SyntheticSource::new(profile, 5, 42);
        assert_eq!(src.geometry(), (w, h));
        for i in 0..5 {
            let r = src.next_request().unwrap().expect("request");
            assert_eq!(r.label, i % 10);
            assert!(!r.events.is_empty());
            assert!(is_time_sorted(&r.events));
        }
        assert!(src.next_request().unwrap().is_none(), "stream must end at n");
    }

    /// Plain mode stamps no stream identity; the classic request stream is
    /// unchanged by the overlap machinery existing.
    #[test]
    fn synthetic_source_plain_mode_has_no_stream() {
        let profile = DatasetProfile::n_mnist();
        let mut src = SyntheticSource::new(profile, 3, 42);
        while let Some(r) = src.next_request().unwrap() {
            assert_eq!(r.stream, None);
        }
    }

    /// Overlap mode: streams cycle round-robin with fixed per-stream
    /// labels, windows stay valid, and after the first window of a stream
    /// roughly `frac` of the previous window's pixels recur.
    #[test]
    fn synthetic_source_overlap_mode_produces_overlapping_streams() {
        let profile = DatasetProfile::n_mnist();
        let n_classes = profile.n_classes;
        let mut src = SyntheticSource::new(profile, 12, 7).with_overlap(0.9, 3);
        let mut prev: Vec<Option<Vec<Event>>> = vec![None; 3];
        for i in 0..12 {
            let r = src.next_request().unwrap().expect("request");
            let s = (i % 3) as u64;
            assert_eq!(r.stream, Some(s));
            assert_eq!(r.label, (s as usize) % n_classes);
            assert!(is_time_sorted(&r.events));
            assert!(!r.events.is_empty());
            if let Some(p) = &prev[s as usize] {
                let pixels: std::collections::HashSet<(u16, u16)> =
                    p.iter().map(|e| (e.x, e.y)).collect();
                let shared = r.events.iter().filter(|e| pixels.contains(&(e.x, e.y))).count();
                assert!(
                    shared as f64 >= 0.5 * r.events.len() as f64,
                    "window {i}: only {shared}/{} events on previously-active pixels",
                    r.events.len()
                );
            }
            prev[s as usize] = Some(r.events);
        }
        assert!(src.next_request().unwrap().is_none());
    }

    /// Overlap mode is deterministic per seed.
    #[test]
    fn synthetic_source_overlap_mode_is_deterministic() {
        let mk = || SyntheticSource::new(DatasetProfile::n_mnist(), 6, 99).with_overlap(0.5, 2);
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..6 {
            let (ra, rb) = (a.next_request().unwrap().unwrap(), b.next_request().unwrap().unwrap());
            assert_eq!(ra.events, rb.events);
            assert_eq!((ra.label, ra.stream), (rb.label, rb.stream));
        }
    }

    #[test]
    fn replay_source_replays_in_file_order_with_limit() {
        let dir = tmp_dir("replay");
        let path = dir.join("d.esda");
        let samples: Vec<Sample> = (0..4)
            .map(|i| Sample { label: i, events: vec![ev(10, 1, 2), ev(20, 3, 4)] })
            .collect();
        write_dataset(&path, 8, 8, &samples).unwrap();
        // Generous speed: pacing sleeps are sub-microsecond.
        let mut src = ReplaySource::open(&path, 1e6).unwrap();
        assert_eq!(src.geometry(), (8, 8));
        assert_eq!(src.remaining(), 4);
        let mut labels = Vec::new();
        while let Some(r) = src.next_request().unwrap() {
            labels.push(r.label);
        }
        assert_eq!(labels, vec![0, 1, 2, 3]);
        assert!(src.next_request().unwrap().is_none(), "drained source stays drained");

        let mut src = ReplaySource::open(&path, 1e6).unwrap().with_limit(2);
        assert_eq!(src.remaining(), 2);
        assert!(src.next_request().unwrap().is_some());
        assert!(src.next_request().unwrap().is_some());
        assert!(src.next_request().unwrap().is_none(), "limit must cap the stream");
    }

    /// Replay pacing: a recording that spans T µs of camera time arrives
    /// no earlier than T/speed after the stream starts — and a large
    /// speed factor compresses that to nothing.
    #[test]
    fn replay_paces_arrivals_by_duration_over_speed() {
        let dir = tmp_dir("pace");
        let path = dir.join("d.esda");
        // Two samples, each spanning 10 ms of camera time.
        let samples: Vec<Sample> = (0..2)
            .map(|i| Sample { label: i, events: vec![ev(0, 0, 0), ev(10_000, 1, 1)] })
            .collect();
        write_dataset(&path, 4, 4, &samples).unwrap();
        let mut src = ReplaySource::open(&path, 1.0).unwrap();
        let t0 = Instant::now();
        let a = src.next_request().unwrap().unwrap();
        let b = src.next_request().unwrap().unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(20),
            "20 ms of camera time replayed at 1x in {:?}",
            t0.elapsed()
        );
        assert!(b.arrival >= a.arrival, "arrivals must be monotone");

        let mut fast = ReplaySource::open(&path, 1e3).unwrap();
        let t0 = Instant::now();
        while fast.next_request().unwrap().is_some() {}
        assert!(
            t0.elapsed() < Duration::from_millis(20),
            "1000x replay should compress 20 ms to ~20 µs, took {:?}",
            t0.elapsed()
        );
    }

    /// The ingestion boundary enforces time order: replay rejects
    /// unsorted samples by default and stable-sorts them on request.
    #[test]
    fn replay_applies_the_unsorted_policy() {
        let dir = tmp_dir("unsorted");
        let path = dir.join("d.esda");
        let samples = vec![Sample {
            label: 0,
            events: vec![ev(30, 1, 1), ev(10, 2, 2), ev(20, 3, 3)],
        }];
        write_dataset(&path, 8, 8, &samples).unwrap();
        let mut strict = ReplaySource::open(&path, 1e6).unwrap();
        let err = strict.next_request().unwrap_err();
        assert!(err.to_string().contains("time-sorted"), "{err}");
        assert!(err.is_recoverable(), "a validation reject must be recoverable");
        // A rejected sample is consumed: retrying must not hand back a
        // phantom empty request built from the taken-out events — the
        // stream simply ends here (it was the only sample).
        assert!(strict.next_request().unwrap().is_none(), "rejected sample must be consumed");

        let mut lenient = ReplaySource::open(&path, 1e6)
            .unwrap()
            .with_unsorted_policy(UnsortedPolicy::Sort);
        let r = lenient.next_request().unwrap().unwrap();
        let ts: Vec<u32> = r.events.iter().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![10, 20, 30]);
    }

    /// Out-of-geometry events would index the repr builder's dense
    /// scratch out of bounds — the boundary rejects them.
    #[test]
    fn replay_rejects_out_of_geometry_events() {
        let dir = tmp_dir("geom");
        let path = dir.join("d.esda");
        let samples = vec![Sample { label: 0, events: vec![ev(5, 200, 0)] }];
        write_dataset(&path, 8, 8, &samples).unwrap();
        let mut src = ReplaySource::open(&path, 1e6).unwrap();
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
        assert!(err.is_recoverable(), "a geometry reject must be recoverable");
    }

    #[test]
    fn replay_rejects_degenerate_speed() {
        let dir = tmp_dir("speed");
        let path = dir.join("d.esda");
        write_dataset(&path, 4, 4, &[]).unwrap();
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(ReplaySource::open(&path, bad).is_err(), "accepted speed {bad}");
        }
    }

    /// Regression: a tiny-but-valid `@speed` used to reach
    /// `Duration::from_secs_f64` with an astronomically large due offset
    /// and *panic* on overflow; the pacer must instead report an
    /// `IngestError` naming the degenerate pacing.
    #[test]
    fn replay_rejects_pacing_overflow_instead_of_panicking() {
        let dir = tmp_dir("overflow");
        let path = dir.join("d.esda");
        // One sample spanning 10 ms of camera time: at speed 1e-300 its
        // due offset is ~1e295 s — far past anything a Duration can hold.
        let samples =
            vec![Sample { label: 0, events: vec![ev(0, 0, 0), ev(10_000, 1, 1)] }];
        write_dataset(&path, 4, 4, &samples).unwrap();
        let mut src = ReplaySource::open(&path, 1e-300).expect("1e-300 is a valid speed");
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("pacing overflow"), "{err}");
        assert!(err.to_string().contains("sample 0"), "{err}");
        // A zero-duration capture at the same speed paces fine (0 / tiny
        // = 0): the guard rejects degenerate *products*, not speeds.
        let path2 = dir.join("flat.esda");
        write_dataset(&path2, 4, 4, &[Sample { label: 3, events: vec![ev(5, 0, 0)] }])
            .unwrap();
        let mut src = ReplaySource::open(&path2, 1e-300).unwrap();
        assert_eq!(src.next_request().unwrap().unwrap().label, 3);
    }

    /// The streaming replay reads one sample at a time off the io
    /// primitives: a header over-claim fails at open, and a sample that
    /// over-claims the remaining bytes fails exactly when it is reached —
    /// after the valid prefix of the capture was already served.
    #[test]
    fn replay_streams_and_rejects_corruption_at_the_offending_sample() {
        use std::io::Write as _;
        let dir = tmp_dir("stream");
        // Header promising more samples than the file could hold: open
        // fails before any request is emitted.
        let path = dir.join("overclaim_n.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 1000).unwrap();
        f.flush().unwrap();
        drop(f);
        let err = ReplaySource::open(&path, 1e6).unwrap_err();
        assert!(err.to_string().contains("1000 sample(s)"), "{err}");

        // A valid first sample, then a sample claiming more event bytes
        // than remain: the first replays, the second errors (streaming —
        // the failure surfaces mid-stream, not at open).
        let path = dir.join("overclaim_ne.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 2).unwrap();
        append_sample(&mut f, &Sample { label: 4, events: vec![ev(1, 1, 1)] }).unwrap();
        f.write_all(&7u32.to_le_bytes()).unwrap(); // label
        f.write_all(&100u32.to_le_bytes()).unwrap(); // 100 events claimed…
        f.write_all(&[0u8; 10]).unwrap(); // …1 event's bytes present
        f.flush().unwrap();
        drop(f);
        let mut src = ReplaySource::open(&path, 1e6).unwrap();
        assert_eq!(src.remaining(), 2);
        assert_eq!(src.next_request().unwrap().unwrap().label, 4);
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("sample 1"), "{err}");
        assert!(err.to_string().contains("claims 100 event(s)"), "{err}");
        assert!(!err.is_recoverable(), "a byte-stream failure must be fatal");
        // A byte-stream failure latches: retrying must re-report it, not
        // parse the corrupt sample's payload bytes as a fresh prefix.
        let err2 = src.next_request().unwrap_err();
        assert!(err2.to_string().contains("claims 100 event(s)"), "{err2}");

        // Truncated before the second sample's prefix: same per-sample
        // failure point.
        let path = dir.join("cut.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 2).unwrap();
        append_sample(&mut f, &Sample { label: 2, events: vec![ev(1, 1, 1)] }).unwrap();
        f.write_all(&[0u8; 3]).unwrap(); // 3 of the 8 prefix bytes
        f.flush().unwrap();
        drop(f);
        let mut src = ReplaySource::open(&path, 1e6).unwrap();
        assert_eq!(src.next_request().unwrap().unwrap().label, 2);
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("sample 1"), "{err}");
    }

    /// A tail source sees samples appear as a producer appends them, and
    /// ends the stream once the producer goes quiet.
    #[test]
    fn tail_source_follows_a_growing_file() {
        let dir = tmp_dir("tail");
        let path = dir.join("grow.esda");
        let s0 = Sample { label: 7, events: vec![ev(1, 1, 1), ev(2, 2, 2)] };
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 0).unwrap(); // advisory count: producer appends
        append_sample(&mut f, &s0).unwrap();
        f.flush().unwrap();
        drop(f);

        let mut src = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(200),
        )
        .unwrap();
        assert_eq!(src.geometry(), (8, 8));
        let r = src.next_request().unwrap().expect("pre-existing sample");
        assert_eq!(r.label, 7);
        assert_eq!(r.events, s0.events);

        // A producer thread appends the next sample after a delay; the
        // tail blocks until it is fully on disk.
        let path2 = path.clone();
        let appender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let mut f = std::fs::OpenOptions::new().append(true).open(&path2).unwrap();
            let s1 = Sample { label: 3, events: vec![ev(9, 4, 4)] };
            append_sample(&mut f, &s1).unwrap();
            f.flush().unwrap();
        });
        let r = src.next_request().unwrap().expect("appended sample");
        assert_eq!(r.label, 3);
        appender.join().unwrap();

        // No further growth: the idle timeout ends the stream.
        let t0 = Instant::now();
        assert!(src.next_request().unwrap().is_none());
        assert!(t0.elapsed() >= Duration::from_millis(200), "must wait out the idle window");
    }

    /// Live tails default to sorting reordered events instead of
    /// rejecting the stream.
    #[test]
    fn tail_source_sorts_reordered_events_by_default() {
        let dir = tmp_dir("tailsort");
        let path = dir.join("grow.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 0).unwrap();
        append_sample(
            &mut f,
            &Sample { label: 0, events: vec![ev(50, 1, 1), ev(10, 2, 2)] },
        )
        .unwrap();
        f.flush().unwrap();
        drop(f);
        let mut src = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(50),
        )
        .unwrap();
        let r = src.next_request().unwrap().unwrap();
        let ts: Vec<u32> = r.events.iter().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![10, 50], "tail must stable-sort reordered events");
        // Under an explicit reject policy the same bytes are an error.
        let mut strict = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(50),
        )
        .unwrap()
        .with_unsorted_policy(UnsortedPolicy::Reject);
        assert!(strict.next_request().is_err());
    }

    /// A producer that dies mid-append leaves trailing bytes that never
    /// become a whole sample: that is a truncation error, not a clean end
    /// of stream — and a consumer started before the file exists waits
    /// for the producer instead of failing instantly.
    #[test]
    fn tail_reports_truncation_and_waits_for_late_producers() {
        let dir = tmp_dir("tailtrunc");
        let path = dir.join("grow.esda");
        // Consumer first: opening waits for the producer to create the
        // file and write the header.
        let path2 = path.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let mut f = std::fs::File::create(&path2).unwrap();
            write_header(&mut f, 8, 8, 0).unwrap();
            append_sample(&mut f, &Sample { label: 1, events: vec![ev(1, 1, 1)] }).unwrap();
            // ...then dies mid-append: a prefix claiming 4 events, no
            // event bytes.
            f.write_all(&2u32.to_le_bytes()).unwrap();
            f.write_all(&4u32.to_le_bytes()).unwrap();
            f.flush().unwrap();
        });
        let mut src = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(120),
        )
        .expect("open must wait for the producer to create the file");
        producer.join().unwrap();
        let r = src.next_request().unwrap().expect("the complete sample");
        assert_eq!(r.label, 1);
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("mid-sample"), "{err}");
    }

    /// A tail file that shrinks below the consumed offset (truncated or
    /// rotated) must fail loudly — a stale offset into a replacement
    /// file would parse unrelated bytes as samples.
    #[test]
    fn tail_rejects_a_shrunken_file() {
        let dir = tmp_dir("tailshrink");
        let path = dir.join("grow.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 0).unwrap();
        append_sample(&mut f, &Sample { label: 5, events: vec![ev(1, 1, 1)] }).unwrap();
        f.flush().unwrap();
        drop(f);
        let mut src = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(50),
        )
        .unwrap();
        assert_eq!(src.next_request().unwrap().unwrap().label, 5);
        // Rotate: the file is replaced by a bare header, shorter than
        // what the tail already consumed.
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(io::FILE_HEADER_BYTES).unwrap();
        drop(f);
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("shrank"), "{err}");
    }

    #[test]
    fn tail_rejects_corrupt_event_count() {
        let dir = tmp_dir("tailcorrupt");
        let path = dir.join("grow.esda");
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 8, 8, 0).unwrap();
        // A prefix claiming ~4 billion events: waiting for it would hang
        // the pipeline forever, so the tail must call it corrupt.
        f.write_all(&0u32.to_le_bytes()).unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap();
        f.flush().unwrap();
        drop(f);
        let mut src = TailSource::open_with(
            &path,
            Duration::from_millis(1),
            Duration::from_millis(50),
        )
        .unwrap();
        let err = src.next_request().unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
    }

    /// A labels sidecar overrides the container's recorded labels, one
    /// `u32` per sample in order.
    #[test]
    fn labels_sidecar_overrides_container_labels() {
        let dir = tmp_dir("labels");
        let path = dir.join("ds.esda");
        let samples: Vec<Sample> = (0..4)
            .map(|i| Sample { label: 9, events: vec![ev(10 * i, 1, 1)] })
            .collect();
        write_dataset(&path, 8, 8, &samples).unwrap();
        let sidecar = dir.join("truth.labels");
        let mut bytes = Vec::new();
        for l in [3u32, 1, 4, 1] {
            bytes.extend_from_slice(&l.to_le_bytes());
        }
        std::fs::write(&sidecar, &bytes).unwrap();
        let mut src =
            ReplaySource::open(&path, 1e6).unwrap().with_labels(&sidecar).unwrap();
        let mut got = Vec::new();
        while let Some(r) = src.next_request().unwrap() {
            got.push(r.label);
        }
        assert_eq!(got, vec![3, 1, 4, 1], "sidecar truth replaces the recorded 9s");
    }

    /// Regression: a sidecar that does not cover the dataset exactly is a
    /// fatal error up front — silently scoring against someone else's
    /// annotation would corrupt every accuracy number downstream.
    #[test]
    fn labels_sidecar_count_mismatch_is_fatal() {
        let dir = tmp_dir("labelsbad");
        let path = dir.join("ds.esda");
        let samples: Vec<Sample> =
            (0..3).map(|i| Sample { label: 0, events: vec![ev(i, 1, 1)] }).collect();
        write_dataset(&path, 8, 8, &samples).unwrap();
        // Too few labels.
        let short = dir.join("short.labels");
        std::fs::write(&short, 2u32.to_le_bytes()).unwrap();
        let err = ReplaySource::open(&path, 1e6)
            .unwrap()
            .with_labels(&short)
            .err()
            .expect("1 label for 3 samples must fail");
        assert!(!err.is_recoverable(), "a mismatched sidecar is fatal");
        assert!(err.to_string().contains("1 label(s)"), "{err}");
        // Not a whole number of u32s.
        let ragged = dir.join("ragged.labels");
        std::fs::write(&ragged, [1u8, 2, 3]).unwrap();
        let err = ReplaySource::open(&path, 1e6)
            .unwrap()
            .with_labels(&ragged)
            .err()
            .expect("3 ragged bytes must fail");
        assert!(err.to_string().contains("whole number"), "{err}");
    }

    /// The model-mix wrapper stamps models cyclically by weight and
    /// passes everything else through untouched.
    #[test]
    fn mix_source_stamps_models_by_weight() {
        let profile = DatasetProfile::n_mnist();
        let inner = SyntheticSource::new(profile, 7, 3);
        let mut src = MixSource::new(Box::new(inner), &[2, 1]);
        assert_eq!(src.geometry(), (34, 34));
        let mut models = Vec::new();
        while let Some(r) = src.next_request().unwrap() {
            models.push(r.model);
        }
        assert_eq!(models, vec![0, 0, 1, 0, 0, 1, 0], "weights [2,1] cycle 0,0,1");
        // Degenerate weights fall back to the default model.
        let profile = DatasetProfile::n_mnist();
        let mut src =
            MixSource::new(Box::new(SyntheticSource::new(profile, 2, 3)), &[]);
        assert_eq!(src.next_request().unwrap().unwrap().model, 0);
    }
}
