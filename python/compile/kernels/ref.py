"""Pure-jnp oracles for the ESDA layer algebra (the L1 correctness
reference).

Dense bitmap-masked formulation of submanifold sparse convolution (see
DESIGN.md §3 "Hardware adaptation"): activations live in an (H, W, C)
array, the nonzero set in an (H, W) mask. Stride-1 submanifold conv is
``conv(x) * mask``; stride-2 sparse conv is ``conv_s2(x) * maxpool2(mask)``
(the paper's 2×2-grid token rule, Fig. 3b). This matches the rust
functional references in ``rust/src/sparse/conv.rs`` coordinate-for-
coordinate (weights laid out as w[dy, dx, cin, cout] == rust's
``w[(off*cin+ci)*cout+co]``).
"""

import jax.numpy as jnp
from jax import lax


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def apply_act(x, act: str):
    if act == "relu6":
        return relu6(x)
    if act == "relu":
        return jnp.maximum(x, 0.0)
    return x


def downsample_mask(mask):
    """Stride-2 token rule: output cell nonzero iff its 2x2 grid has any
    nonzero (pads odd edges with zeros, matching ceil(w/2) geometry)."""
    h, w = mask.shape
    ph, pw = (h + 1) // 2 * 2, (w + 1) // 2 * 2
    m = jnp.pad(mask.astype(jnp.float32), ((0, ph - h), (0, pw - w)))
    m = m.reshape(ph // 2, 2, pw // 2, 2).max(axis=(1, 3))
    return m > 0


def conv2d(x, w, stride: int):
    """Plain dense conv, pad (k-1)/2, stride s. x: (H, W, Cin),
    w: (k, k, Cin, Cout)."""
    k = w.shape[0]
    pad = (k - 1) // 2
    extra_h = x.shape[0] % 2 if stride == 2 else 0
    extra_w = x.shape[1] % 2 if stride == 2 else 0
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad + extra_h), (pad, pad + extra_w)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    if stride == 2:
        out = out[: (x.shape[0] + 1) // 2, : (x.shape[1] + 1) // 2]
    return out


def conv1x1(x, mask, w, b, act="none"):
    """Pointwise conv: tokens (mask) unchanged."""
    out = x @ w + b
    return apply_act(out, act) * mask[..., None], mask


def submanifold_conv(x, mask, w, b, stride=1, act="none"):
    """k×k submanifold (stride 1) / sparse (stride 2) convolution.

    w: (k, k, Cin, Cout); returns (out, out_mask).
    """
    out = conv2d(x, w, stride) + b
    out_mask = mask if stride == 1 else downsample_mask(mask)
    return apply_act(out, act) * out_mask[..., None], out_mask


def submanifold_dwconv(x, mask, w, b, stride=1, act="none"):
    """Depthwise variant. w: (k, k, C)."""
    k, _, c = w.shape
    wd = w.reshape(k, k, 1, c)
    pad = (k - 1) // 2
    extra_h = x.shape[0] % 2 if stride == 2 else 0
    extra_w = x.shape[1] % 2 if stride == 2 else 0
    out = lax.conv_general_dilated(
        x[None],
        wd,
        window_strides=(stride, stride),
        padding=[(pad, pad + extra_h), (pad, pad + extra_w)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )[0]
    if stride == 2:
        out = out[: (x.shape[0] + 1) // 2, : (x.shape[1] + 1) // 2]
    out = out + b
    out_mask = mask if stride == 1 else downsample_mask(mask)
    return apply_act(out, act) * out_mask[..., None], out_mask


def global_pool_fc(x, mask, wfc, bfc):
    """Average over nonzero tokens (MinkowskiEngine semantics), then FC."""
    n = jnp.maximum(mask.sum(), 1.0)
    pooled = (x * mask[..., None]).sum(axis=(0, 1)) / n
    return pooled @ wfc + bfc


def residual_add(a, b, mask):
    return (a + b) * mask[..., None]


def standard_conv(x, mask, w, b, stride=1, act="none"):
    """Standard (non-submanifold) conv twin for the Fig. 12 comparison:
    the output mask is wherever the conv output is nonzero (dilation)."""
    out = apply_act(conv2d(x, w, stride) + b, act)
    out_mask = jnp.any(jnp.abs(out) > 0, axis=-1)
    return out, out_mask
