//! In-tree static analysis: `esda lint`.
//!
//! A dependency-free invariant checker for the repo's own source tree.
//! The dynamic checks (allocator counters, property tests) only prove
//! invariants on paths the tests actually execute; this pass proves the
//! textual ones everywhere — including fallback branches — and runs
//! even where `cargo test` cannot. Rules:
//!
//! - **panic** — no `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` in the serving path (`coordinator/`,
//!   `model/plan.rs`, `sparse/`, `events/`). The mutex-poisoning idiom
//!   `.lock().unwrap()` is allowed by pattern (a poisoned lock means a
//!   worker already panicked — propagating is the correct response);
//!   anything else needs `// lint:allow(panic): <reason>`.
//! - **hot-alloc** — no allocating constructors (`Vec::new`, `vec![`,
//!   `with_capacity`, `.to_vec()`, `.clone()`, `.collect`, `format!`,
//!   `Box::new`, `String::from`) inside regions bracketed by
//!   `// lint: hot-path` … `// lint: hot-path end` markers (the
//!   steady-state execute/delta kernels).
//! - **cast** — no bare narrowing `as u16` / `as u32` / `as usize` in
//!   the wire-format files (`events/io.rs`, `coordinator/net.rs`);
//!   conversions must go through `try_from`-based checked helpers.
//! - **module-size** — no non-test library module over
//!   [`MODULE_SIZE_CAP`] code lines (blank, comment-only, and
//!   `#[cfg(test)]` lines don't count). `coordinator/serve.rs` grew to
//!   a 2,100-line monolith before it was split into `serve/` stages;
//!   this rule keeps the next one from regrowing.
//! - **drift-metrics** — every `usize` counter field of `Metrics` /
//!   `TenantStats` / `ClassStats` / `DeltaMetrics` / `ModelStats` must
//!   be referenced in `report/` (a counter nobody renders is a
//!   books-keeping bug waiting to be re-found by hand).
//! - **drift-flags** — every `--flag` string parsed via the `Args`
//!   accessors in `util/cli.rs` / `main.rs` must appear in README.md.
//! - **print** — `println!` / `eprintln!` are forbidden in library
//!   modules outside `report/` and `main.rs` (libraries return data;
//!   the binary renders it). `examples/` and `benches/` are binaries
//!   like `main.rs` and share its exemption (printing is their job).
//! - **lock-order** / **lock-span** / **atomic-rmw** /
//!   **atomic-ordering** — the concurrency-discipline rules over
//!   `coordinator/`; see [`concurrency`].
//!
//! The walk covers `rust/src`, `examples/`, and `rust/benches/` (the
//! binaries get the panic/print/cast treatment; the library-shape rules
//! exempt them like `main.rs`).
//!
//! Any rule can be suppressed site-by-site with
//! `// lint:allow(<rule>): <reason>` on the same or preceding line —
//! the reason is mandatory, an annotation without one is itself a
//! finding — or file-wide with `// lint:allow-file(<rule>): <reason>`
//! in the file's first [`FILE_ALLOW_WINDOW`] lines (for binaries whose
//! whole idiom a rule would fight, e.g. fail-fast `.unwrap()` in an
//! example). Test items (`#[cfg(test)]` / `#[test]`) are exempt from
//! every rule.

pub mod concurrency;
pub mod scan;

use scan::ScannedLine;
use std::path::{Path, PathBuf};

/// One source file presented to the linter.
pub struct SourceFile {
    /// Path relative to the crate's `src/` root, `/`-separated (rule
    /// scoping keys off this).
    pub rel_path: String,
    pub text: String,
}

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    /// Suggested remediation, rendered by `esda lint --fix-plan`.
    pub fix: String,
}

impl Finding {
    /// The canonical `file:line: rule: message` form.
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

const PANIC_TOKENS: [&str; 5] = [".unwrap()", ".expect(", "panic!", "unreachable!", "todo!"];
const ALLOC_TOKENS: [&str; 9] = [
    "Vec::new",
    "vec![",
    "with_capacity",
    ".to_vec()",
    ".clone()",
    ".collect",
    "format!",
    "Box::new",
    "String::from",
];
const NARROW_CASTS: [&str; 3] = ["u16", "u32", "usize"];
const CAST_FILES: [&str; 3] = ["events/io.rs", "coordinator/net.rs", "examples/net_serving.rs"];
const METRIC_STRUCTS: [&str; 5] =
    ["Metrics", "TenantStats", "ClassStats", "DeltaMetrics", "ModelStats"];

/// Cap on non-test code lines per library module (see the module docs).
pub const MODULE_SIZE_CAP: usize = 900;
const FLAG_ACCESSORS: [&str; 6] =
    [".get(", ".get_or(", ".get_usize(", ".get_u64(", ".get_f64(", ".has("];
const FLAG_FILES: [&str; 2] = ["util/cli.rs", "main.rs"];

/// Lint a set of scanned sources. `readme` is the README text the
/// drift-flags rule checks against (the rule is skipped when `None` —
/// e.g. when linting a bare file list with no README in reach).
pub fn lint_sources(files: &[SourceFile], readme: Option<&str>) -> Vec<Finding> {
    let scanned: Vec<(&SourceFile, scan::Scanned)> =
        files.iter().map(|f| (f, scan::scan(&f.text))).collect();
    let mut out = Vec::new();
    for (f, s) in &scanned {
        rule_panic(f, s, &mut out);
        rule_hot_alloc(f, s, &mut out);
        rule_cast(f, s, &mut out);
        rule_print(f, s, &mut out);
        rule_module_size(f, s, &mut out);
    }
    rule_drift_metrics(&scanned, &mut out);
    rule_drift_flags(&scanned, readme, &mut out);
    concurrency::rules(&scanned, &mut out);
    out.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    // Several sites suppressed by one reasonless (file-)directive all
    // report the same directive line; keep one copy.
    out.dedup_by(|a, b| {
        a.file == b.file && a.line == b.line && a.rule == b.rule && a.message == b.message
    });
    out
}

/// Collect `.rs` files under each path (files taken as-is, directories
/// walked recursively), with rel paths taken from the last `src`
/// component so rule scoping works wherever the walk was rooted.
pub fn collect_files(paths: &[PathBuf]) -> Result<Vec<SourceFile>, String> {
    let mut found = Vec::new();
    for p in paths {
        if p.is_dir() {
            walk(p, &mut found)?;
        } else {
            found.push(p.clone());
        }
    }
    found.sort();
    found.dedup();
    let mut files = Vec::new();
    for p in found {
        let text = std::fs::read_to_string(&p).map_err(|e| format!("{}: {e}", p.display()))?;
        files.push(SourceFile { rel_path: rel_of(&p), text });
    }
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in rd {
        let p = entry.map_err(|e| format!("{}: {e}", dir.display()))?.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Rel path for rule scoping: components after the last `src`, or from
/// the last `examples`/`benches` component inclusive (so an example
/// lands at `examples/foo.rs` wherever the walk was rooted), or the
/// whole path when neither anchor appears.
fn rel_of(p: &Path) -> String {
    let comps: Vec<String> =
        p.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    if let Some(pos) = comps.iter().rposition(|c| c == "src") {
        return comps[pos + 1..].join("/");
    }
    if let Some(pos) = comps.iter().rposition(|c| c == "examples" || c == "benches") {
        return comps[pos..].join("/");
    }
    comps.join("/")
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Byte offsets of `tok` in `code`, requiring a non-identifier char (or
/// line start) before tokens that begin with an identifier char.
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let needs_boundary = tok.starts_with(is_ident);
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        let prev_ident = code[..at].chars().next_back().is_some_and(is_ident);
        if !needs_boundary || !prev_ident {
            out.push(at);
        }
        from = at + tok.len();
    }
    out
}

/// Does `word` occur in `hay` with non-identifier chars on both sides?
fn word_in(hay: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let pre = hay[..at].chars().next_back().is_some_and(is_ident);
        let post = hay[at + word.len()..].chars().next().is_some_and(is_ident);
        if !pre && !post {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// Parse a `lint:allow(<rule>): <reason>` directive out of comment
/// text. Returns `(rule, reason)`; the reason is empty when the
/// mandatory `: <reason>` tail is missing.
fn allow_marker(comment: &str) -> Option<(&str, &str)> {
    let pos = comment.find("lint:allow(")?;
    let rest = &comment[pos + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim();
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').unwrap_or("").trim();
    Some((rule, reason))
}

enum Allow {
    No,
    Yes,
    /// Marker present but reasonless — 0-based line of the marker.
    MissingReason(usize),
}

/// Look for a matching allow directive on the violation's own line or
/// on the run of pure-comment lines immediately above it (doc comments
/// included, so a directive can sit among a field's docs).
fn allow_state(lines: &[ScannedLine], idx: usize, rule: &str) -> Allow {
    let mut candidates = vec![idx];
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.code.trim().is_empty() && !l.comment.trim().is_empty() {
            candidates.push(j);
        } else {
            break;
        }
    }
    for &k in &candidates {
        if let Some((r, reason)) = allow_marker(&lines[k].comment) {
            if r == rule {
                if reason.is_empty() {
                    return Allow::MissingReason(k);
                }
                return Allow::Yes;
            }
        }
    }
    Allow::No
}

/// Masthead directives must sit in the file's first lines — a suppression
/// buried mid-file is invisible to a reviewer skimming the header.
pub const FILE_ALLOW_WINDOW: usize = 30;

/// Look for a `lint:allow-file(<rule>): <reason>` masthead directive in
/// the first [`FILE_ALLOW_WINDOW`] lines.
fn allow_file_state(lines: &[ScannedLine], rule: &str) -> Allow {
    for (k, l) in lines.iter().take(FILE_ALLOW_WINDOW).enumerate() {
        let Some(pos) = l.comment.find("lint:allow-file(") else {
            continue;
        };
        let rest = &l.comment[pos + "lint:allow-file(".len()..];
        let Some(close) = rest.find(')') else {
            continue;
        };
        if rest[..close].trim() != rule {
            continue;
        }
        let after = rest[close + 1..].trim_start();
        if after.strip_prefix(':').unwrap_or("").trim().is_empty() {
            return Allow::MissingReason(k);
        }
        return Allow::Yes;
    }
    Allow::No
}

/// Push a finding unless an allow directive suppresses it; a
/// reasonless directive becomes its own finding.
fn emit(
    out: &mut Vec<Finding>,
    file: &str,
    lines: &[ScannedLine],
    idx: usize,
    rule: &'static str,
    message: String,
    fix: String,
) {
    match allow_state(lines, idx, rule) {
        Allow::Yes => return,
        Allow::MissingReason(k) => {
            out.push(Finding {
                file: file.to_string(),
                line: k + 1,
                rule,
                message: format!("lint:allow({rule}) without a reason"),
                fix: format!("spell it `// lint:allow({rule}): <why this site is safe>`"),
            });
            return;
        }
        Allow::No => {}
    }
    match allow_file_state(lines, rule) {
        Allow::Yes => {}
        Allow::MissingReason(k) => out.push(Finding {
            file: file.to_string(),
            line: k + 1,
            rule,
            message: format!("lint:allow-file({rule}) without a reason"),
            fix: format!("spell it `// lint:allow-file({rule}): <why this file is exempt>`"),
        }),
        Allow::No => out.push(Finding {
            file: file.to_string(),
            line: idx + 1,
            rule,
            message,
            fix,
        }),
    }
}

fn panic_scoped(rel: &str) -> bool {
    rel == "model/plan.rs"
        || ["coordinator/", "sparse/", "events/", "examples/", "benches/"]
            .iter()
            .any(|d| rel.starts_with(d))
}

/// `examples/` and `benches/` are binaries, exempt (like `main.rs`)
/// from the library-shape rules: print and module-size.
fn is_binary_tree(rel: &str) -> bool {
    rel == "main.rs" || rel.starts_with("examples/") || rel.starts_with("benches/")
}

fn rule_panic(f: &SourceFile, s: &scan::Scanned, out: &mut Vec<Finding>) {
    if !panic_scoped(&f.rel_path) {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for tok in PANIC_TOKENS {
            for at in token_positions(&line.code, tok) {
                if tok == ".unwrap()" && lock_idiom(&s.lines, i, at + tok.len()) {
                    continue;
                }
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "panic",
                    format!("`{tok}` on the serving path can panic"),
                    "handle the error, or annotate `// lint:allow(panic): <why>`".to_string(),
                );
            }
        }
    }
}

/// Is the `.unwrap()` ending at byte `end` of line `i` the tail of a
/// `.lock().unwrap()` chain? Checked whitespace-free across up to two
/// preceding lines so rustfmt-split chains still match.
fn lock_idiom(lines: &[ScannedLine], i: usize, end: usize) -> bool {
    let mut ctx = String::new();
    for line in &lines[i.saturating_sub(2)..i] {
        ctx.push_str(&line.code);
    }
    ctx.push_str(&lines[i].code[..end]);
    ctx.retain(|c| !c.is_whitespace());
    ctx.ends_with(".lock().unwrap()")
}

fn rule_hot_alloc(f: &SourceFile, s: &scan::Scanned, out: &mut Vec<Finding>) {
    let mut open: Option<usize> = None;
    for (i, line) in s.lines.iter().enumerate() {
        if let Some(rest) = line.comment.trim().strip_prefix("lint: hot-path") {
            if rest.trim_start().starts_with("end") {
                if open.take().is_none() {
                    out.push(Finding {
                        file: f.rel_path.clone(),
                        line: i + 1,
                        rule: "hot-alloc",
                        message: "`lint: hot-path end` without an open region".to_string(),
                        fix: "open the region with `// lint: hot-path`".to_string(),
                    });
                }
            } else if open.is_some() {
                out.push(Finding {
                    file: f.rel_path.clone(),
                    line: i + 1,
                    rule: "hot-alloc",
                    message: "nested `lint: hot-path` marker in an open region".to_string(),
                    fix: "close the previous region with `// lint: hot-path end`".to_string(),
                });
            } else {
                open = Some(i);
            }
            continue;
        }
        if open.is_none() || line.in_test {
            continue;
        }
        for tok in ALLOC_TOKENS {
            for _ in token_positions(&line.code, tok) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "hot-alloc",
                    format!("`{tok}` allocates inside a hot-path region"),
                    "reuse arena scratch, or allocate at compile/setup time".to_string(),
                );
            }
        }
    }
    if let Some(i) = open {
        out.push(Finding {
            file: f.rel_path.clone(),
            line: i + 1,
            rule: "hot-alloc",
            message: "hot-path region opened here is never closed".to_string(),
            fix: "add `// lint: hot-path end` after the kernel".to_string(),
        });
    }
}

fn rule_cast(f: &SourceFile, s: &scan::Scanned, out: &mut Vec<Finding>) {
    if !CAST_FILES.contains(&f.rel_path.as_str()) {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for at in token_positions(&line.code, " as ") {
            let after = line.code[at + 4..].trim_start();
            let ident: String = after.chars().take_while(|&c| is_ident(c)).collect();
            if NARROW_CASTS.contains(&ident.as_str()) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "cast",
                    format!("bare `as {ident}` on the wire path truncates silently"),
                    format!("use `{ident}::try_from(..)` or a checked helper"),
                );
            }
        }
    }
}

fn rule_print(f: &SourceFile, s: &scan::Scanned, out: &mut Vec<Finding>) {
    if is_binary_tree(&f.rel_path) || f.rel_path.starts_with("report/") {
        return;
    }
    for (i, line) in s.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for tok in ["println!", "eprintln!"] {
            for _ in token_positions(&line.code, tok) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    i,
                    "print",
                    format!("`{tok}` in a library module"),
                    "return data and let main.rs / report render it".to_string(),
                );
            }
        }
    }
}

/// One module over the size cap is one refactor away from the next
/// `serve.rs`. Counted lines are non-test lines holding actual code —
/// docs, comments, and `#[cfg(test)]` items never push a module over.
/// `main.rs` is the binary, not a library module, and is exempt (like
/// the print rule).
fn rule_module_size(f: &SourceFile, s: &scan::Scanned, out: &mut Vec<Finding>) {
    if is_binary_tree(&f.rel_path) {
        return;
    }
    let code_lines = s.lines.iter().filter(|l| !l.in_test && !l.code.trim().is_empty()).count();
    if code_lines > MODULE_SIZE_CAP {
        emit(
            out,
            &f.rel_path,
            &s.lines,
            0,
            "module-size",
            format!("module holds {code_lines} non-test code lines (cap {MODULE_SIZE_CAP})"),
            "split it into a module directory of focused stages (see coordinator/serve/)"
                .to_string(),
        );
    }
}

fn rule_drift_metrics(scanned: &[(&SourceFile, scan::Scanned)], out: &mut Vec<Finding>) {
    let metrics = scanned.iter().find(|(f, _)| f.rel_path == "coordinator/metrics.rs");
    let Some((mf, ms)) = metrics else {
        return;
    };
    let mut hay = String::new();
    for (f, s) in scanned {
        if !f.rel_path.starts_with("report/") {
            continue;
        }
        for l in &s.lines {
            if !l.in_test {
                hay.push_str(&l.code);
                hay.push('\n');
            }
        }
    }
    if hay.is_empty() {
        return;
    }
    for strukt in METRIC_STRUCTS {
        for (idx, field) in counter_fields(&ms.lines, strukt) {
            if !word_in(&hay, &field) {
                emit(
                    out,
                    &mf.rel_path,
                    &ms.lines,
                    idx,
                    "drift-metrics",
                    format!("counter `{strukt}.{field}` is never referenced in report/"),
                    "render it in report/, or annotate why it is internal-only".to_string(),
                );
            }
        }
    }
}

/// `usize` fields of `pub struct <strukt>`: (0-based line, name).
fn counter_fields(lines: &[ScannedLine], strukt: &str) -> Vec<(usize, String)> {
    let pat = format!("pub struct {strukt}");
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let code = &lines[i].code;
        if let Some(p) = code.find(&pat) {
            let next = code[p + pat.len()..].chars().next();
            if !next.is_some_and(is_ident) {
                break;
            }
        }
        i += 1;
    }
    let mut depth = 0i64;
    let mut entered = false;
    while i < lines.len() {
        let code = &lines[i].code;
        if entered && depth == 1 {
            let t = code.trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some(c) = rest.find(':') {
                    let name = rest[..c].trim();
                    let ty = rest[c + 1..].trim().trim_end_matches(',');
                    if ty == "usize" && !name.is_empty() && name.chars().all(is_ident) {
                        out.push((i, name.to_string()));
                    }
                }
            }
        }
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    entered = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if entered && depth == 0 {
            break;
        }
        i += 1;
    }
    out
}

fn rule_drift_flags(
    scanned: &[(&SourceFile, scan::Scanned)],
    readme: Option<&str>,
    out: &mut Vec<Finding>,
) {
    let Some(readme) = readme else {
        return;
    };
    for (f, s) in scanned {
        if !FLAG_FILES.contains(&f.rel_path.as_str()) {
            continue;
        }
        for lit in &s.strings {
            let idx = lit.line - 1;
            let in_test = match s.lines.get(idx) {
                Some(l) => l.in_test,
                None => true,
            };
            if in_test {
                continue;
            }
            let p = lit.prefix.trim_end();
            if !FLAG_ACCESSORS.iter().any(|a| p.ends_with(a)) {
                continue;
            }
            let flag = format!("--{}", lit.value);
            if !readme.contains(&flag) {
                emit(
                    out,
                    &f.rel_path,
                    &s.lines,
                    idx,
                    "drift-flags",
                    format!("flag `{flag}` is parsed here but undocumented in README.md"),
                    format!("document `{flag}` in README.md (or drop the dead flag)"),
                );
            }
        }
    }
}
