//! L3 serving coordinator: the sharded event-vision serving runtime that
//! composes the substrates into a deployable system —
//!
//! ```text
//!                                      ┌ accel worker 0 ┐
//! event source → representation → ingress queue    …     → classifications
//!   (camera/        builder       (admission ─ accel worker N ─ + metrics
//!    synthetic)    (histogram2)    control)
//! ```
//!
//! Stages run on std threads connected by bounded queues (backpressure),
//! since the offline build vendors no async runtime. The event source is
//! any [`ingest::EventSource`] — the synthetic camera, a paced dataset
//! replay, a tailed capture file, or a UDP/TCP socket speaking the
//! [`net`] event-packet format — stamping real arrival times that
//! latency (and any `--slo-ms` deadline) is measured from. The
//! accelerator stage
//! is a pool of replicas — homogeneous (N workers sharing one [`Backend`]
//! trait object) or heterogeneous (a [`ReplicaPool`] of per-replica
//! instances across classes, with a cost-aware router picking a class per
//! request). The ingress queue applies admission control (block vs
//! drop-oldest), deadlines are enforced at the ingress, the router, and
//! the worker pop (see [`serve`]), and the merged [`metrics::Metrics`]
//! report per-worker and
//! per-class utilization, p50/p95/p99 latency percentiles, and SLO
//! attainment.
//!
//! [`run_pipeline`] is the single-accelerator batch-1 facade (the paper's
//! deployment); [`run_server`] is the replicated homogeneous runtime;
//! [`run_pool`] is the heterogeneous cost-aware runtime.
pub mod backend;
pub mod ingest;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod queue;
pub mod serve;

pub use backend::{
    Backend, BackendError, Classification, DeltaStatus, DeltaStore, Dense, Functional,
    PoolClass, ReplicaPool, ReplicaSpec, Shared, Simulator, Swappable, DEFAULT_MODEL,
};
pub use ingest::{
    EventSource, IngestError, MixSource, ReplaySource, SourcedRequest, SyntheticSource,
    TailSource, UnsortedPolicy, DEFAULT_TENANT,
};
pub use metrics::{
    ClassStats, CostModel, CostProfile, CostSnapshot, DeltaMetrics, Metrics, ModelStats,
    PercentileReport, RequestTiming, ScalingEvent, SlidingWindow, TenantStats, WorkerStats,
};
pub use net::{decode_packet, encode_packet, NetConfig, NetSource, Packet};
pub use pipeline::{run_pipeline, PipelineConfig, PipelineResult};
pub use queue::{AdmissionQueue, DropPolicy, TryPushError};
pub use serve::{
    run_pool, run_pool_source, run_server, run_server_source, synthetic_source, AutoscaleConfig,
    PipelineError, Prediction, ServerConfig, ServerResult, ShadowCaptureConfig, ShadowConfig,
    TenantConfig,
};

/// Shared unit-test fixtures (integration tests under `rust/tests/` keep
/// their own copies — crate-private test code is invisible to them).
#[cfg(test)]
pub(crate) mod testutil {
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::quant::{quantize_network, QuantizedNet};
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;
    use crate::sparse::SparseMap;
    use crate::util::Rng;

    /// A tiny calibrated int8 network for `profile`.
    pub fn qnet_for(profile: &DatasetProfile) -> QuantizedNet {
        let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
        let w = FloatWeights::random(&spec, 3);
        let mut rng = Rng::new(9);
        let calib: Vec<SparseMap<f32>> = (0..2)
            .map(|i| {
                let es = profile.sample(i % profile.n_classes, &mut rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            })
            .collect();
        quantize_network(&spec, &w, &calib)
    }
}
