//! Shared runtime state the serving stages communicate through: the
//! in-flight request shape ([`Routed`]), the per-class / per-tenant /
//! per-model books, sticky-routing state, the shadow-capture writer,
//! and the small helpers the spine and stages both need.
//!
//! Everything here is `pub(super)`: the stage modules ([`super::ingress`],
//! [`super::router`], [`super::workers`], [`super::scaler`],
//! [`super::lifecycle`]) are the only consumers — the public surface
//! lives in the parent module.

use crate::coordinator::backend::{Backend, PoolClass};
use crate::coordinator::lock_ranks;
use crate::coordinator::metrics::{CostModel, DeltaMetrics, RequestTiming};
use crate::coordinator::queue::{AdmissionQueue, TryPushError};
use crate::events::{io, Event};
use crate::sparse::SparseMap;
use crate::util::lockcheck::RankedMutex;
use std::collections::HashMap;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// An admitted request: built by the repr stage, (optionally) routed, then
/// served from a queue. With a single replica class there is no router and
/// workers drain the ingress directly; with several, the router fills in
/// `predicted_s` and moves it to a class sub-queue.
pub(super) struct Routed {
    pub(super) label: usize,
    /// Index into the run's tenant table (0 for single-tenant runs).
    pub(super) tenant: usize,
    /// Index into the run's model table (0 for single-model runs): the
    /// router only offers this request to classes serving its model.
    pub(super) model: usize,
    pub(super) map: SparseMap<f32>,
    /// Raw events retained for the shadow disagreement capture — `Some`
    /// only for models whose shadow can land them in the capture file;
    /// everything else drops them once the representation is built.
    pub(super) events: Option<Vec<Event>>,
    /// When the request was born at its source — end-to-end latency and
    /// the deadline are measured from here.
    pub(super) arrival: Instant,
    /// `arrival + slo` when an SLO is configured; a request past this is
    /// worthless and every stage may discard it.
    pub(super) deadline: Option<Instant>,
    /// Event-count bucket ([`CostModel::bucket_of`]), computed once at
    /// admission.
    pub(super) bucket: usize,
    /// Service seconds the router predicted for this request (NaN when no
    /// router ran or the class was unseeded at routing time).
    pub(super) predicted_s: f64,
    /// Per-stream identity for delta inference (see
    /// [`crate::coordinator::ingest::SourcedRequest::stream`]); `None` =
    /// no stream.
    pub(super) stream: Option<u64>,
    /// True when the router delivered this request over the sticky fast
    /// path: `predicted_s` stays NaN by design, so the per-class rollup
    /// must not count it as an unseeded probe.
    pub(super) sticky: bool,
}

impl Routed {
    pub(super) fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|dl| now >= dl)
    }
}

/// A worker's handle on its backend: borrowed from the caller (the
/// homogeneous path shares one `&dyn Backend` across replicas) or shared
/// ownership of a pool replica (`Arc`, so the autoscaler can hand clones
/// to worker threads it spawns mid-run).
#[derive(Clone)]
pub(super) enum BackendRef<'a> {
    Borrowed(&'a dyn Backend),
    Shared(Arc<dyn Backend>),
}

impl<'a> BackendRef<'a> {
    pub(super) fn get(&self) -> &dyn Backend {
        match self {
            BackendRef::Borrowed(b) => *b,
            BackendRef::Shared(a) => a.as_ref(),
        }
    }
}

/// One replica class's scheduling inputs: display name, model tag, batch
/// affinity, one backend per base worker replica, and (for scalable pool
/// classes) the growth bound plus factory access.
pub(super) struct ClassSlots<'a> {
    pub(super) name: String,
    /// Model this class serves (`ReplicaSpec::for_model`); single-model
    /// paths all carry the default tag.
    pub(super) model: String,
    pub(super) batch: usize,
    pub(super) backends: Vec<BackendRef<'a>>,
    /// Upper replica bound (== `backends.len()` when not scalable).
    pub(super) max: usize,
    /// Factory access for on-demand replicas past the base count (pool
    /// classes only; the homogeneous path cannot grow).
    pub(super) grow: Option<&'a PoolClass>,
}

/// A replica class's live runtime state.
pub(super) struct ClassCtx<'a> {
    pub(super) name: String,
    /// Index into the run's model table — the router's model filter.
    pub(super) model: usize,
    pub(super) batch: usize,
    /// Instantiated replica backends, indexed by slot. Grows monotonically
    /// (scale-up instantiates lazily, scale-down keeps the warm backend
    /// for re-activation); only slots `< active` serve.
    // lint: lock-rank(40): class-slots
    pub(super) slots: RankedMutex<Vec<BackendRef<'a>>>,
    /// Active replica count — the scheduling truth the router divides
    /// backlogs by and workers compare their slot index against. Always
    /// within `[min, max]`.
    // lint: atomic(seqcst): scheduling truth; scaler, router, and workers
    // must agree on the count at every step boundary
    pub(super) active: AtomicUsize,
    /// Highest `active` value seen (for the report).
    // lint: atomic(relaxed): report-only high-water mark
    pub(super) peak: AtomicUsize,
    /// Lower replica bound: the controller never takes `active` below it,
    /// and retire tokens are only minted on scale-down, so the class
    /// always keeps at least `min` serving workers.
    pub(super) min: usize,
    /// Upper replica bound the autoscaler may grow to.
    pub(super) max: usize,
    /// Factory access for slots past the eagerly-built base replicas.
    pub(super) grow: Option<&'a PoolClass>,
    /// Pending retire tokens: each scale-down step deposits one, and
    /// exactly one worker of the class claims it and exits after draining
    /// its in-flight batch. Token-based (rather than slot-indexed)
    /// retirement makes re-growth race-free: there is never a moment
    /// where a re-activated slot is served twice.
    // lint: atomic(seqcst): CAS-claimed token protocol (`take_retire_token`)
    pub(super) retire: AtomicUsize,
    /// Per-class sub-queue (always blocking — drops are global-only).
    pub(super) queue: AdmissionQueue<Routed>,
    /// Requests routed here and not yet classified (queued + in service).
    // lint: atomic(seqcst): conservation counter — router feasibility and
    // drain decisions must see pop decrements in order
    pub(super) backlog: AtomicUsize,
    /// Observed-service-time predictor the router consults.
    pub(super) cost: CostModel,
    /// Deadline sheds attributed to this class: router-predicted
    /// infeasibility plus pop-time expiries.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_drops: AtomicUsize,
    /// Cumulative accelerator-busy microseconds across the class's
    /// replicas, updated per visit — the autoscaler's windowed
    /// utilization input.
    // lint: atomic(relaxed): sampling input; the scaler tolerates lag
    pub(super) busy_us: AtomicU64,
}

/// One classified request as a worker recorded it.
pub(super) struct ServedRecord {
    pub(super) label: usize,
    pub(super) tenant: usize,
    pub(super) model: usize,
    pub(super) pred: usize,
    pub(super) timing: RequestTiming,
    pub(super) predicted_s: f64,
    /// Whether the request completed within its deadline (`None`: no
    /// deadline was set).
    pub(super) met_deadline: Option<bool>,
    /// Delivered via the sticky fast path (excluded from the unseeded
    /// probe count — its NaN prediction is by design, not ignorance).
    pub(super) sticky: bool,
}

/// Per-request metadata a worker holds across the backend visit.
pub(super) struct Meta {
    pub(super) label: usize,
    pub(super) tenant: usize,
    pub(super) model: usize,
    pub(super) arrival: Instant,
    pub(super) bucket: usize,
    pub(super) predicted_s: f64,
    pub(super) deadline: Option<Instant>,
    pub(super) sticky: bool,
}

/// Sticky (cache-affinity) routing state — present only when a router
/// runs AND some class backend supports delta inference. `table`
/// remembers which worker holds each stream's delta cache warm; `sides`
/// holds one bounded side queue per delta-capable worker. Stickiness is a
/// pure performance hint: every miss (cold stream, retired worker, full
/// side queue) falls back to cost-aware routing, and replicas of a class
/// share one delta store, so a request that lands elsewhere is still
/// served correctly — it just pays cache traffic it could have avoided.
pub(super) struct StickyCtx {
    /// stream id → worker that served the stream last.
    // lint: lock-rank(30): sticky-table
    pub(super) table: RankedMutex<HashMap<u64, usize>>,
    /// Live sticky targets: `(worker id, class index, side queue)`. A
    /// retiring worker deregisters itself before draining its remainder.
    // lint: lock-rank(31): sticky-sides
    pub(super) sides: RankedMutex<Vec<(usize, usize, Arc<AdmissionQueue<Routed>>)>>,
    // lint: atomic(relaxed): hit/miss tallies, read after the scope joins
    pub(super) hits: AtomicUsize,
    // lint: atomic(relaxed): hit/miss tallies, read after the scope joins
    pub(super) miss_cold: AtomicUsize,
    // lint: atomic(relaxed): hit/miss tallies, read after the scope joins
    pub(super) miss_retired: AtomicUsize,
    // lint: atomic(relaxed): hit/miss tallies, read after the scope joins
    pub(super) miss_capacity: AtomicUsize,
}

impl StickyCtx {
    pub(super) fn new() -> StickyCtx {
        StickyCtx {
            table: RankedMutex::new(lock_ranks::STICKY_TABLE, "sticky-table", HashMap::new()),
            sides: RankedMutex::new(lock_ranks::STICKY_SIDES, "sticky-sides", Vec::new()),
            hits: AtomicUsize::new(0),
            miss_cold: AtomicUsize::new(0),
            miss_retired: AtomicUsize::new(0),
            miss_capacity: AtomicUsize::new(0),
        }
    }

    /// Advertise worker `wid` (serving class `ci`) as a sticky target.
    pub(super) fn enroll(&self, wid: usize, ci: usize, side: &Arc<AdmissionQueue<Routed>>) {
        self.sides.lock().unwrap().push((wid, ci, Arc::clone(side)));
    }

    /// Remember where a stream's delta cache now lives.
    pub(super) fn remember(&self, stream: u64, wid: usize) {
        self.table.lock().unwrap().insert(stream, wid);
    }

    /// Withdraw a retiring worker from the target list. The worker closes
    /// its side queue *after* this call, so a concurrently in-flight
    /// sticky push bounces back ([`TryPushError::Closed`]) to the router,
    /// which cost-routes the request to a live worker instead.
    pub(super) fn deregister(&self, wid: usize) {
        self.sides.lock().unwrap().retain(|(w, _, _)| *w != wid);
    }

    /// Try to deliver `req` to the worker holding its stream's cache.
    /// `None`: delivered, books updated. `Some`: handed back for
    /// cost-aware routing, with the miss reason counted.
    pub(super) fn try_route(&self, mut req: Routed, classes: &[ClassCtx<'_>]) -> Option<Routed> {
        let Some(stream) = req.stream else {
            return Some(req);
        };
        let Some(wid) = self.table.lock().unwrap().get(&stream).copied() else {
            self.miss_cold.fetch_add(1, Ordering::Relaxed);
            return Some(req);
        };
        let entry = self
            .sides
            .lock()
            .unwrap()
            .iter()
            .find(|(w, _, _)| *w == wid)
            .map(|(_, ci, q)| (*ci, Arc::clone(q)));
        let Some((ci, side)) = entry else {
            // The worker retired since it last served this stream.
            self.table.lock().unwrap().remove(&stream);
            self.miss_retired.fetch_add(1, Ordering::Relaxed);
            return Some(req);
        };
        if classes[ci].model != req.model {
            // A mixed-traffic stream hopped models: its cached window
            // lives behind another model's backend, useless here — and
            // the model filter is correctness, not a hint.
            self.miss_cold.fetch_add(1, Ordering::Relaxed);
            return Some(req);
        }
        // A sticky delivery is not a cost-model prediction: NaN keeps it
        // out of the router-accuracy books, and the `sticky` flag keeps
        // it out of the unseeded-probe count.
        req.sticky = true;
        req.predicted_s = f64::NAN;
        // Backlog up *before* the push: the worker's pop decrements, and
        // the counter must never dip below zero in between.
        classes[ci].backlog.fetch_add(1, Ordering::SeqCst);
        match side.try_push(req) {
            Ok(()) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // The target may be parked on an empty class queue —
                // unpark it so its cancellation predicate sees side work.
                classes[ci].queue.wake_consumers();
                None
            }
            Err(e) => {
                classes[ci].backlog.fetch_sub(1, Ordering::SeqCst);
                let mut r = match e {
                    // Bounded stickiness: a hot worker must not build an
                    // unbounded private backlog while siblings idle.
                    TryPushError::Full(r) => {
                        self.miss_capacity.fetch_add(1, Ordering::Relaxed);
                        r
                    }
                    TryPushError::Closed(r) => {
                        self.table.lock().unwrap().remove(&stream);
                        self.miss_retired.fetch_add(1, Ordering::Relaxed);
                        r
                    }
                };
                r.sticky = false;
                Some(r)
            }
        }
    }
}

/// One tenant's live admission state and books. The `in_queue` occupancy
/// tracks this tenant's requests sitting in the *ingress* queue only —
/// the quota is an admission concept; once the router moves a request to
/// a class sub-queue it has been admitted and scheduled. All counters are
/// written from the stage threads and read after the scope joins.
pub(super) struct TenantCtx {
    pub(super) name: String,
    pub(super) weight: usize,
    /// Ingress slots this tenant may occupy (weighted share of the queue
    /// depth; the full depth when the run has a single tenant).
    pub(super) quota: usize,
    /// Per-tenant SLO overriding the global one.
    pub(super) slo: Option<Duration>,
    /// This tenant's requests currently in the ingress queue (maintained
    /// only in multi-tenant runs — the single-tenant path never reads it).
    // lint: atomic(seqcst): conservation counter — quota admission must see
    // router decrements in order or occupancy drifts negative
    pub(super) in_queue: AtomicUsize,
    /// Admission sheds: drop-oldest evictions + over-quota arrivals.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) dropped: AtomicUsize,
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_offered: AtomicUsize,
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_ingress: AtomicUsize,
    /// Router sheds + worker-pop expiries.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_router: AtomicUsize,
    /// Recoverable source rejects attributed to this tenant.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) ingest_rejects: AtomicUsize,
}

impl TenantCtx {
    pub(super) fn new(
        name: String,
        weight: usize,
        slo: Option<Duration>,
        quota: usize,
    ) -> TenantCtx {
        TenantCtx {
            name,
            weight,
            quota,
            slo,
            in_queue: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
            deadline_offered: AtomicUsize::new(0),
            deadline_ingress: AtomicUsize::new(0),
            deadline_router: AtomicUsize::new(0),
            ingest_rejects: AtomicUsize::new(0),
        }
    }
}

/// One fleet model's live books, mirroring [`TenantCtx`]'s structure:
/// drop counters written at the same stage points (keyed by the request's
/// model instead of its tenant), plus the model's optional shadow state.
/// `served`/`correct` are tallied from the worker records at
/// finalization, so the struct holds only what the stages must write
/// concurrently.
pub(super) struct ModelCtx {
    pub(super) name: String,
    /// Admission sheds: drop-oldest evictions + over-quota arrivals.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) dropped: AtomicUsize,
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_offered: AtomicUsize,
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_ingress: AtomicUsize,
    /// Router sheds + worker-pop expiries.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_router: AtomicUsize,
    /// Shadow deployment mirrored onto this model, when configured.
    pub(super) shadow: Option<ShadowCtx>,
}

impl ModelCtx {
    pub(super) fn new(name: String, shadow: Option<ShadowCtx>) -> ModelCtx {
        ModelCtx {
            name,
            dropped: AtomicUsize::new(0),
            deadline_offered: AtomicUsize::new(0),
            deadline_ingress: AtomicUsize::new(0),
            deadline_router: AtomicUsize::new(0),
            shadow,
        }
    }
}

/// One model's live shadow-deployment state: the candidate backend, the
/// deterministic mirror schedule, and the conformance books. The
/// `counter`-based selection (`floor((k+1)·f) > floor(k·f)`) mirrors
/// exactly `fraction` of the model's served stream with no RNG and no
/// burst bias — every run over the same stream mirrors the same
/// requests.
pub(super) struct ShadowCtx {
    pub(super) candidate: Arc<dyn Backend>,
    pub(super) fraction: f64,
    /// Served requests seen so far (the mirror schedule's clock).
    // lint: atomic(relaxed): fetch_add schedule clock — per-tick atomicity
    // is what matters, not cross-thread order
    pub(super) counter: AtomicUsize,
    // lint: atomic(relaxed): conformance tally, read after the scope joins
    pub(super) mirrored: AtomicUsize,
    // lint: atomic(relaxed): conformance tally, read after the scope joins
    pub(super) disagreements: AtomicUsize,
    /// Disagreeing samples that could not land in the capture (cap
    /// reached, write error, or raw events no longer available).
    // lint: atomic(relaxed): conformance tally, read after the scope joins
    pub(super) capture_drops: AtomicUsize,
    /// The capture writer, shared across every shadowed model (one
    /// `--shadow-capture` path per run); `None` when capture is off.
    // lint: lock-rank(60): shadow-capture
    pub(super) capture: Option<Arc<RankedMutex<Option<ShadowWriter>>>>,
}

/// Appends shadow-disagreement samples to a replayable `.esda` capture.
/// The header is written with a zero sample count at creation and
/// rewritten with the real count at [`ShadowWriter::finalize`] — the
/// same producer discipline a camera-dump pipeline uses, so the capture
/// replays through `--source replay:` like any dataset.
pub(super) struct ShadowWriter {
    file: std::fs::File,
    written: usize,
    max: usize,
}

impl ShadowWriter {
    pub(super) fn create(
        path: &Path,
        w: usize,
        h: usize,
        max: usize,
    ) -> std::io::Result<ShadowWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut file = std::fs::File::create(path)?;
        io::write_header(&mut file, w, h, 0)?;
        Ok(ShadowWriter { file, written: 0, max })
    }

    /// Append one disagreeing sample. `false` = not written (cap reached
    /// or IO error) — the caller counts it as a capture drop.
    pub(super) fn append(&mut self, label: u32, events: Vec<Event>) -> bool {
        if self.written >= self.max {
            return false;
        }
        let sample = io::Sample { label, events };
        match io::append_sample(&mut self.file, &sample) {
            Ok(()) => {
                self.written += 1;
                true
            }
            Err(_) => false,
        }
    }

    /// Samples appended so far.
    pub(super) fn written(&self) -> usize {
        self.written
    }

    /// Rewrite the header's sample count with what was actually appended
    /// and flush, making the capture a well-formed dataset.
    pub(super) fn finalize(mut self) -> std::io::Result<usize> {
        self.file.flush()?;
        // The count is the header's last field: magic + version + w + h
        // precede it (see `events::io`).
        self.file.seek(SeekFrom::Start(io::FILE_HEADER_BYTES - 4))?;
        let n = u32::try_from(self.written).unwrap_or(u32::MAX);
        self.file.write_all(&n.to_le_bytes())?;
        self.file.flush()?;
        Ok(self.written)
    }
}

/// Run-global admission-side counters — everything the source and repr
/// stages write outside the ingress queue's own books.
pub(super) struct IngressBooks {
    /// Requests that arrived with a deadline.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_offered: AtomicUsize,
    /// Already-expired arrivals dropped before their repr was built.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) deadline_ingress: AtomicUsize,
    /// Over-quota tenant arrivals shed before admission.
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) quota_drops: AtomicUsize,
    /// Recoverable source rejects (the stream skipped past them).
    // lint: atomic(relaxed): shed tally, read after the scope joins
    pub(super) ingest_rejects: AtomicUsize,
}

impl IngressBooks {
    pub(super) fn new() -> IngressBooks {
        IngressBooks {
            deadline_offered: AtomicUsize::new(0),
            deadline_ingress: AtomicUsize::new(0),
            quota_drops: AtomicUsize::new(0),
            ingest_rejects: AtomicUsize::new(0),
        }
    }
}

/// Borrows of the run-wide state every stage thread needs, bundled so
/// the stage functions keep readable signatures. Built once by the
/// lifecycle spine before the thread scope opens; `'env` is the spine's
/// stack frame, `'a` the caller's backend borrows.
pub(super) struct SharedCtx<'env, 'a> {
    pub(super) classes: &'env [ClassCtx<'a>],
    pub(super) tenants: &'env [TenantCtx],
    pub(super) models: &'env [ModelCtx],
    pub(super) ingress: &'env AdmissionQueue<Routed>,
    pub(super) sticky: Option<&'env StickyCtx>,
    // lint: lock-rank(10): first-error
    pub(super) first_error: &'env RankedMutex<Option<String>>,
}

/// Claim one pending retire token (false when none are pending). CAS
/// loop so concurrent claimers never double-spend a token — each
/// scale-down step retires exactly one worker.
// lint: atomic(seqcst): CAS-claimed token protocol (`ClassCtx::retire`)
pub(super) fn take_retire_token(retire: &AtomicUsize) -> bool {
    let mut t = retire.load(Ordering::SeqCst);
    while t > 0 {
        match retire.compare_exchange(t, t - 1, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(_) => return true,
            Err(cur) => t = cur,
        }
    }
    false
}

/// Per-worker raw output collected at join time.
pub(super) struct WorkerOutput {
    pub(super) wid: usize,
    pub(super) class: usize,
    pub(super) busy_s: f64,
    pub(super) records: Vec<ServedRecord>,
    pub(super) batch_sizes: Vec<usize>,
    /// Delta-inference outcome tallies for requests this worker served.
    pub(super) delta: DeltaMetrics,
}

/// Join one pipeline thread, funneling a panic into the run's
/// first-error slot instead of tearing down the coordinator mid-shutdown.
/// The remaining stages still get joined and their outputs collected.
pub(super) fn join_noting<T>(
    r: std::thread::Result<T>,
    what: &str,
    // lint: lock-rank(10): first-error
    first_error: &RankedMutex<Option<String>>,
) {
    if r.is_err() {
        let msg = format!("{what} thread panicked");
        first_error.lock().unwrap_or_else(|e| e.into_inner()).get_or_insert_with(|| msg);
    }
}
