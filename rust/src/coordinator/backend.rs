//! Classification backends behind a shared object-safe trait.
//!
//! The serving runtime replicates accelerators across worker threads, so a
//! backend must be usable from many threads at once: `Backend: Send + Sync`
//! and `classify` takes `&self`. The three implementations mirror the
//! paper's platforms:
//!
//! - [`Simulator`] — the cycle-level ESDA dataflow simulator (batch-1, the
//!   paper's FPGA deployment; also reports hardware cycles),
//! - [`Functional`] — the int8 functional reference (fast, no cycle model),
//! - [`Dense`] — the PJRT dense engine (the GPU-platform stand-in; real
//!   only with the `pjrt` feature).

use crate::arch::{simulate_inference, HwConfig};
use crate::model::exec::{argmax, classify_i8};
use crate::model::quant::QuantizedNet;
use crate::sparse::SparseMap;
use std::fmt;

/// Default simulator cycle budget per inference (generous: deadlock and
/// runaway detection live inside the simulator itself).
pub const DEFAULT_CYCLE_BUDGET: u64 = 10_000_000_000;

/// One classification outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Classification {
    /// Predicted class index.
    pub pred: usize,
    /// Simulated hardware cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Backend failure (simulator deadlock/timeout, PJRT error, …).
#[derive(Debug, Clone)]
pub struct BackendError(pub String);

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for BackendError {}

/// A classification backend that worker replicas can share.
///
/// Implementations must be stateless across calls (or internally
/// synchronized): the pool calls `classify` concurrently from N threads.
pub trait Backend: Send + Sync {
    /// Short display name for reports.
    fn name(&self) -> &str;

    /// Classify one sparse input map.
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError>;
}

/// Functional int8 reference (fast; no cycle model).
pub struct Functional {
    pub qnet: QuantizedNet,
}

impl Functional {
    pub fn new(qnet: QuantizedNet) -> Functional {
        Functional { qnet }
    }
}

impl Backend for Functional {
    fn name(&self) -> &str {
        "functional-int8"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        Ok(Classification { pred: classify_i8(&self.qnet, map), sim_cycles: None })
    }
}

/// Cycle-level ESDA simulator (reports hardware cycles too).
pub struct Simulator {
    pub qnet: QuantizedNet,
    pub cfg: HwConfig,
    pub cycle_budget: u64,
}

impl Simulator {
    pub fn new(qnet: QuantizedNet, cfg: HwConfig) -> Simulator {
        Simulator { qnet, cfg, cycle_budget: DEFAULT_CYCLE_BUDGET }
    }
}

impl Backend for Simulator {
    fn name(&self) -> &str {
        "cycle-simulator"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        let (logits, report) = simulate_inference(&self.qnet, &self.cfg, map, self.cycle_budget)
            .map_err(|e| BackendError(format!("simulation: {e}")))?;
        Ok(Classification { pred: argmax(&logits), sim_cycles: Some(report.cycles) })
    }
}

/// PJRT dense engine (AOT artifact). The engine handle is `Send` but not
/// `Sync`, so one shared instance serializes inferences behind a mutex —
/// worker replicas queue on it. A truly parallel dense pool needs one
/// engine per replica (future work: per-worker backend factories).
pub struct Dense {
    pub engine: std::sync::Mutex<crate::runtime::Engine>,
}

impl Dense {
    pub fn new(engine: crate::runtime::Engine) -> Dense {
        Dense { engine: std::sync::Mutex::new(engine) }
    }
}

impl Backend for Dense {
    fn name(&self) -> &str {
        "pjrt-dense"
    }

    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        // A previous panic while holding the lock cannot corrupt the
        // engine (inference takes `&self`), so poisoning is ignorable.
        let engine = self.engine.lock().unwrap_or_else(|p| p.into_inner());
        let logits = engine
            .infer_sparse(map)
            .map_err(|e| BackendError(format!("dense inference: {e}")))?;
        Ok(Classification { pred: argmax(&logits), sim_cycles: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::qnet_for;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::util::Rng;

    /// Simulator and functional backends must classify identically.
    #[test]
    fn backends_agree_on_predictions() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let func = Functional::new(qnet.clone());
        let sim = Simulator::new(qnet, HwConfig::uniform(n_ops, 8));
        let mut rng = Rng::new(77);
        for i in 0..3 {
            let es = profile.sample(i, &mut rng);
            let map = histogram2_norm(&es, profile.w, profile.h, 8.0);
            let f = func.classify(&map).unwrap();
            let s = sim.classify(&map).unwrap();
            assert_eq!(f.pred, s.pred);
            assert!(f.sim_cycles.is_none());
            assert!(s.sim_cycles.unwrap() > 0);
        }
    }

    /// Backends are shareable across threads (the pool's core contract).
    #[test]
    fn backend_trait_objects_are_sync() {
        fn assert_sync<T: Sync + ?Sized>() {}
        assert_sync::<dyn Backend>();
        assert_sync::<Functional>();
        assert_sync::<Simulator>();
        assert_sync::<Dense>();
    }

    /// A stub Dense backend surfaces engine errors instead of panicking.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn dense_stub_errors_cleanly() {
        let eng = crate::runtime::Engine { h: 4, w: 4, c: 2, n_classes: 3 };
        let dense = Dense::new(eng);
        let map = SparseMap::empty(4, 4, 2);
        let e = dense.classify(&map).unwrap_err();
        assert!(e.to_string().contains("pjrt"));
    }
}
