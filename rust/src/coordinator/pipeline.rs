//! The single-accelerator pipeline — the paper's batch-1 deployment, kept
//! as a thin compatibility facade over the sharded serving runtime
//! ([`super::serve::run_server`]) with one worker replica and lossless
//! (blocking) admission.
//!
//! Compared to the original fixed three-stage implementation this path:
//! - takes any [`Backend`](super::backend::Backend) trait object instead
//!   of a closed enum,
//! - surfaces accelerator-stage panics and backend errors as
//!   [`PipelineError`] instead of poisoning the stage joins, and
//! - counts requests that were admitted but never classified
//!   (`PipelineError::in_flight`) when the accelerator hangs up early.

use super::backend::Backend;
use super::serve::{run_server, PipelineError, Prediction, ServerConfig};
use crate::events::DatasetProfile;

/// Pipeline configuration (single-accelerator path).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub n_requests: usize,
    pub seed: u64,
    /// Channel depth between stages.
    pub queue_depth: usize,
    /// Histogram clip value.
    pub clip: f32,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { n_requests: 32, seed: 1, queue_depth: 4, clip: 8.0 }
    }
}

/// Outcome of a pipeline run.
pub struct PipelineResult {
    pub metrics: super::metrics::Metrics,
    pub predictions: Vec<Prediction>,
}

/// Run the three-stage pipeline to completion on a single accelerator.
pub fn run_pipeline(
    profile: &DatasetProfile,
    backend: &dyn Backend,
    cfg: &PipelineConfig,
) -> Result<PipelineResult, PipelineError> {
    let scfg = ServerConfig {
        n_requests: cfg.n_requests,
        seed: cfg.seed,
        clip: cfg.clip,
        workers: 1,
        queue_depth: cfg.queue_depth,
        drop_policy: super::queue::DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let r = run_server(profile, backend, &scfg)?;
    Ok(PipelineResult { metrics: r.metrics, predictions: r.predictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;
    use crate::coordinator::backend::{BackendError, Classification, Functional, Simulator};
    use crate::coordinator::testutil::qnet_for;
    use crate::sparse::SparseMap;

    #[test]
    fn functional_backend_processes_all_requests() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = PipelineConfig { n_requests: 12, seed: 4, queue_depth: 2, clip: 8.0 };
        let r = run_pipeline(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 12);
        assert!(r.metrics.e2e_summary().mean() > 0.0);
        assert!(r.metrics.throughput() > 0.0);
    }

    #[test]
    fn simulator_backend_reports_cycles() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
        let cfg = PipelineConfig { n_requests: 3, seed: 5, queue_depth: 2, clip: 8.0 };
        let r = run_pipeline(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 3);
        let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
        assert!(lat > 0.0);
    }

    /// Stage-3 (accelerator) panics surface as a `PipelineError` with
    /// in-flight accounting — they must not poison the stage joins.
    #[test]
    fn accelerator_panic_surfaces_as_error() {
        struct Panicky;
        impl crate::coordinator::backend::Backend for Panicky {
            fn name(&self) -> &str {
                "panicky"
            }
            fn classify(&self, _map: &SparseMap<f32>) -> Result<Classification, BackendError> {
                panic!("injected accelerator panic");
            }
        }
        let profile = DatasetProfile::n_mnist();
        let cfg = PipelineConfig { n_requests: 8, seed: 6, queue_depth: 2, clip: 8.0 };
        let err = run_pipeline(&profile, &Panicky, &cfg).unwrap_err();
        assert!(err.msg.contains("injected accelerator panic"), "msg: {}", err.msg);
        assert_eq!(err.completed, 0);
        // The panicking worker hung up while requests were queued behind it.
        assert!(err.in_flight >= 1, "in-flight requests not counted: {err:?}");
    }
}
