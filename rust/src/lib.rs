//! # ESDA — Composable Dynamic Sparse Dataflow Architecture
//!
//! A full reproduction of "A Composable Dynamic Sparse Dataflow Architecture
//! for Efficient Event-based Vision Processing on FPGA" (Gao, Zhang, Ding, So;
//! FPGA '24) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L3 (this crate)**: the paper's architecture as a cycle-level dataflow
//!   simulator ([`arch`]), the sparsity-aware hardware optimizer ([`hwopt`]),
//!   the model search ([`nas`]), the event-data substrate ([`events`]), and a
//!   PJRT runtime ([`runtime`]) that executes the AOT-compiled JAX model.
//! - **L2**: JAX model (`python/compile/model.py`), lowered once to HLO text.
//! - **L1**: Pallas submanifold-convolution kernel
//!   (`python/compile/kernels/submanifold.py`), interpret-mode on CPU.
pub mod util;
pub mod events;
pub mod sparse;
pub mod model;
pub mod arch;
pub mod hwopt;
pub mod nas;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod lint;
