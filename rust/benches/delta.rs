// lint:allow-file(panic): fail-fast bench harness — unwrap/expect on setup is the idiom
//! Incremental (delta) inference speedup vs. window overlap: full
//! recompute (`ExecPlan::classify`) against the dirty-frontier delta path
//! (`ExecPlan::classify_delta`) over sliding-window streams at overlap
//! fractions {0, 0.5, 0.9, 0.99} — plus allocs-per-window for the delta
//! path (the per-stream cache must be at zero in steady state) and a
//! bit-exactness cross-check on every window.
//!
//! The workload models the event-camera regime the delta path targets: a
//! static scene (a fixed background set of events, the carried fraction)
//! plus a drifting object (an 8×8 patch of fresh events that moves a few
//! pixels per window). At overlap 0 every window is all-fresh, the diff
//! exceeds `--delta-max-frac`, and the delta path degrades to a full
//! recompute (speedup ~1x); at 0.9+ only the patch neighbourhood is
//! recomputed and the speedup is the point of the whole feature.
//!
//! Emits `BENCH_delta.json` at the repository root (override the path
//! with `ESDA_BENCH_OUT`):
//!
//! ```sh
//! cargo bench --bench delta
//! ```
//!
//! `ESDA_BENCH_SMOKE=1` runs a fast low-iteration pass — numbers too
//! noisy to compare, but every field is measured and non-null.
//! `ESDA_BENCH_ASSERT=1` additionally asserts the ISSUE acceptance bar:
//! delta >= 2x full-recompute throughput at 0.9 overlap.

use esda::events::{repr::histogram2_norm, DatasetProfile, Event};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::{DeltaCache, ExecCtx, ExecPlan, NetworkSpec};
use esda::sparse::SparseMap;
use esda::util::alloc::CountingAllocator;
use esda::util::json::Json;
use esda::util::stats::bench;
use esda::util::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Measured iterations: the real run amortizes noise over 20; smoke mode
/// (CI) only proves the harness measures and emits real numbers.
fn iters() -> (usize, usize) {
    if std::env::var_os("ESDA_BENCH_SMOKE").is_some() {
        (1, 2)
    } else {
        (2, 20)
    }
}

fn req_per_s(n: usize, mean_s: f64) -> f64 {
    if mean_s <= 0.0 {
        return f64::NAN;
    }
    n as f64 / mean_s
}

const PATCH: usize = 8;
const EVENTS_PER_WINDOW: usize = 800;
const N_WINDOWS: usize = 16;

/// Sliding-window stream at `overlap`: each window carries
/// `overlap * EVENTS_PER_WINDOW` fixed background events and replaces the
/// rest with fresh events inside a patch that drifts per window.
fn windows(profile: &DatasetProfile, overlap: f64, seed: u64) -> Vec<SparseMap<f32>> {
    let (w, h) = (profile.w, profile.h);
    let mut rng = Rng::new(seed);
    let n_bg = (overlap * EVENTS_PER_WINDOW as f64).round() as usize;
    let bg: Vec<Event> = (0..n_bg)
        .map(|j| Event {
            t_us: j as u32,
            x: rng.below(w as u64) as u16,
            y: rng.below(h as u64) as u16,
            polarity: rng.chance(0.5),
        })
        .collect();
    (0..N_WINDOWS)
        .map(|i| {
            let (px, py) = ((7 * i) % (w - PATCH), (11 * i) % (h - PATCH));
            let mut es = bg.clone();
            for j in 0..EVENTS_PER_WINDOW - n_bg {
                es.push(Event {
                    t_us: (n_bg + j) as u32,
                    x: (px + rng.index(PATCH)) as u16,
                    y: (py + rng.index(PATCH)) as u16,
                    polarity: rng.chance(0.5),
                });
            }
            histogram2_norm(&es, w, h, 8.0)
        })
        .collect()
}

fn main() {
    let (warmup, iters) = iters();
    let assert_speedup = std::env::var_os("ESDA_BENCH_ASSERT").is_some();
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 7);
    let mut rng = Rng::new(42);
    let calib: Vec<SparseMap<f32>> = (0..3)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);
    let plan = ExecPlan::compile(&qnet);
    let max_frac = 0.35; // the serving default (--delta-max-frac)

    println!(
        "# delta inference — full recompute vs dirty-frontier delta \
         ({} on n_mnist, {N_WINDOWS} windows/stream, max_frac {max_frac})\n",
        spec.name
    );

    let mut sink = 0usize;
    let mut curve = Vec::new();
    let mut speedup_at_09 = f64::NAN;
    for overlap in [0.0, 0.5, 0.9, 0.99] {
        let wins = windows(&profile, overlap, 1000 + (overlap * 100.0) as u64);

        // Bit-exactness first (also a warm-up): the delta path must equal
        // the full path on every window, including fallback boundaries.
        let mut ctx = ExecCtx::new();
        let mut cache = DeltaCache::new();
        let mut hits = 0usize;
        let mut fulls = 0usize;
        let (mut dirty_sum, mut recomputed_sum) = (0.0f64, 0.0f64);
        for m in &wins {
            let full = plan.classify(&mut ctx, m);
            let (delta, outcome) = plan.classify_delta(&mut ctx, &mut cache, m, max_frac);
            assert_eq!(full, delta, "delta path must be bit-exact (overlap {overlap})");
            if outcome.is_delta() {
                hits += 1;
                dirty_sum += outcome.dirty_frac();
                recomputed_sum += outcome.recomputed_frac();
            } else {
                fulls += 1;
            }
            sink += delta;
        }

        // Full-recompute throughput over the same stream.
        let s = bench(warmup, iters, || {
            for m in &wins {
                sink += plan.classify(&mut ctx, m);
            }
        });
        let full_rps = req_per_s(N_WINDOWS, s.mean());

        // Delta throughput (cache already warm), then steady-state allocs.
        let s = bench(warmup, iters, || {
            for m in &wins {
                sink += plan.classify_delta(&mut ctx, &mut cache, m, max_frac).0;
            }
        });
        let delta_rps = req_per_s(N_WINDOWS, s.mean());
        let a0 = CountingAllocator::thread_allocs();
        for m in &wins {
            sink += plan.classify_delta(&mut ctx, &mut cache, m, max_frac).0;
        }
        let allocs = (CountingAllocator::thread_allocs() - a0) as f64 / N_WINDOWS as f64;

        let speedup = delta_rps / full_rps;
        if overlap == 0.9 {
            speedup_at_09 = speedup;
        }
        println!(
            "overlap {overlap:4}: full {full_rps:9.0} req/s | delta {delta_rps:9.0} req/s \
             ({speedup:5.2}x) | {hits:2} hit(s) / {fulls:2} full | {allocs:5.1} allocs/window",
        );
        curve.push(Json::obj(vec![
            ("overlap", Json::Num(overlap)),
            ("full_req_per_s", Json::Num(full_rps)),
            ("delta_req_per_s", Json::Num(delta_rps)),
            ("speedup", Json::Num(speedup)),
            ("delta_hits", Json::Num(hits as f64)),
            ("delta_fulls", Json::Num(fulls as f64)),
            (
                "mean_dirty_frac",
                Json::Num(if hits == 0 { 0.0 } else { dirty_sum / hits as f64 }),
            ),
            (
                "mean_recomputed_frac",
                Json::Num(if hits == 0 { 0.0 } else { recomputed_sum / hits as f64 }),
            ),
            ("delta_allocs_per_window", Json::Num(allocs)),
        ]));
    }

    if assert_speedup {
        assert!(
            speedup_at_09 >= 2.0,
            "acceptance: delta must be >= 2x full at 0.9 overlap (got {speedup_at_09:.2}x)"
        );
        println!("\nacceptance: {speedup_at_09:.2}x at 0.9 overlap (>= 2x required) — ok");
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("delta".into())),
        ("model", Json::Str(spec.name.clone())),
        ("dataset", Json::Str(profile.name.into())),
        ("n_windows", Json::Num(N_WINDOWS as f64)),
        ("events_per_window", Json::Num(EVENTS_PER_WINDOW as f64)),
        ("max_frac", Json::Num(max_frac)),
        ("iters", Json::Num(iters as f64)),
        ("curve", Json::Arr(curve)),
    ]);
    let path = std::env::var("ESDA_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_delta.json").into());
    std::fs::write(&path, format!("{out}\n")).expect("write bench json");
    println!("\nwrote {path} (sink {sink})");
}
