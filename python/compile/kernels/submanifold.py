"""L1 Pallas kernels: submanifold sparse convolution in the TPU-native
shift-and-MAC formulation.

HARDWARE ADAPTATION (DESIGN.md §3): the paper's FPGA line-buffer +
token-FIFO microarchitecture is re-thought for the TPU memory hierarchy —
activations as an (H, W, C) VMEM block, the nonzero set as an (H, W) mask
block, and the k×k weighted sum as nine shifted mask-gated partial
products. This keeps loads regular (no data-dependent control flow, which
the TPU vector unit cannot do) and lets the MXU handle the channel
contraction; the *dynamic* token skipping lives in the L3 cycle model.

All kernels run under ``interpret=True`` — real-TPU lowering emits Mosaic
custom calls the CPU PJRT plugin cannot execute (see /opt/xla-example).

Tiling: spatial dims are padded to TILE (8) multiples and the grid walks
row-tiles with a one-row halo held in VMEM; at these feature-map sizes
(≤240×180) a (TILE+2)·(W+2)·C f32 slab is ≤ ~0.7 MB, far under VMEM.
For interpret-mode simplicity each kernel instance sees the whole padded
array and the BlockSpec documents the intended HBM→VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _shifted(x, dy, dx):
    """x shifted so that out[h, w] = x[h + dy, w + dx], zero-padded."""
    h, w = x.shape[0], x.shape[1]
    pad = [(max(0, -dy), max(0, dy)), (max(0, -dx), max(0, dx))] + [(0, 0)] * (x.ndim - 2)
    xp = jnp.pad(x, pad)
    return jax.lax.dynamic_slice_in_dim(
        jax.lax.dynamic_slice_in_dim(xp, max(0, dy), h, axis=0), max(0, dx), w, axis=1
    )


def _pointwise_kernel(x_ref, m_ref, w_ref, b_ref, o_ref, *, act):
    """1×1 conv: channel contraction on the MXU, gated by the mask."""
    x = x_ref[...]
    m = m_ref[...]
    out = jnp.dot(x.reshape(-1, x.shape[-1]), w_ref[...]).reshape(x.shape[:2] + (w_ref.shape[-1],))
    out = out + b_ref[...]
    out = ref.apply_act(out, act)
    o_ref[...] = out * m[..., None]


def pointwise(x, mask, w, b, act="none"):
    """Pallas 1×1 convolution. x: (H, W, Cin), w: (Cin, Cout)."""
    h, wd, _ = x.shape
    cout = w.shape[-1]
    kernel = functools.partial(_pointwise_kernel, act=act)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, wd, cout), x.dtype),
        interpret=True,
    )(x, mask.astype(x.dtype), w, b)
    return out, mask


def _dw3x3_kernel(x_ref, m_ref, w_ref, b_ref, o_ref, *, act, stride):
    """Depthwise 3×3 via 9 shifted mask-gated partial products."""
    x = x_ref[...]
    m = m_ref[...]
    xm = x * m[..., None]  # gate inputs: absent tokens contribute zero
    acc = jnp.zeros_like(x)
    for dy in range(3):
        for dx in range(3):
            acc = acc + _shifted(xm, dy - 1, dx - 1) * w_ref[dy, dx, :]
    acc = acc + b_ref[...]
    if stride == 2:
        acc = acc[::2, ::2, :]
        om = ref.downsample_mask(m > 0).astype(x.dtype)[: acc.shape[0], : acc.shape[1]]
    else:
        om = m
    acc = ref.apply_act(acc, act)
    o_ref[...] = acc * om[..., None]


def dwconv3x3(x, mask, w, b, stride=1, act="none"):
    """Pallas depthwise 3×3 submanifold conv. w: (3, 3, C)."""
    h, wd, c = x.shape
    oh, ow = ((h + 1) // 2, (wd + 1) // 2) if stride == 2 else (h, wd)
    kernel = functools.partial(_dw3x3_kernel, act=act, stride=stride)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), x.dtype),
        interpret=True,
    )(x, mask.astype(x.dtype), w, b)
    out_mask = mask if stride == 1 else ref.downsample_mask(mask)
    return out, out_mask


def _full3x3_kernel(x_ref, m_ref, w_ref, b_ref, o_ref, *, act, stride):
    """Full 3×3: nine shifted inputs, each contracted on the MXU."""
    x = x_ref[...]
    m = m_ref[...]
    xm = x * m[..., None]
    h, wd, cin = x.shape
    cout = w_ref.shape[-1]
    acc = jnp.zeros((h, wd, cout), x.dtype)
    for dy in range(3):
        for dx in range(3):
            sh = _shifted(xm, dy - 1, dx - 1)
            acc = acc + jnp.dot(sh.reshape(-1, cin), w_ref[dy, dx]).reshape(h, wd, cout)
    acc = acc + b_ref[...]
    if stride == 2:
        acc = acc[::2, ::2, :]
        om = ref.downsample_mask(m > 0).astype(x.dtype)[: acc.shape[0], : acc.shape[1]]
    else:
        om = m
    acc = ref.apply_act(acc, act)
    o_ref[...] = acc * om[..., None]


def conv3x3(x, mask, w, b, stride=1, act="none"):
    """Pallas full 3×3 submanifold/sparse conv. w: (3, 3, Cin, Cout)."""
    h, wd, _ = x.shape
    cout = w.shape[-1]
    oh, ow = ((h + 1) // 2, (wd + 1) // 2) if stride == 2 else (h, wd)
    kernel = functools.partial(_full3x3_kernel, act=act, stride=stride)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, cout), x.dtype),
        interpret=True,
    )(x, mask.astype(x.dtype), w, b)
    out_mask = mask if stride == 1 else ref.downsample_mask(mask)
    return out, out_mask


def _pool_fc_kernel(x_ref, m_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]
    m = m_ref[...]
    n = jnp.maximum(m.sum(), 1.0)
    pooled = (x * m[..., None]).sum(axis=(0, 1)) / n
    o_ref[...] = jnp.dot(pooled, w_ref[...]) + b_ref[...]


def pool_fc(x, mask, wfc, bfc):
    """Pallas global-average-pool (over tokens) + classifier."""
    n_classes = wfc.shape[-1]
    return pl.pallas_call(
        _pool_fc_kernel,
        out_shape=jax.ShapeDtypeStruct((n_classes,), x.dtype),
        interpret=True,
    )(x, mask.astype(x.dtype), wfc, bfc)


def vmem_footprint_bytes(h, w, c, cout, k=3, dtype_bytes=4, tile_h=None):
    """Estimated VMEM bytes for one kernel instance.

    ``tile_h=None`` models the whole-slab BlockSpec (what interpret mode
    runs); a row-tiled schedule holds ``tile_h + (k-1)`` halo rows of input
    and ``tile_h`` rows of output resident — the schedule the §Perf section
    sizes for real VMEM (≈16 MB/core)."""
    th_in = h if tile_h is None else tile_h + (k - 1)
    th_out = h if tile_h is None else tile_h
    act_in = th_in * w * c * dtype_bytes
    act_out = th_out * w * cout * dtype_bytes
    mask = th_in * w * dtype_bytes
    weights = k * k * c * cout * dtype_bytes
    return act_in + act_out + mask + weights
