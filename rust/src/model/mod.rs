//! Network description, weights, quantization, and functional execution.
//!
//! A [`graph::NetworkSpec`] is a list of [`graph::Block`]s (stem conv,
//! MBConv inverted-residual blocks, pooling + FC head — the model family
//! the paper builds on, §3.3.7). Blocks expand to a flat [`graph::Op`]
//! program which:
//!
//! - [`exec`] runs functionally in f32 (training parity) or int8
//!   (hardware-exact) — the oracle for the cycle-level simulator,
//! - `crate::arch::builder` maps 1:1 onto dataflow hardware modules,
//! - `crate::hwopt` costs per-op under the Eqn. 5 model.
//!
//! [`weights`] holds the tensors (loadable from the python-exported binary
//! container), and [`quant`] converts calibrated float weights into the
//! dyadic int8 form both the functional int8 path and the simulator
//! consume.
//! [`plan`] splits functional int8 execution into a compile phase
//! ([`plan::ExecPlan`], built once per network) and an execute phase
//! through a reusable per-worker buffer arena ([`plan::ExecCtx`]) — the
//! serving hot path. [`exec`] remains the allocating per-op oracle.
pub mod graph;
pub mod weights;
pub mod quant;
pub mod exec;
pub mod plan;

pub use graph::{Act, Block, NetworkSpec, Op};
pub use plan::{DeltaCache, DeltaOutcome, ExecCtx, ExecPlan, FullReason};
pub use weights::{OpWeights, QuantOpWeights};
