//! The sharded serving runtime with a heterogeneous, cost-aware pool.
//!
//! ```text
//!                                              ┌ class "func" ┬ worker 0 ┐
//! event source → repr builder → ingress → router┤  sub-queue   └ worker 1 ┤→ merged
//!  (synthetic     (histogram2)   queue   (cost- │             …           │  metrics +
//!   camera)                    (admission aware)└ class "sim" ── worker N ┘  predictions
//!                               control)
//! ```
//!
//! The source and representation stages run on their own threads (the
//! "processing system" of Fig. 2). With more than one replica class,
//! admitted requests flow through a **router** that picks a class per
//! request (with a single class, workers drain the ingress directly — no
//! router thread, no cost-model overhead, and the original drop-oldest
//! semantics): each class
//! advertises a cost model (an EWMA of observed service seconds per
//! event-count bucket, seeded from its first requests — see
//! [`CostModel`]) and a batch affinity (the micro-batch cap its workers
//! drain; dense engines want large batches, the cycle simulator wants
//! batch 1). The router sends each request to the class minimizing
//! predicted completion time given current per-class backlogs, via
//! per-class sub-queues layered on the global [`AdmissionQueue`].
//!
//! Admission control stays **global**: only the ingress queue drops
//! (`Block` exerts backpressure, `DropOldest` sheds stale load and counts
//! every drop); sub-queues always block, so a saturated class
//! back-pressures the router and the shedding decision is still made — and
//! accounted — at one place.
//!
//! Worker panics and backend errors are caught and surfaced as
//! [`PipelineError`] — they never poison a join — and requests that were
//! admitted but not classified when the run aborts are counted as
//! `in_flight`.
//!
//! Entry points: [`run_server`] (homogeneous — one backend shared by N
//! workers, a single routing class) and [`run_pool`] (heterogeneous — a
//! [`ReplicaPool`] of per-replica backend instances).

use super::backend::{Backend, ReplicaPool};
use super::metrics::{
    ClassStats, CostModel, Metrics, PercentileReport, RequestTiming, WorkerStats,
};
use super::queue::{AdmissionQueue, DropPolicy};
use crate::events::{repr::histogram2_norm, DatasetProfile};
use crate::sparse::SparseMap;
use crate::util::{panic_message, Rng};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::Instant;

/// Serving-runtime configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of requests the synthetic source generates.
    pub n_requests: usize,
    /// Source seed (fixes the request stream).
    pub seed: u64,
    /// Histogram clip value.
    pub clip: f32,
    /// Accelerator worker replicas ([`run_server`] only — a
    /// [`ReplicaPool`] carries its own per-class counts).
    pub workers: usize,
    /// Ingress queue depth (also the depth of each per-class sub-queue).
    pub queue_depth: usize,
    /// Admission control policy when the ingress queue saturates.
    pub drop_policy: DropPolicy,
    /// Max requests a worker drains from its queue per wakeup
    /// ([`run_server`] only — pool classes carry their own batch
    /// affinity; 1 = classic one-at-a-time). Workers never wait to fill a
    /// batch — they take what is already queued — so batching adds no
    /// latency when the system is unloaded and amortizes per-visit
    /// backend overhead when it is saturated.
    pub batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            n_requests: 32,
            seed: 1,
            clip: 8.0,
            workers: 1,
            queue_depth: 4,
            drop_policy: DropPolicy::Block,
            batch: 1,
        }
    }
}

/// One served request's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prediction {
    /// Ground-truth class of the synthetic recording.
    pub label: usize,
    /// Backend's predicted class.
    pub pred: usize,
    /// Worker replica that served it.
    pub worker: usize,
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServerResult {
    pub metrics: Metrics,
    /// Per-request outcomes, grouped by worker (use as a multiset: the
    /// worker interleaving is scheduling-dependent).
    pub predictions: Vec<Prediction>,
}

/// A serving run that aborted: the first backend error or worker panic,
/// plus how much work completed and how much was stranded.
#[derive(Debug, Clone)]
pub struct PipelineError {
    pub msg: String,
    /// Requests classified before the abort.
    pub completed: usize,
    /// Requests admitted but never classified.
    pub in_flight: usize,
    /// Requests evicted by admission control before the abort.
    pub dropped: usize,
}

impl fmt::Display for PipelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serving aborted after {} request(s) ({} in flight, {} dropped): {}",
            self.completed, self.in_flight, self.dropped, self.msg
        )
    }
}

impl std::error::Error for PipelineError {}

/// An admitted request: built by the repr stage, (optionally) routed, then
/// served from a queue. With a single replica class there is no router and
/// workers drain the ingress directly; with several, the router fills in
/// `predicted_s` and moves it to a class sub-queue.
struct Routed {
    label: usize,
    map: SparseMap<f32>,
    enqueued: Instant,
    /// Event-count bucket ([`CostModel::bucket_of`]), computed once at
    /// admission.
    bucket: usize,
    /// Service seconds the router predicted for this request (NaN when no
    /// router ran or the class was unseeded at routing time).
    predicted_s: f64,
}

/// One replica class's scheduling inputs: display name, batch affinity,
/// and one backend reference per worker replica.
struct ClassSlots<'a> {
    name: String,
    batch: usize,
    backends: Vec<&'a dyn Backend>,
}

/// A replica class's live runtime state.
struct ClassCtx<'a> {
    name: String,
    batch: usize,
    backends: Vec<&'a dyn Backend>,
    /// Per-class sub-queue (always blocking — drops are global-only).
    queue: AdmissionQueue<Routed>,
    /// Requests routed here and not yet classified (queued + in service).
    backlog: AtomicUsize,
    /// Observed-service-time predictor the router consults.
    cost: CostModel,
}

/// Pick the class minimizing predicted completion time for a request in
/// `bucket`, given current backlogs. Unseeded classes are probed eagerly
/// (their real cost is unknown and must be learned) but only up to one
/// outstanding request per replica while any alternative — seeded, or
/// under its probe cap — exists. In the cold-start corner where *every*
/// class is unseeded and probe-capped, requests spread by per-replica
/// backlog (and each sub-queue's bounded depth caps how much can ever
/// stack behind one slow class). Ties break toward the smaller
/// per-replica backlog.
///
/// Returns the chosen class index and the per-request service prediction
/// the decision was based on (NaN for a probe), so the caller records
/// exactly what the router saw — not a re-query that a concurrent
/// `observe` may have seeded in the meantime.
fn route(classes: &[ClassCtx<'_>], bucket: usize) -> (usize, f64) {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    let mut best_load = f64::INFINITY;
    let mut best_pred = f64::NAN;
    for (i, c) in classes.iter().enumerate() {
        let backlog = c.backlog.load(Ordering::SeqCst);
        let replicas = c.backends.len();
        // Queued + in-service requests per replica: the tie-break key, so
        // a 1-replica class doesn't absorb as much as a 4-replica one.
        let load = backlog as f64 / replicas as f64;
        let pred = c.cost.predict(bucket);
        let cost = match pred {
            // Predicted completion ≈ own service time scaled by how many
            // requests already wait ahead of it per replica.
            Some(s) => s * (load + 1.0),
            None if backlog < replicas => f64::NEG_INFINITY,
            None => f64::INFINITY,
        };
        if cost < best_cost || (cost == best_cost && load < best_load) {
            best = i;
            best_cost = cost;
            best_load = load;
            best_pred = pred.unwrap_or(f64::NAN);
        }
    }
    (best, best_pred)
}

/// One classified request as a worker recorded it.
struct ServedRecord {
    label: usize,
    pred: usize,
    timing: RequestTiming,
    predicted_s: f64,
}

/// Per-worker raw output collected at join time.
struct WorkerOutput {
    wid: usize,
    class: usize,
    busy_s: f64,
    records: Vec<ServedRecord>,
    batch_sizes: Vec<usize>,
}

/// The accelerator worker body: drain `queue` in micro-batches and
/// classify through this replica's backend. `routed` is true when a
/// router feeds this class (several classes): the worker then maintains
/// the class backlog and folds observed service times back into the class
/// cost model; in the single-class fast path (`queue` *is* the ingress)
/// both are skipped — there is no routing decision to inform.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    ci: usize,
    class: &ClassCtx<'_>,
    queue: &AdmissionQueue<Routed>,
    routed: bool,
    backend: &dyn Backend,
    classes: &[ClassCtx<'_>],
    ingress: &AdmissionQueue<Routed>,
    first_error: &Mutex<Option<String>>,
) -> WorkerOutput {
    // Record the first failure and hard-stop every stage: producers fail
    // fast, the router and all class workers wake and exit.
    let fail = |msg: String| {
        first_error.lock().unwrap().get_or_insert_with(|| msg);
        ingress.abort();
        for c in classes {
            c.queue.abort();
        }
    };
    let mut records: Vec<ServedRecord> = Vec::new();
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut busy_s = 0.0f64;
    let batch_cap = class.batch.max(1);
    let mut batch: Vec<Routed> = Vec::with_capacity(batch_cap);
    let mut metas: Vec<(usize, Instant, usize, f64)> = Vec::with_capacity(batch_cap);
    let mut maps: Vec<SparseMap<f32>> = Vec::with_capacity(batch_cap);
    loop {
        queue.pop_batch(batch_cap, &mut batch);
        if batch.is_empty() {
            break; // closed and drained, or aborted
        }
        let n = batch.len();
        metas.clear();
        maps.clear();
        for req in batch.drain(..) {
            metas.push((req.label, req.enqueued, req.bucket, req.predicted_s));
            maps.push(req.map);
        }
        let t0 = Instant::now();
        let outcome = catch_unwind(AssertUnwindSafe(|| backend.classify_batch(&maps)));
        let visit_s = t0.elapsed().as_secs_f64();
        if routed {
            // The visit is over: these requests leave the class's routing
            // backlog whatever the outcome.
            class.backlog.fetch_sub(n, Ordering::SeqCst);
        }
        let results = match outcome {
            Ok(rs) => rs,
            Err(p) => {
                fail(format!("worker panic: {}", panic_message(p.as_ref())));
                break;
            }
        };
        if results.len() != n {
            // A broken Backend impl must fail loudly, not silently lose
            // requests to zip truncation.
            fail(format!(
                "backend '{}' returned {} result(s) for a batch of {n}",
                backend.name(),
                results.len(),
            ));
            break;
        }
        busy_s += visit_s;
        batch_sizes.push(n);
        // The visit is one accelerator pass; attribute its cost evenly
        // across the requests it served, and — when a router is making
        // decisions — teach it what this class actually costs at each
        // request's event-count bucket.
        let service_s = visit_s / n as f64;
        if routed {
            for &(_, _, bucket, _) in &metas {
                class.cost.observe(bucket, service_s);
            }
        }
        let mut failed = false;
        for (&(label, enqueued, _bucket, predicted_s), res) in metas.iter().zip(results) {
            match res {
                Ok(c) => {
                    let timing = RequestTiming {
                        e2e_s: enqueued.elapsed().as_secs_f64(),
                        service_s,
                        sim_cycles: c.sim_cycles,
                    };
                    records.push(ServedRecord { label, pred: c.pred, timing, predicted_s });
                }
                Err(e) => {
                    fail(e.to_string());
                    failed = true;
                    break;
                }
            }
        }
        if failed {
            break;
        }
    }
    WorkerOutput { wid, class: ci, busy_s, records, batch_sizes }
}

/// Run the serving pipeline to completion over `cfg.n_requests` synthetic
/// requests with a **homogeneous** pool: `cfg.workers` replicas sharing
/// one backend, a single class. With one class there is no routing
/// decision, so no router thread runs — workers drain the ingress queue
/// directly, exactly as the pre-pool runtime did (same admission and
/// drop-oldest semantics, no cost-model overhead).
pub fn run_server(
    profile: &DatasetProfile,
    backend: &dyn Backend,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(cfg.workers >= 1, "need at least one worker replica");
    let slots = vec![ClassSlots {
        name: backend.name().to_string(),
        batch: cfg.batch.max(1),
        backends: vec![backend; cfg.workers],
    }];
    serve_classes(profile, slots, cfg)
}

/// Run the serving pipeline over a **heterogeneous** [`ReplicaPool`]: each
/// class brings its own replica count, per-replica backend instances, and
/// batch affinity; the router spreads admitted requests across classes by
/// predicted completion time. `cfg.workers` and `cfg.batch` are ignored —
/// the pool defines the shape.
pub fn run_pool(
    profile: &DatasetProfile,
    pool: &ReplicaPool,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!pool.classes.is_empty(), "pool needs at least one replica class");
    let slots: Vec<ClassSlots<'_>> = pool
        .classes
        .iter()
        .map(|c| ClassSlots {
            name: c.name.clone(),
            batch: c.batch,
            backends: c.replicas.iter().map(|b| b.as_ref()).collect(),
        })
        .collect();
    serve_classes(profile, slots, cfg)
}

/// The shared serving spine behind [`run_server`] and [`run_pool`].
fn serve_classes(
    profile: &DatasetProfile,
    slots: Vec<ClassSlots<'_>>,
    cfg: &ServerConfig,
) -> Result<ServerResult, PipelineError> {
    assert!(!slots.is_empty(), "need at least one replica class");
    assert!(
        slots.iter().all(|c| !c.backends.is_empty()),
        "every replica class needs at least one worker"
    );
    let t_start = Instant::now();
    // With a single class there is nothing to route: workers drain the
    // ingress directly (no router thread, no cost-model locks), which also
    // preserves the exact drop-oldest semantics the homogeneous runtime
    // always had — the stalest *queued* request is the one evicted.
    let has_router = slots.len() > 1;
    let ingress: AdmissionQueue<Routed> = AdmissionQueue::new(cfg.queue_depth, cfg.drop_policy);
    let classes: Vec<ClassCtx<'_>> = slots
        .into_iter()
        .map(|c| ClassCtx {
            // Sub-queues always block: admission control (and its drop
            // accounting) lives at the global ingress only. A full
            // sub-queue back-pressures the router, which lets the ingress
            // saturate, where the shedding decision is made and counted.
            // (Trade-off vs the single-class path: requests already routed
            // into a sub-queue are no longer evictable, so under drop-
            // oldest the very stalest in-flight requests survive while
            // ingress-queued ones shed.)
            queue: AdmissionQueue::new(cfg.queue_depth, DropPolicy::Block),
            backlog: AtomicUsize::new(0),
            cost: CostModel::new(),
            name: c.name,
            batch: c.batch.max(1),
            backends: c.backends,
        })
        .collect();
    let first_error: Mutex<Option<String>> = Mutex::new(None);
    let (tx_ev, rx_ev) =
        sync_channel::<(usize, Vec<crate::events::Event>)>(cfg.queue_depth.max(1));

    let mut outputs: Vec<WorkerOutput> = Vec::new();
    std::thread::scope(|s| {
        // Stage 1: synthetic event camera.
        let p1 = profile.clone();
        let (n, seed) = (cfg.n_requests, cfg.seed);
        let source = s.spawn(move || {
            let mut rng = Rng::new(seed);
            for i in 0..n {
                let class = i % p1.n_classes;
                let events = p1.sample(class, &mut rng);
                if tx_ev.send((class, events)).is_err() {
                    return; // downstream hung up early
                }
            }
        });

        // Stage 2: representation builder + admission control.
        let (w, h, clip) = (profile.w, profile.h, cfg.clip);
        let ingress_ref = &ingress;
        let repr = s.spawn(move || {
            for (label, events) in rx_ev.iter() {
                let map = histogram2_norm(&events, w, h, clip);
                let req = Routed {
                    label,
                    bucket: CostModel::bucket_of(map.nnz()),
                    map,
                    enqueued: Instant::now(),
                    predicted_s: f64::NAN,
                };
                if ingress_ref.push(req).is_err() {
                    break; // queue closed by an aborting worker
                }
            }
            ingress_ref.close();
        });

        // Stage 3: the cost-aware router — admitted requests to class
        // sub-queues by predicted completion time. Only spawned when there
        // is a routing decision to make.
        let classes_ref: &[ClassCtx<'_>] = &classes;
        let router = has_router.then(|| {
            s.spawn(move || {
                while let Some(mut req) = ingress_ref.pop() {
                    let (ci, predicted_s) = route(classes_ref, req.bucket);
                    let class = &classes_ref[ci];
                    req.predicted_s = predicted_s;
                    class.backlog.fetch_add(1, Ordering::SeqCst);
                    if class.queue.push(req).is_err() {
                        break; // aborted downstream
                    }
                }
                for c in classes_ref {
                    c.queue.close();
                }
            })
        });

        // Stage 4: per-class accelerator worker pools.
        let error_ref = &first_error;
        let mut handles = Vec::new();
        let mut next_wid = 0usize;
        for (ci, class) in classes.iter().enumerate() {
            for &backend in &class.backends {
                let wid = next_wid;
                next_wid += 1;
                handles.push(s.spawn(move || {
                    let queue = if has_router { &class.queue } else { ingress_ref };
                    worker_loop(
                        wid, ci, class, queue, has_router, backend, classes_ref, ingress_ref,
                        error_ref,
                    )
                }));
            }
        }
        outputs = handles.into_iter().map(|h| h.join().expect("worker thread")).collect();
        if let Some(h) = router {
            h.join().expect("router thread");
        }
        repr.join().expect("repr thread");
        source.join().expect("source thread");
    });

    outputs.sort_by_key(|o| o.wid);
    let (submitted, dropped, _still_queued) = ingress.stats();
    let processed: usize = outputs.iter().map(|o| o.records.len()).sum();
    let in_flight = submitted.saturating_sub(dropped + processed);

    if let Some(msg) = first_error.into_inner().unwrap() {
        return Err(PipelineError { msg, completed: processed, in_flight, dropped });
    }
    // Clean completion conserves requests: everything admitted was either
    // served or dropped (stranded requests only exist on the Err path).
    debug_assert_eq!(in_flight, 0, "completed run stranded {in_flight} request(s)");

    let wall_s = t_start.elapsed().as_secs_f64();
    let mut metrics = Metrics { started: t_start, dropped, wall_s, ..Metrics::default() };
    let mut predictions = Vec::with_capacity(processed);
    for o in &outputs {
        let service: Vec<f64> = o.records.iter().map(|r| r.timing.service_s).collect();
        let e2e: Vec<f64> = o.records.iter().map(|r| r.timing.e2e_s).collect();
        let batches: Vec<f64> = o.batch_sizes.iter().map(|&b| b as f64).collect();
        metrics.per_worker.push(WorkerStats {
            worker: o.wid,
            class: classes[o.class].name.clone(),
            served: o.records.len(),
            batches: o.batch_sizes.len(),
            busy_s: o.busy_s,
            service: PercentileReport::from_samples(&service),
            e2e: PercentileReport::from_samples(&e2e),
            batch: PercentileReport::from_samples(&batches),
        });
        metrics.batch_sizes.extend_from_slice(&o.batch_sizes);
        for r in &o.records {
            metrics.record(r.timing, r.pred == r.label);
            predictions.push(Prediction { label: r.label, pred: r.pred, worker: o.wid });
        }
    }
    // Per-class rollup: served/visit/busy books plus how well the routing
    // predictor tracked observed service times.
    for (ci, class) in classes.iter().enumerate() {
        let mut served = 0usize;
        let mut batches = 0usize;
        let mut busy_s = 0.0f64;
        let mut service: Vec<f64> = Vec::new();
        let mut batch_f: Vec<f64> = Vec::new();
        let mut err_sum = 0.0f64;
        let mut err_n = 0usize;
        let mut unseeded = 0usize;
        for o in outputs.iter().filter(|o| o.class == ci) {
            served += o.records.len();
            batches += o.batch_sizes.len();
            busy_s += o.busy_s;
            batch_f.extend(o.batch_sizes.iter().map(|&b| b as f64));
            for r in &o.records {
                service.push(r.timing.service_s);
                if r.predicted_s.is_finite() {
                    err_sum += (r.predicted_s - r.timing.service_s).abs()
                        / r.timing.service_s.max(1e-9);
                    err_n += 1;
                } else if has_router {
                    // Probe traffic: routed before this class's cost model
                    // had an observation. (Without a router no prediction
                    // is ever attempted, so nothing counts as a probe.)
                    unseeded += 1;
                }
            }
        }
        metrics.per_class.push(ClassStats {
            class: class.name.clone(),
            replicas: class.backends.len(),
            served,
            batches,
            busy_s,
            batch: PercentileReport::from_samples(&batch_f),
            service: PercentileReport::from_samples(&service),
            cost_err: if err_n > 0 { err_sum / err_n as f64 } else { f64::NAN },
            unseeded,
        });
    }
    Ok(ServerResult { metrics, predictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;
    use crate::coordinator::backend::{
        BackendError, Classification, Functional, ReplicaSpec, Simulator,
    };
    use crate::coordinator::testutil::qnet_for;

    #[test]
    fn pool_processes_all_requests() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig { n_requests: 12, seed: 4, workers: 3, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 12);
        assert_eq!(r.predictions.len(), 12);
        assert_eq!(r.metrics.dropped, 0);
        assert_eq!(r.metrics.per_worker.len(), 3);
        assert_eq!(r.metrics.per_worker.iter().map(|w| w.served).sum::<usize>(), 12);
        assert!(r.metrics.throughput() > 0.0);
        // The homogeneous path reports a single routing class.
        assert_eq!(r.metrics.per_class.len(), 1);
        assert_eq!(r.metrics.per_class[0].served, 12);
        assert_eq!(r.metrics.per_class[0].replicas, 3);
    }

    /// Micro-batching is a scheduling detail: every request is still served
    /// exactly once, and the batch-size books stay consistent.
    #[test]
    fn batched_pool_serves_every_request_once() {
        let profile = DatasetProfile::n_mnist();
        let backend = Functional::new(qnet_for(&profile));
        let cfg = ServerConfig {
            n_requests: 20,
            seed: 6,
            workers: 2,
            queue_depth: 8,
            batch: 4,
            ..Default::default()
        };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 20);
        assert_eq!(r.predictions.len(), 20);
        let visits: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(visits, 20, "batch sizes must partition the request stream");
        assert!(r.metrics.batch_sizes.iter().all(|&b| (1..=4).contains(&b)));
        assert!(r.metrics.mean_batch() >= 1.0);
        let per_worker: usize = r.metrics.per_worker.iter().map(|w| w.batches).sum();
        assert_eq!(per_worker, r.metrics.batch_sizes.len());
    }

    #[test]
    fn simulator_replicas_report_cycles() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let backend = Simulator::new(qnet, HwConfig::uniform(n_ops, 16));
        let cfg = ServerConfig { n_requests: 4, seed: 5, workers: 2, ..Default::default() };
        let r = run_server(&profile, &backend, &cfg).unwrap();
        assert_eq!(r.metrics.total, 4);
        let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
        assert!(lat > 0.0);
    }

    /// A two-class heterogeneous pool serves every request exactly once,
    /// respects each class's batch affinity, and reports a per-class
    /// breakdown whose books balance.
    #[test]
    fn heterogeneous_pool_keeps_class_books_balanced() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let qnet2 = qnet.clone();
        let pool = ReplicaPool::build(vec![
            ReplicaSpec::functional(2, qnet),
            ReplicaSpec::new("func-b", 1, 2, move |_| {
                Ok(Box::new(Functional::new(qnet2.clone())))
            }),
        ])
        .unwrap();
        assert_eq!(pool.n_replicas(), 3);
        let cfg = ServerConfig { n_requests: 16, seed: 9, queue_depth: 4, ..Default::default() };
        let r = run_pool(&profile, &pool, &cfg).unwrap();
        assert_eq!(r.metrics.total, 16);
        assert_eq!(r.metrics.per_worker.len(), 3);
        assert_eq!(r.metrics.per_class.len(), 2);
        assert_eq!(r.metrics.per_class.iter().map(|c| c.served).sum::<usize>(), 16);
        let class_batches: usize = r.metrics.per_class.iter().map(|c| c.batches).sum();
        assert_eq!(class_batches, r.metrics.batch_sizes.len());
        let visits: usize = r.metrics.batch_sizes.iter().sum();
        assert_eq!(visits, 16, "batch sizes must partition the request stream");
        for c in &r.metrics.per_class {
            let cap = if c.class == "func" { 4.0 } else { 2.0 };
            assert!(
                c.batches == 0 || c.batch.max <= cap,
                "class {} exceeded its batch affinity: {:?}",
                c.class,
                c.batch
            );
        }
        // Worker stats carry their class name for the report.
        for w in &r.metrics.per_worker {
            assert!(w.class == "func" || w.class == "func-b", "class: {}", w.class);
        }
    }

    /// A backend that errors mid-stream aborts cleanly with in-flight
    /// accounting instead of deadlocking or poisoning joins.
    #[test]
    fn backend_error_aborts_cleanly() {
        struct FailAfter {
            inner: Functional,
            calls: std::sync::atomic::AtomicUsize,
        }
        impl Backend for FailAfter {
            fn name(&self) -> &str {
                "fail-after"
            }
            fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
                let n = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if n >= 5 {
                    return Err(BackendError("injected fault".into()));
                }
                self.inner.classify(map)
            }
        }
        let profile = DatasetProfile::n_mnist();
        let backend = FailAfter {
            inner: Functional::new(qnet_for(&profile)),
            calls: std::sync::atomic::AtomicUsize::new(0),
        };
        let cfg = ServerConfig { n_requests: 16, seed: 2, workers: 2, ..Default::default() };
        let err = run_server(&profile, &backend, &cfg).unwrap_err();
        assert!(err.msg.contains("injected fault"), "msg: {}", err.msg);
        assert!(err.completed < 16);
    }
}
