//! Tiny CLI flag parser (clap is not vendored).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags (`--model a=x --model b=y`, read via [`Args::get_all`]), and
//! positional arguments, with typed accessors and a generated usage
//! string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Every occurrence of each value-carrying flag, in order. The
    /// single-value accessors read the *last* occurrence, so a repeated
    /// scalar flag keeps the familiar "later overrides earlier" shell
    /// semantics while list flags see everything.
    flags: BTreeMap<String, Vec<String>>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (usually `std::env::args().skip(1)`).
    /// `bool_flags` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.flags
                        .entry(stripped[..eq].to_string())
                        .or_default()
                        .push(stripped[eq + 1..].to_string());
                } else if bool_flags.contains(&stripped) {
                    out.bools.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{stripped} expects a value"))?;
                    // A following `--flag` is almost certainly a typo'd
                    // invocation, not a value (`--source --slo-ms 5`
                    // would silently yield source="--slo-ms"). Values
                    // that legitimately start with `--` still have the
                    // `--flag=--value` escape hatch above.
                    if v.starts_with("--") {
                        return Err(format!("flag --{stripped} expects a value, got flag '{v}'"));
                    }
                    out.flags.entry(stripped.to_string()).or_default().push(v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Last occurrence of a value flag (repeats override, shell-style).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a value flag, in command-line order (empty
    /// when the flag was never passed) — for repeatable list flags like
    /// `--model name=arch`.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.flags.get(name).map_or(&[], |v| v.as_slice())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// One entry of a `--pool` spec: a replica class name, its base replica
/// count, an optional autoscaling upper bound, and an optional
/// batch-affinity override.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolItem {
    pub class: String,
    /// Base (minimum) replica count.
    pub count: usize,
    /// `Some(m)` when spelled `class=min..max`: the autoscaler may grow
    /// the class up to `m` replicas. `None` pins the class at `count`.
    pub max: Option<usize>,
    /// `Some(b)` when spelled `class=count@b`; `None` leaves the class's
    /// default batch affinity in place.
    pub batch: Option<usize>,
}

/// Parse a `--pool` spec: a comma-separated list of
/// `class=count[@batch]` or `class=min..max[@batch]` entries, e.g.
/// `func=4,sim=1,dense=1`, `func=4@8,sim=1`, or `func=1..4,sim=1..2@1`.
pub fn parse_pool_spec(s: &str) -> Result<Vec<PoolItem>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let (class, rest) = part.split_once('=').ok_or_else(|| {
            format!("pool entry '{part}': expected class=count[@batch] or class=min..max[@batch]")
        })?;
        let (count_s, batch) = match rest.split_once('@') {
            Some((c, b)) => {
                let b: usize = b
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad batch '{b}'"))?;
                if b == 0 {
                    return Err(format!("pool entry '{part}': batch must be >= 1"));
                }
                (c, Some(b))
            }
            None => (rest, None),
        };
        let (count, max) = match count_s.split_once("..") {
            Some((lo, hi)) => {
                let lo: usize = lo
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad min count '{lo}'"))?;
                let hi: usize = hi
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad max count '{hi}'"))?;
                if hi < lo {
                    return Err(format!(
                        "pool entry '{part}': replica range must satisfy min <= max"
                    ));
                }
                (lo, Some(hi))
            }
            None => {
                let count: usize = count_s
                    .parse()
                    .map_err(|_| format!("pool entry '{part}': bad count '{count_s}'"))?;
                (count, None)
            }
        };
        if count == 0 {
            return Err(format!("pool entry '{part}': count must be >= 1"));
        }
        if class.is_empty() {
            return Err(format!("pool entry '{part}': empty class name"));
        }
        out.push(PoolItem { class: class.to_string(), count, max, batch });
    }
    Ok(out)
}

/// A parsed `--source` spec: where the serving runtime's requests come
/// from.
#[derive(Debug, Clone, PartialEq)]
pub enum SourceSpec {
    /// The synthetic event camera (default).
    Synth,
    /// Replay a recorded `.esda` dataset at `speed`× wall-clock rate.
    Replay { path: String, speed: f64 },
    /// Follow a growing `.esda` file (camera-dump pipeline).
    Tail { path: String },
    /// Listen for event packets on a UDP socket (one packet per
    /// datagram).
    Udp { port: u16 },
    /// Accept length-prefixed event-packet streams on a TCP socket.
    Tcp { port: u16 },
}

/// Parse a `--source` spec: `synth`, `replay:path[@speed]`,
/// `tail:path`, `udp:port`, or `tcp:port`.
/// For `replay:`, the substring after the *last* `@` is the replay speed
/// when it parses as a number (which must then be finite and > 0);
/// a non-numeric suffix is simply part of the path, so
/// `replay:runs@v2/cap.esda` opens that file at 1× while
/// `replay:cap.esda@2.5` replays at 2.5×. A path whose final component
/// genuinely ends in `@<number>` needs an explicit speed suffix.
pub fn parse_source_spec(s: &str) -> Result<SourceSpec, String> {
    if s == "synth" {
        return Ok(SourceSpec::Synth);
    }
    if let Some(rest) = s.strip_prefix("replay:") {
        let (path, speed) = match rest.rsplit_once('@') {
            Some((p, sp)) => match sp.parse::<f64>() {
                Ok(v) if v.is_finite() && v > 0.0 => (p, v),
                Ok(v) => {
                    return Err(format!(
                        "--source replay: speed must be finite and > 0, got {v}"
                    ))
                }
                // Non-numeric suffix: the '@' belongs to the path.
                Err(_) => (rest, 1.0),
            },
            None => (rest, 1.0),
        };
        if path.is_empty() {
            return Err("--source replay: empty path".into());
        }
        return Ok(SourceSpec::Replay { path: path.to_string(), speed });
    }
    if let Some(path) = s.strip_prefix("tail:") {
        if path.is_empty() {
            return Err("--source tail: empty path".into());
        }
        return Ok(SourceSpec::Tail { path: path.to_string() });
    }
    if let Some(port) = s.strip_prefix("udp:") {
        let port: u16 = port
            .parse()
            .map_err(|_| format!("--source udp: bad port '{port}'"))?;
        if port == 0 {
            return Err("--source udp: port must be >= 1".into());
        }
        return Ok(SourceSpec::Udp { port });
    }
    if let Some(port) = s.strip_prefix("tcp:") {
        let port: u16 = port
            .parse()
            .map_err(|_| format!("--source tcp: bad port '{port}'"))?;
        if port == 0 {
            return Err("--source tcp: port must be >= 1".into());
        }
        return Ok(SourceSpec::Tcp { port });
    }
    Err(format!(
        "--source: expected synth | replay:path[@speed] | tail:path | udp:port | tcp:port, \
         got '{s}'"
    ))
}

/// One entry of a `--tenant` spec: a tenant name, its fair-share weight,
/// and an optional per-tenant latency SLO overriding the global
/// `--slo-ms`.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative fair-share weight (admission quota is proportional).
    pub weight: usize,
    /// `Some(ms)` when spelled `name=weight,slo_ms`; `None` inherits the
    /// global SLO (if any).
    pub slo_ms: Option<f64>,
}

/// Parse a `--tenant` spec: a comma-separated list of
/// `name=weight[,slo_ms]` entries. A token containing `=` starts a new
/// tenant; a bare numeric token is the per-tenant SLO (milliseconds) of
/// the tenant preceding it. E.g. `--tenant cam0=3,cam1=1` or
/// `--tenant cam0=3,5.0,cam1=1`.
pub fn parse_tenant_spec(s: &str) -> Result<Vec<TenantSpec>, String> {
    let mut out: Vec<TenantSpec> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if let Some((name, w)) = part.split_once('=') {
            if name.is_empty() {
                return Err(format!("tenant entry '{part}': empty tenant name"));
            }
            if out.iter().any(|t| t.name == name) {
                return Err(format!("tenant entry '{part}': duplicate tenant '{name}'"));
            }
            let weight: usize = w
                .parse()
                .map_err(|_| format!("tenant entry '{part}': bad weight '{w}'"))?;
            if weight == 0 {
                return Err(format!("tenant entry '{part}': weight must be >= 1"));
            }
            out.push(TenantSpec { name: name.to_string(), weight, slo_ms: None });
        } else {
            let tenant = out
                .last_mut()
                .ok_or_else(|| format!("tenant spec: slo '{part}' precedes any name=weight"))?;
            if tenant.slo_ms.is_some() {
                return Err(format!(
                    "tenant '{}': second slo value '{part}'",
                    tenant.name
                ));
            }
            let ms: f64 = part
                .parse()
                .map_err(|_| format!("tenant '{}': bad slo '{part}'", tenant.name))?;
            if !ms.is_finite() || ms <= 0.0 {
                return Err(format!(
                    "tenant '{}': slo must be finite and > 0, got {ms}",
                    tenant.name
                ));
            }
            tenant.slo_ms = Some(ms);
        }
    }
    if out.is_empty() {
        return Err("tenant spec: expected name=weight[,slo_ms] entries".into());
    }
    Ok(out)
}

/// A parsed `--shadow` spec: mirror a fraction of `model`'s served
/// traffic to a freshly built `arch` candidate and compare predictions.
#[derive(Debug, Clone, PartialEq)]
pub struct ShadowSpec {
    pub model: String,
    pub arch: String,
    /// Fraction of served requests to mirror, in (0, 1].
    pub fraction: f64,
}

/// Parse a `--shadow` spec: `model=arch[@fraction]`, e.g.
/// `det=mbv2@0.25` mirrors a quarter of model `det`'s served traffic to
/// a candidate `mbv2` build. The fraction defaults to 1.0 (mirror
/// everything) and must be in (0, 1] — a zero mirror is a misspelled
/// no-op, not a configuration.
pub fn parse_shadow_spec(s: &str) -> Result<ShadowSpec, String> {
    let (model, rest) = s
        .split_once('=')
        .ok_or_else(|| format!("shadow entry '{s}': expected model=arch[@fraction]"))?;
    let (arch, fraction) = match rest.split_once('@') {
        Some((a, f)) => {
            let f: f64 =
                f.parse().map_err(|_| format!("shadow entry '{s}': bad fraction '{f}'"))?;
            if !(f > 0.0 && f <= 1.0) {
                return Err(format!(
                    "shadow entry '{s}': fraction must be in (0, 1], got {f}"
                ));
            }
            (a, f)
        }
        None => (rest, 1.0),
    };
    if model.is_empty() || arch.is_empty() {
        return Err(format!("shadow entry '{s}': empty model or arch name"));
    }
    Ok(ShadowSpec { model: model.to_string(), arch: arch.to_string(), fraction })
}

/// A parsed `--swap` spec: hot-swap `model`'s serving backend to a
/// fresh `arch` build after `at_secs` seconds of serving.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapSpec {
    pub model: String,
    pub arch: String,
    /// Seconds into the run at which to flip.
    pub at_secs: f64,
}

/// Parse a `--swap` spec: `model=arch@secs`, e.g. `det=mbv2@1.5` swaps
/// model `det` to a fresh `mbv2` build 1.5 s into the run. The delay
/// must be finite, >= 0, and sane (<= 1e6 s).
pub fn parse_swap_spec(s: &str) -> Result<SwapSpec, String> {
    let err = || format!("swap entry '{s}': expected model=arch@secs");
    let (model, rest) = s.split_once('=').ok_or_else(err)?;
    let (arch, secs) = rest.split_once('@').ok_or_else(err)?;
    if model.is_empty() || arch.is_empty() {
        return Err(format!("swap entry '{s}': empty model or arch name"));
    }
    let at_secs: f64 =
        secs.parse().map_err(|_| format!("swap entry '{s}': bad delay '{secs}'"))?;
    // `contains` also rejects NaN and infinities.
    if !(0.0..=1e6).contains(&at_secs) {
        return Err(format!(
            "swap entry '{s}': delay must be finite, >= 0 and <= 1e6 s, got {at_secs}"
        ));
    }
    Ok(SwapSpec { model: model.to_string(), arch: arch.to_string(), at_secs })
}

/// Parse a `--model-mix` spec: a comma-separated list of `name=weight`
/// entries, e.g. `det=3,cls=1` sends model `det` three requests for
/// every one of `cls`. Weights are relative shares; a model absent from
/// the spec gets no synthetic traffic.
pub fn parse_mix_spec(s: &str) -> Result<Vec<(String, usize)>, String> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let (name, w) = part
            .split_once('=')
            .ok_or_else(|| format!("mix entry '{part}': expected name=weight"))?;
        if name.is_empty() {
            return Err(format!("mix entry '{part}': empty model name"));
        }
        if out.iter().any(|(n, _)| n == name) {
            return Err(format!("mix entry '{part}': duplicate model '{name}'"));
        }
        let w: usize =
            w.parse().map_err(|_| format!("mix entry '{part}': bad weight '{w}'"))?;
        out.push((name.to_string(), w));
    }
    if out.is_empty() {
        return Err("mix spec: expected name=weight entries".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], bools: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), bools).unwrap()
    }

    #[test]
    fn parses_flag_styles() {
        let a = parse(
            &["simulate", "--model=mbv2", "--steps", "100", "--verbose", "extra"],
            &["verbose"],
        );
        assert_eq!(a.positional(), &["simulate".to_string(), "extra".to_string()]);
        assert_eq!(a.get("model"), Some("mbv2"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["--steps".to_string()], &[]);
        assert!(r.is_err());
    }

    /// `--source --slo-ms 5` must not swallow `--slo-ms` as the value of
    /// `--source`; `--flag=--weird` stays the escape hatch for values
    /// that genuinely start with `--`.
    #[test]
    fn flag_value_cannot_be_another_flag() {
        let e = Args::parse(
            ["--source", "--slo-ms", "5"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap_err();
        assert!(e.contains("--source expects a value"), "got: {e}");
        let a = parse(&["--marker=--weird", "--steps", "3"], &[]);
        assert_eq!(a.get("marker"), Some("--weird"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 3);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("name", "d"), "d");
    }

    #[test]
    fn bad_int_reports_flag() {
        let a = parse(&["--steps", "abc"], &[]);
        let e = a.get_usize("steps", 0).unwrap_err();
        assert!(e.contains("steps"));
    }

    /// Repeated flags accumulate for `get_all` while the scalar
    /// accessors keep shell semantics (last occurrence wins).
    #[test]
    fn repeated_flags_accumulate() {
        let a = parse(
            &["--model", "det=mbv2", "--model=cls=lenet", "--seed", "1", "--seed", "2"],
            &[],
        );
        assert_eq!(a.get_all("model"), &["det=mbv2".to_string(), "cls=lenet".to_string()]);
        assert_eq!(a.get("seed"), Some("2"), "last occurrence wins");
        assert_eq!(a.get_usize("seed", 0).unwrap(), 2);
        assert!(a.get_all("nope").is_empty());
    }

    #[test]
    fn shadow_spec_parses_fraction_default_and_override() {
        assert_eq!(
            parse_shadow_spec("det=mbv2").unwrap(),
            ShadowSpec { model: "det".into(), arch: "mbv2".into(), fraction: 1.0 }
        );
        assert_eq!(
            parse_shadow_spec("det=mbv2@0.25").unwrap(),
            ShadowSpec { model: "det".into(), arch: "mbv2".into(), fraction: 0.25 }
        );
        for bad in ["", "det", "det=", "=mbv2", "det=mbv2@0", "det=mbv2@1.5", "det=mbv2@-1",
            "det=mbv2@x", "det=mbv2@nan"]
        {
            assert!(parse_shadow_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn swap_spec_parses_delay() {
        assert_eq!(
            parse_swap_spec("det=mbv2@1.5").unwrap(),
            SwapSpec { model: "det".into(), arch: "mbv2".into(), at_secs: 1.5 }
        );
        assert_eq!(parse_swap_spec("det=lenet@0").unwrap().at_secs, 0.0);
        for bad in ["", "det", "det=mbv2", "det=@1", "=mbv2@1", "det=mbv2@-1", "det=mbv2@x",
            "det=mbv2@inf", "det=mbv2@nan", "det=mbv2@1e7"]
        {
            assert!(parse_swap_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn mix_spec_parses_weights() {
        assert_eq!(
            parse_mix_spec("det=3,cls=1").unwrap(),
            vec![("det".to_string(), 3), ("cls".to_string(), 1)]
        );
        assert_eq!(parse_mix_spec("a=0").unwrap(), vec![("a".to_string(), 0)]);
        for bad in ["", "det", "det=", "=3", "det=x", "det=1,det=2", "det=1,,cls=2"] {
            assert!(parse_mix_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn pool_spec_parses_counts_and_batch_overrides() {
        let items = parse_pool_spec("func=4,sim=1,dense=2").unwrap();
        assert_eq!(
            items,
            vec![
                PoolItem { class: "func".into(), count: 4, max: None, batch: None },
                PoolItem { class: "sim".into(), count: 1, max: None, batch: None },
                PoolItem { class: "dense".into(), count: 2, max: None, batch: None },
            ]
        );
        let items = parse_pool_spec("func=4@8, sim=1").unwrap();
        assert_eq!(items[0].batch, Some(8));
        assert_eq!(
            items[1],
            PoolItem { class: "sim".into(), count: 1, max: None, batch: None }
        );
    }

    /// The autoscaling range syntax: `class=min..max[@batch]`.
    #[test]
    fn pool_spec_parses_replica_ranges() {
        let items = parse_pool_spec("func=1..4,sim=2..2@1,dense=3").unwrap();
        assert_eq!(
            items,
            vec![
                PoolItem { class: "func".into(), count: 1, max: Some(4), batch: None },
                PoolItem { class: "sim".into(), count: 2, max: Some(2), batch: Some(1) },
                PoolItem { class: "dense".into(), count: 3, max: None, batch: None },
            ]
        );
    }

    #[test]
    fn pool_spec_rejects_malformed_entries() {
        for bad in [
            "", "func", "func=", "func=0", "=3", "func=2@0", "func=2@x", "func=4,,sim=1",
            "func=4..2", "func=0..2", "func=..2", "func=1..", "func=1..x", "func=x..2",
        ] {
            assert!(parse_pool_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn source_spec_parses_every_variant() {
        assert_eq!(parse_source_spec("synth").unwrap(), SourceSpec::Synth);
        assert_eq!(
            parse_source_spec("replay:data/n_mnist_test.esda").unwrap(),
            SourceSpec::Replay { path: "data/n_mnist_test.esda".into(), speed: 1.0 }
        );
        assert_eq!(
            parse_source_spec("replay:d.esda@2.5").unwrap(),
            SourceSpec::Replay { path: "d.esda".into(), speed: 2.5 }
        );
        assert_eq!(
            parse_source_spec("tail:/var/cam/dump.esda").unwrap(),
            SourceSpec::Tail { path: "/var/cam/dump.esda".into() }
        );
        // A non-numeric suffix after '@' is part of the path, not a
        // malformed speed.
        assert_eq!(
            parse_source_spec("replay:runs@v2/cap.esda").unwrap(),
            SourceSpec::Replay { path: "runs@v2/cap.esda".into(), speed: 1.0 }
        );
        assert_eq!(parse_source_spec("udp:9000").unwrap(), SourceSpec::Udp { port: 9000 });
        assert_eq!(parse_source_spec("tcp:7700").unwrap(), SourceSpec::Tcp { port: 7700 });
    }

    #[test]
    fn source_spec_rejects_malformed_entries() {
        for bad in [
            "", "nope", "replay:", "replay:@2", "tail:", "replay:d.esda@0",
            "replay:d.esda@-1", "replay:d.esda@inf", "replay:d.esda@nan",
            "udp:", "udp:0", "udp:x", "udp:70000", "tcp:", "tcp:0", "tcp:-5",
        ] {
            assert!(parse_source_spec(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn tenant_spec_parses_weights_and_slos() {
        let ts = parse_tenant_spec("cam0=3,cam1=1").unwrap();
        assert_eq!(
            ts,
            vec![
                TenantSpec { name: "cam0".into(), weight: 3, slo_ms: None },
                TenantSpec { name: "cam1".into(), weight: 1, slo_ms: None },
            ]
        );
        let ts = parse_tenant_spec("cam0=3,5.5,cam1=2").unwrap();
        assert_eq!(ts[0].slo_ms, Some(5.5));
        assert_eq!(ts[1], TenantSpec { name: "cam1".into(), weight: 2, slo_ms: None });
        // Whitespace-tolerant, like the pool spec.
        let ts = parse_tenant_spec("a=1, 10, b=2").unwrap();
        assert_eq!(ts[0].slo_ms, Some(10.0));
        assert_eq!(ts[1].name, "b");
    }

    #[test]
    fn tenant_spec_rejects_malformed_entries() {
        for bad in [
            "", "cam0", "cam0=", "cam0=0", "=3", "cam0=x", "5,cam0=1", "cam0=1,5,6",
            "cam0=1,0", "cam0=1,-2", "cam0=1,inf", "cam0=1,cam0=2",
        ] {
            assert!(parse_tenant_spec(bad).is_err(), "accepted '{bad}'");
        }
    }
}
